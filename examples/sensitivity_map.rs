//! Parameter-democratization demo (paper §2.3 / Fig 2): compute OBS
//! sensitivity maps for a synthetic outlier-bearing weight matrix in
//! full precision vs 1-bit quantized form, and render the heatmaps.
//!
//!     cargo run --release --example sensitivity_map
//!
//! (For trained-model maps, run `repro experiment fig2` after training.)

use anyhow::Result;

use pquant::config::Variant;
use pquant::sensitivity::{ascii_heatmap, dequantized_weights, sensitivity_map};
use pquant::tensor::Matrix;
use pquant::util::rng::Rng;

fn main() -> Result<()> {
    let (k, n) = (96, 48);
    let mut rng = Rng::new(7);
    // bulk of weights small, a few outliers — the fp16 LLM regime
    let mut w = Matrix::from_fn(k, n, |_, _| rng.normal() * 0.05);
    for i in 0..12 {
        *w.at_mut((i * 17) % k, (i * 11) % n) = 2.5 + rng.f64() as f32;
    }
    let x = Matrix::from_fn(512, k, |_, _| rng.normal());

    println!("== full-precision weights ==");
    let fp = sensitivity_map(&w, &x, 1e-2)?;
    println!(
        "gini {:.3} | log-kurtosis {:.2} | top-1% mass {:.3}",
        fp.gini, fp.log_kurtosis, fp.top1pct_mass
    );
    println!("{}", ascii_heatmap(&fp.map, 16, 48));

    println!("== same weights after 1-bit sign/absmean quantization ==");
    let wq = dequantized_weights(&w, Variant::BitNet);
    let bq = sensitivity_map(&wq, &x, 1e-2)?;
    println!(
        "gini {:.3} | log-kurtosis {:.2} | top-1% mass {:.3}",
        bq.gini, bq.log_kurtosis, bq.top1pct_mass
    );
    println!("{}", ascii_heatmap(&bq.map, 16, 48));

    println!(
        "democratization: gini {:.3} → {:.3}, top-1% mass {:.3} → {:.3}",
        fp.gini, bq.gini, fp.top1pct_mass, bq.top1pct_mass
    );
    println!("(the paper's Fig 2 observation: quantization flattens the landscape)");
    Ok(())
}
