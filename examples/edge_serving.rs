//! Edge-serving demo (paper Appendix A + §4.5): batched request serving on
//! the packed rust engines, comparing pQuant against the FP16 and
//! BitNet1.58 baselines at identical geometry.
//!
//!     cargo run --release --example edge_serving

use anyhow::Result;

use pquant::config::{ModelConfig, Variant};
use pquant::infer::PackedModel;
use pquant::report::Table;
use pquant::serve::{load_test, ServeOptions};

fn geometry(variant: Variant, n_experts: usize) -> ModelConfig {
    ModelConfig {
        name: format!("edge-{}", variant.name()),
        variant,
        vocab: 1024,
        d_model: 256,
        n_layers: 4,
        n_heads: 8,
        d_ff: 704,
        r: if variant == Variant::PQuant { 32 } else { 0 },
        n_experts: if variant == Variant::PQuant { n_experts } else { 1 },
        seq_len: 128,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

fn main() -> Result<()> {
    let n_requests: usize = std::env::var("SERVE_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let opts = ServeOptions { max_batch: 4, workers: 1 };
    let mut t = Table::new(
        "Edge serving at matched geometry (16 new tokens/request)",
        &["engine", "resident MiB", "tokens/s", "p50 ms", "p95 ms", "vs fp16"],
    );
    let mut fp16_tps = 0.0;
    for (label, variant, n) in [
        ("fp16", Variant::Fp16, 1),
        ("bitnet1.58", Variant::BitNet158, 1),
        ("pquant n1", Variant::PQuant, 1),
        ("pquant n8", Variant::PQuant, 8),
    ] {
        let model = PackedModel::random(&geometry(variant, n), 3);
        let mib = model.storage_bytes() as f64 / (1024.0 * 1024.0);
        let (responses, _, tps) = load_test(vec![model], n_requests, 8, 16, &opts);
        let mut lats: Vec<f64> = responses
            .iter()
            .map(|r| (r.queue_wait + r.service_time).as_secs_f64() * 1e3)
            .collect();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if variant == Variant::Fp16 {
            fp16_tps = tps;
        }
        t.row(vec![
            label.into(),
            format!("{mib:.1}"),
            format!("{tps:.1}"),
            format!("{:.1}", lats[lats.len() / 2]),
            format!("{:.1}", lats[(lats.len() * 95 / 100).min(lats.len() - 1)]),
            format!("{:.2}x", tps / fp16_tps),
        ]);
    }
    t.print();
    println!("paper claims: >2x tokens/s vs FP16 (§1), traffic constant in N (§4.5)");
    Ok(())
}
