//! Edge-serving demo (paper Appendix A + §4.5): the full deployment path —
//! pack a model offline, export it as a `.pqm` artifact, load it back
//! through the multi-model [`ModelRegistry`], and serve streamed requests
//! through the persistent [`Engine`] — comparing pQuant against the FP16
//! and BitNet1.58 baselines at identical geometry, then hot-swapping a
//! generation in place *while requests are in flight*.
//!
//!     cargo run --release --example edge_serving

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use pquant::artifact;
use pquant::config::{ModelConfig, Variant};
use pquant::infer::PackedModel;
use pquant::report::Table;
use pquant::serve::{
    Engine, EngineOptions, Event, GenRequest, HttpServer, ModelRegistry, Router, Ticket,
};

fn geometry(variant: Variant, n_experts: usize) -> ModelConfig {
    ModelConfig {
        name: format!("edge-{}-n{n_experts}", variant.name()),
        variant,
        vocab: 1024,
        d_model: 256,
        n_layers: 4,
        n_heads: 8,
        d_ff: 704,
        r: if variant == Variant::PQuant { 32 } else { 0 },
        n_experts: if variant == Variant::PQuant { n_experts } else { 1 },
        seq_len: 128,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

fn main() -> Result<()> {
    let n_requests: usize = std::env::var("SERVE_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let pqm_dir = std::path::Path::new("results/pqm");
    let registry = Arc::new(ModelRegistry::new());

    let mut t = Table::new(
        "Edge serving from .pqm artifacts at matched geometry (16 new tokens/request)",
        &["engine", ".pqm MiB", "load ms", "tokens/s", "ttft p50 ms", "ttft p95 ms", "vs fp16"],
    );
    let mut fp16_tps = 0.0;
    for (label, variant, n) in [
        ("fp16", Variant::Fp16, 1),
        ("bitnet1.58", Variant::BitNet158, 1),
        ("pquant n1", Variant::PQuant, 1),
        ("pquant n8", Variant::PQuant, 8),
    ] {
        // Offline pack (stand-in for train → from_state) and export.
        let mut source = PackedModel::random(&geometry(variant, n), 3);
        let path = pqm_dir.join(format!("{}.pqm", source.cfg.name));
        let file_bytes = artifact::save_pqm(&source, None, &path)?;

        // Load through the registry — the restartable serving path.
        let t0 = Instant::now();
        registry.load_pqm(label, &path)?;
        let load_ms = t0.elapsed().as_secs_f64() * 1e3;

        // The loaded model must generate exactly the in-memory tokens.
        let (lease, mut reps) = registry.replicas(label, 1).expect("just registered");
        let mut replica = reps.pop().unwrap();
        ensure!(
            replica.generate(&[5, 9, 2], 12) == source.generate(&[5, 9, 2], 12),
            "{label}: .pqm round-trip changed generation output"
        );
        drop(lease);

        // Serve through the engine: workers hold registry leases, so a
        // concurrent hot-swap would observe them through the drain barrier.
        let engine = Engine::start(
            &registry,
            EngineOptions {
                model: label.into(),
                max_batch: 4,
                queue_depth: n_requests.max(64),
                ..EngineOptions::default()
            },
        )?;
        let t0 = Instant::now();
        // submit_blocking absorbs queue/KV backpressure as the burst drains.
        let tickets: Vec<Ticket> = (0..n_requests)
            .map(|id| {
                let prompt: Vec<u32> = (0..8).map(|i| (id as u32 + i as u32) % 1024).collect();
                engine.submit_blocking(GenRequest::greedy(prompt, 16))
            })
            .collect::<std::result::Result<_, _>>()?;
        let toks: usize = tickets.into_iter().map(|t| t.wait().tokens.len()).sum();
        let tps = toks as f64 / t0.elapsed().as_secs_f64();
        let ttft = engine.shutdown().ttft_percentiles();
        if variant == Variant::Fp16 {
            fp16_tps = tps;
        }
        t.row(vec![
            label.into(),
            format!("{:.1}", file_bytes as f64 / (1024.0 * 1024.0)),
            format!("{load_ms:.1}"),
            format!("{tps:.1}"),
            format!("{:.1}", ttft.p50),
            format!("{:.1}", ttft.p95),
            format!("{:.2}x", tps / fp16_tps),
        ]);
    }
    t.print();

    // Warm hot-swap under load: roll "pquant n1" forward to the n8 artifact
    // while requests are still decoding — in-flight requests drain on the
    // old generation's lease, new submissions land on the new one.
    let engine = Engine::start(
        &registry,
        EngineOptions { model: "pquant n1".into(), max_batch: 2, ..EngineOptions::default() },
    )?;
    let inflight = engine.submit(GenRequest::greedy(vec![5, 9, 2], 48))?;
    // Wait until it is actually decoding so the swap races real work.
    while !matches!(inflight.recv(), Some(Event::Token(_)) | None) {}
    let n8_path = pqm_dir.join(format!("{}.pqm", geometry(Variant::PQuant, 8).name));
    let report = registry.hot_swap_pqm("pquant n1", &n8_path, Duration::from_secs(2))?;
    let post_swap = engine.submit(GenRequest::greedy(vec![5, 9, 2], 16))?;
    let old = inflight.wait();
    let new = post_swap.wait();
    println!(
        "\nhot-swapped 'pquant n1' → n8 artifact: generation {} (drained: {}, {:.1} ms)",
        report.generation,
        report.drained,
        report.waited.as_secs_f64() * 1e3
    );
    println!(
        "  in-flight request finished on generation {} ({} tokens); post-swap request served by generation {}",
        old.generation,
        old.tokens.len(),
        new.generation
    );
    engine.shutdown();
    for m in registry.info() {
        println!(
            "  {:12} gen {} {:10} {:7.2}M params {:7.1} MiB resident",
            m.name,
            m.generation,
            m.variant.name(),
            m.params as f64 / 1e6,
            m.storage_bytes as f64 / (1024.0 * 1024.0)
        );
    }
    // Prefix sharing under a common system prompt: N concurrent requests
    // whose prompts start with the same 32 tokens. One warm-up request
    // registers the block-aligned prefix in the KV pool's share map; the
    // burst then attaches those frozen blocks instead of recomputing them,
    // and each request diverges into its own blocks by copy-on-write.
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "pquant n1".into(),
            max_batch: 4,
            queue_depth: n_requests.max(64),
            ..EngineOptions::default()
        },
    )?;
    let system: Vec<u32> = (0..32u32).map(|i| (i * 7) % 1024).collect();
    let mut warm = system.clone();
    warm.extend([1, 2]);
    engine.submit(GenRequest::greedy(warm, 8))?.wait();
    let tickets: Vec<Ticket> = (0..n_requests)
        .map(|id| {
            let mut prompt = system.clone();
            prompt.extend([id as u32 % 1024, 3, 9]);
            engine.submit_blocking(GenRequest::greedy(prompt, 16))
        })
        .collect::<std::result::Result<_, _>>()?;
    let burst_toks: usize = tickets.into_iter().map(|t| t.wait().tokens.len()).sum();
    let metrics = engine.shutdown();
    let kv = metrics.kv().expect("engine defaults to a paged KV pool");
    println!(
        "\nshared system prompt: {} requests x 16 new tokens ({} tokens out)",
        n_requests, burst_toks
    );
    println!(
        "  kv pool {} x {}-token blocks | utilization {:.1}% | shared-block hit rate {:.0}% \
         ({} of {} prompt blocks attached from the map) | cow copies {} | preempted {}",
        kv.n_blocks,
        kv.block_size,
        kv.utilization * 100.0,
        kv.shared_hit_rate * 100.0,
        kv.shared_attached,
        kv.prompt_blocks,
        kv.cow_copies,
        metrics.preempted.load(std::sync::atomic::Ordering::Relaxed),
    );

    // The network front door: the same engine behind the HTTP/SSE server
    // (`repro serve --http ADDR` is this, minus the in-process client).
    // The wire protocol is plain HTTP + SSE, so from a shell it is just:
    //
    //   curl -N http://ADDR/v1/generate -d '{"prompt": [5, 9, 2], "n_new": 12}'
    //   curl http://ADDR/v1/metrics
    //
    // Here we speak it over a raw TcpStream (offline containers have no
    // curl guarantee) and check the streamed tokens against the reference.
    let engine = Arc::new(Engine::start(
        &registry,
        EngineOptions { model: "pquant n1".into(), max_batch: 4, ..EngineOptions::default() },
    )?);
    let server = HttpServer::bind(
        "127.0.0.1:0",
        Router::new(registry.clone()).route("pquant n1", engine.clone()),
    )?;
    let addr = server.local_addr();
    println!("\nHTTP front end on http://{addr}");
    let body = r#"{"prompt": [5, 9, 2], "n_new": 12}"#;
    let mut conn = std::net::TcpStream::connect(addr)?;
    use std::io::{Read, Write};
    write!(
        conn,
        "POST /v1/generate HTTP/1.1\r\nHost: edge\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    let mut response = String::new();
    conn.read_to_string(&mut response)?;
    let streamed: Vec<u32> = response
        .lines()
        .filter_map(|l| l.strip_prefix("data: "))
        .filter_map(|d| pquant::util::json::Json::parse(d).ok())
        .filter_map(|j| j.opt("token").and_then(|t| t.as_usize().ok()).map(|t| t as u32))
        .collect();
    let (lease, mut reps) = registry.replicas("pquant n1", 1).expect("registered");
    ensure!(
        streamed == reps.pop().unwrap().generate(&[5, 9, 2], 12),
        "SSE stream diverged from the reference decode"
    );
    drop(lease);
    println!(
        "  streamed {} tokens over SSE, bit-identical to PackedModel::generate",
        streamed.len()
    );
    server.shutdown(); // drains in-flight streams, then joins every handler
    drop(engine);

    println!("\npaper claims: >2x tokens/s vs FP16 (§1), traffic constant in N (§4.5)");
    Ok(())
}
