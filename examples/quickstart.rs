//! Quickstart: the end-to-end driver (DESIGN.md §End-to-end validation).
//!
//! Trains pQuant from scratch on the synthetic corpus via the AOT train
//! step, logging the loss curve; evaluates held-out perplexity and the
//! 7-task zero-shot suite; then converts the checkpoint into packed 1-bit
//! inference weights and generates text with the pure-rust engine.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Environment knobs: QUICKSTART_CONFIG (default micro-pquant),
//! QUICKSTART_STEPS (default 250).

use anyhow::Result;

use pquant::coordinator::{TrainOptions, Trainer};
use pquant::data::default_cached_dataset;
use pquant::infer::PackedModel;
use pquant::runtime::{load_artifact, Runtime};

fn main() -> Result<()> {
    let config =
        std::env::var("QUICKSTART_CONFIG").unwrap_or_else(|_| "micro-pquant".to_string());
    let steps: u64 = std::env::var("QUICKSTART_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(250);

    println!("== pQuant quickstart: {config}, {steps} steps ==\n");
    let art = load_artifact(&config)?;
    let m = &art.manifest;
    println!(
        "model: {} params ({:.2}M), {:.2} avg bits/weight, d_model {}, {} layers, r {}, N {}",
        m.param_count,
        m.param_count as f64 / 1e6,
        m.avg_bits_per_weight,
        m.config.d_model,
        m.config.n_layers,
        m.config.r,
        m.config.n_experts
    );

    // 1. data: synthetic grammar corpus + BPE (cached across runs)
    let (dataset, bpe) = default_cached_dataset(m.config.vocab)?;
    println!(
        "data: {} train tokens, {} valid tokens, vocab {}\n",
        dataset.train.len(),
        dataset.valid.len(),
        dataset.vocab
    );

    // 2. QAT-from-scratch with the two-phase schedule
    let runtime = Runtime::cpu()?;
    let mut trainer = Trainer::new(&runtime, &art, &dataset)?;
    let ckpt = format!("results/quickstart-{config}.ckpt");
    let report = trainer.run(&TrainOptions {
        steps,
        log_every: (steps / 10).max(1),
        eval_every: (steps / 2).max(1),
        final_checkpoint: Some(ckpt.clone()),
        ..Default::default()
    })?;
    println!(
        "\ntraining done: loss {:.3} → {:.3}, {:.0} tokens/s, wall {:.1}s",
        report.losses.first().unwrap(),
        report.tail_loss,
        report.tokens_per_second,
        report.wall_seconds
    );
    println!("\nloss curve:");
    println!("{}", pquant::report::ascii_chart(&[("loss", &report.losses)], 64, 12));

    // 3. evaluation
    if let Some(ppl) = trainer.eval_perplexity(2048)? {
        println!("held-out perplexity: {ppl:.2}");
    }
    let fwd1 = runtime.compile(&art, "fwd")?;
    println!("\nzero-shot suite (chance-normalized):");
    for task in pquant::eval::task_suite(0x7A5C, 24) {
        let acc = pquant::eval::task_accuracy(
            &trainer.state,
            &fwd1,
            &bpe,
            &task,
            m.seq_len,
            m.config.vocab,
        )?;
        println!(
            "  {:6} {:5.1}%  (chance {:.0}%)",
            task.paper_name,
            acc * 100.0,
            task.chance * 100.0
        );
    }

    // 4. deploy: pack to 1-bit + INT8 and generate with the rust engine
    let mut packed = PackedModel::from_state(&art, &trainer.state)?;
    println!(
        "\npacked model: {:.2} MiB resident ({:.1}x smaller than fp16)",
        packed.storage_bytes() as f64 / (1024.0 * 1024.0),
        (m.param_count * 2) as f64 / packed.storage_bytes() as f64
    );
    for prompt in ["the fox is a", "the opposite of hot is", "you cut the bread with a"] {
        let ids = bpe.encode(prompt);
        let out = packed.generate(&ids, 6);
        println!("  {prompt:32} → {}", bpe.decode(&out).trim());
    }
    println!("\nquickstart complete; checkpoint at {ckpt}");
    Ok(())
}
