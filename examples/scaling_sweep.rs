//! Expert-scaling sweep (paper §4.3 / Fig 7 left): train pQuant with
//! N ∈ {1, 2, 4, 8} expert branches at micro scale and report the
//! perplexity trend against the 2-bit BitNet1.58 reference.
//!
//!     cargo run --release --example scaling_sweep
//!
//! Uses the shared experiment cache, so a prior `repro experiment all`
//! makes this instant.

use anyhow::Result;

use pquant::experiments::Lab;
use pquant::report::Table;

fn main() -> Result<()> {
    let steps: u64 = std::env::var("SWEEP_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(250);
    let mut lab = Lab::new()?;
    let mut t = Table::new(
        "Expert scaling sweep (micro, matched data budget)",
        &["config", "N", "total params", "activated", "PPL", "avg acc %"],
    );
    for (n, config) in [
        (1, "micro-pquant"),
        (2, "micro-pquant-n2"),
        (4, "micro-pquant-n4"),
        (8, "micro-pquant-n8"),
    ] {
        let r = lab.run(config, steps, "", |_| {})?;
        let art = lab.artifact(config)?;
        t.row(vec![
            config.into(),
            n.to_string(),
            format!("{:.2}M", art.manifest.param_count as f64 / 1e6),
            format!("{:.2}M", art.manifest.activated_param_count as f64 / 1e6),
            format!("{:.2}", r.ppl),
            format!("{:.1}", r.avg_acc()),
        ]);
    }
    let b = lab.run("micro-bitnet158", steps, "", |_| {})?;
    t.row(vec![
        "micro-bitnet158 (2-bit ref)".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{:.2}", b.ppl),
        format!("{:.1}", b.avg_acc()),
    ]);
    t.print();
    Ok(())
}
