"""L2: AdamW train step for QAT-from-scratch (paper sec 4 + Appendix B).

The step is a pure function

    (params, m, v, sched, tokens) -> (loss, params', m', v')

lowered once per config by aot.py.  ``sched = [step, lr, wd]`` is a plain
f32[3] operand so the *rust coordinator* owns the two-phase learning-rate /
weight-decay schedule (Appendix B.2) and simply feeds different scalars as
training progresses - no re-lowering, no python at runtime.

Optimizer: AdamW with beta1=0.9, beta2=0.95 (paper Appendix C), decoupled
weight decay applied only to >=2-D latent weight matrices (Appendix B.2
discusses decay acting on latent weights).  Gradients and optimizer state
are f32 throughout (sec 3.1: "gradients and optimizer states are maintained
in FP32").
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from . import model

ADAM_B1 = 0.9
ADAM_B2 = 0.95
ADAM_EPS = 1e-8


def decay_mask(params):
    """1.0 for weight matrices (ndim >= 2), 0.0 for norms/scalars/embeddings.

    Embeddings and the LM head stay full precision and are excluded from
    decay, matching common LLM practice for the high-precision tensors the
    paper leaves untouched.
    """
    def mask_leaf(path, leaf):
        name = "/".join(str(p) for p in path)
        if leaf.ndim < 2:
            return 0.0
        if "tok_embed" in name or "lm_head" in name:
            return 0.0
        return 1.0
    return jax.tree_util.tree_map_with_path(mask_leaf, params)


def adamw_step(params, grads, m, v, step, lr, wd, mask):
    """One decoupled-weight-decay Adam update (all pytrees)."""
    m = jax.tree_util.tree_map(
        lambda mi, g: ADAM_B1 * mi + (1 - ADAM_B1) * g, m, grads)
    v = jax.tree_util.tree_map(
        lambda vi, g: ADAM_B2 * vi + (1 - ADAM_B2) * g * g, v, grads)
    bc1 = 1.0 - ADAM_B1 ** step
    bc2 = 1.0 - ADAM_B2 ** step

    def upd(p, mi, vi, mk):
        mhat = mi / bc1
        vhat = vi / bc2
        return p - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + wd * mk * p)

    params = jax.tree_util.tree_map(upd, params, m, v, mask)
    return params, m, v


def make_train_step(cfg: ModelConfig):
    """Builds the jittable train step for one config."""
    def train_step(params, m, v, sched, tokens):
        step, lr, wd = sched[0], sched[1], sched[2]
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(cfg, p, tokens))(params)
        mask = decay_mask(params)
        params, m, v = adamw_step(params, grads, m, v, step, lr, wd, mask)
        return loss, params, m, v
    return train_step


def init_opt_state(params):
    """Zero-initialized Adam moments, matching the params pytree."""
    zeros = lambda p: jnp.zeros_like(p)
    return (jax.tree_util.tree_map(zeros, params),
            jax.tree_util.tree_map(zeros, params))


def make_grad_fn(cfg: ModelConfig):
    """(params, tokens) -> (loss, grads); used by tests and the L2 profile."""
    def grad_fn(params, tokens):
        return jax.value_and_grad(
            lambda p: model.loss_fn(cfg, p, tokens))(params)
    return grad_fn
