"""Model/variant configurations shared by model.py, aot.py and the tests.

Paper Table 1 defines 300M–2.6B configs with D_ff ≈ (8/3)·D_model, an 8-bit
branch width r ≈ 4-5% of parameters (r a multiple of 128), and N ∈ {1..8}
experts.  We preserve every *ratio* but scale the absolute sizes to the
CPU-only testbed (DESIGN.md §3): r is a multiple of 16 (= 128/8, the same
/8 factor applied to D_model) and the r/D_ff fraction matches the paper.

``CONFIGS`` maps "<size>-<variant>[-nN]" → ModelConfig, e.g.
"tiny-pquant-n4", "micro-bitnet", "small-fp16".
"""

import dataclasses
from dataclasses import dataclass

VARIANTS = ("fp16", "bitnet", "bitnet158", "pquant")

# 8-bit branch width granularity: the paper uses multiples of 128 for
# "hardware efficiency"; our sizes are /8 of the paper's so the block is 16.
R_BLOCK = 16


@dataclass(frozen=True)
class ModelConfig:
    """A single (size, variant) training/inference configuration."""
    name: str
    variant: str          # one of VARIANTS
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int             # total FFN hidden width (1-bit part + r)
    r: int = 0            # 8-bit branch width (pquant only)
    n_experts: int = 1    # number of 8-bit branches N (pquant only)
    seq_len: int = 128
    alpha_init: float = 2.0   # feature scaling init for the 8-bit branch
    beta_init: float = 0.2    # feature scaling init for the 1-bit branch

    def __post_init__(self):
        assert self.variant in VARIANTS, self.variant
        assert self.d_model % self.n_heads == 0
        if self.variant == "pquant":
            assert 0 < self.r < self.d_ff
            assert self.r % R_BLOCK == 0, f"r must be a multiple of {R_BLOCK}"
        else:
            assert self.r == 0 and self.n_experts == 1

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_ff_1bit(self) -> int:
        """Width of the 1-bit FFN branch (paper: D_ff − r)."""
        return self.d_ff - self.r

    def param_count(self) -> int:
        """Total parameter count (embeddings + blocks + head)."""
        d, v = self.d_model, self.vocab
        n = 2 * v * d                      # tok embedding + untied lm head
        per_layer = 4 * d * d              # q, k, v, o
        per_layer += 2 * d                 # two RMSNorm gains
        if self.variant == "pquant":
            per_layer += 2 * d * self.d_ff_1bit          # 1-bit up+down
            per_layer += self.n_experts * 2 * d * self.r  # 8-bit experts
            per_layer += d * self.n_experts               # router
            per_layer += 2                                # alpha, beta
        else:
            per_layer += 2 * d * self.d_ff
        n += self.n_layers * per_layer
        n += d                             # final norm
        return n

    def activated_param_count(self) -> int:
        """Parameters touched per forward pass (top-1: one expert active)."""
        if self.variant != "pquant":
            return self.param_count()
        full = self.param_count()
        inactive = (self.n_experts - 1) * 2 * self.d_model * self.r * self.n_layers
        return full - inactive

    def avg_bits_per_weight(self) -> float:
        """Average storage bits per *block* weight (paper's 1.28–1.35 bit).

        Embeddings/norms are excluded, matching the paper's convention of
        quoting the quantized-linear-layer bit width.
        """
        d = self.d_model
        if self.variant == "fp16":
            return 16.0
        if self.variant == "bitnet":
            return 1.0
        if self.variant == "bitnet158":
            return 1.58
        one_bit = 4 * d * d + 2 * d * self.d_ff_1bit
        eight_bit = self.n_experts * 2 * d * self.r
        return (one_bit * 1.0 + eight_bit * 8.0) / (one_bit + eight_bit)


def _mk(size_name, vocab, d_model, n_layers, n_heads, d_ff_total, r, seq_len):
    """Build the four variants (+ expert sweeps for pquant) of one size."""
    out = {}
    for variant in ("fp16", "bitnet", "bitnet158"):
        out[f"{size_name}-{variant}"] = ModelConfig(
            name=f"{size_name}-{variant}", variant=variant, vocab=vocab,
            d_model=d_model, n_layers=n_layers, n_heads=n_heads,
            d_ff=d_ff_total, seq_len=seq_len)
    for n in (1, 2, 4, 8):
        suffix = "" if n == 1 else f"-n{n}"
        out[f"{size_name}-pquant{suffix}"] = ModelConfig(
            name=f"{size_name}-pquant{suffix}", variant="pquant", vocab=vocab,
            d_model=d_model, n_layers=n_layers, n_heads=n_heads,
            d_ff=d_ff_total, r=r, n_experts=n, seq_len=seq_len)
    return out


CONFIGS = {}
# name           vocab  d    L  H  d_ff   r   seq
CONFIGS.update(_mk("nano",  512,  64,  2, 2, 176,  16, 64))
CONFIGS.update(_mk("micro", 512,  128, 4, 4, 352,  16, 128))
CONFIGS.update(_mk("tiny",  1024, 256, 4, 8, 704,  32, 128))
CONFIGS.update(_mk("small", 1024, 384, 6, 8, 1056, 48, 128))

# The default artifact set built by `make artifacts` (DESIGN.md §5); other
# configs can be built on demand with `python -m compile.aot --config X`.
DEFAULT_ARTIFACTS = [
    "nano-fp16", "nano-bitnet", "nano-bitnet158", "nano-pquant",
    "nano-pquant-n4",
    "micro-fp16", "micro-bitnet", "micro-bitnet158",
    "micro-pquant", "micro-pquant-n2", "micro-pquant-n4", "micro-pquant-n8",
    "tiny-fp16", "tiny-bitnet", "tiny-bitnet158", "tiny-pquant",
    "tiny-pquant-n8",
]


def get_config(name: str) -> ModelConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown config {name!r}; known: {sorted(CONFIGS)}")
    return CONFIGS[name]


def scaled_variant(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Derive a modified config (used by ablation harnesses, e.g. r-sweep)."""
    d = dataclasses.asdict(cfg)
    d.update(overrides)
    return ModelConfig(**d)
