"""AOT compile path: lower L2 functions to HLO *text* + manifest sidecars.

For every requested config this emits, under artifacts/<config>/:

  train_step.hlo.txt      (params, m, v, sched[3], tokens[B,T+1]) ->
                          (loss, params', m', v')     [flat operand order]
  fwd.hlo.txt             (params, tokens[1,T]) -> (logits, ffn_input)
  manifest.json           operand/result layout: names, shapes, dtypes,
                          flatten order, config echo, batch sizes
  init.npz                seeded initial parameters (numpy .npz, read by
                          the rust runtime via xla::Literal::read_npz)
  golden.json             (nano configs) loss trajectory for a fixed batch,
                          the rust integration tests' ground truth

HLO text - NOT ``.serialize()`` - is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Python runs ONCE, at build time.  `make artifacts` is incremental: a config
is skipped when its manifest is newer than the compile/ sources.
"""

import argparse
import json
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import CONFIGS, DEFAULT_ARTIFACTS, get_config
from . import model
from . import train

SEED = 20260710  # fixed: reproducible init across builds

# Per-size training batch (paper: 1M tokens "for the other models"; scaled).
TRAIN_BATCH = {"nano": 8, "micro": 8, "tiny": 8, "small": 4}
# Extra batch sizes for the batch-size ablation (Appendix E), micro only.
ABLATION_BATCHES = {"micro": [2, 32]}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def path_name(path) -> str:
    """KeyPath -> dotted name, e.g. layers.0.ffn_up_8bit."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def flat_entries(tree, prefix):
    """Flatten a pytree into manifest entries, in tree_flatten order."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        out.append({
            "name": f"{prefix}{path_name(path)}",
            "shape": list(leaf.shape),
            "dtype": {"float32": "f32", "int32": "s32"}[str(leaf.dtype)],
        })
    return out


def array_entry(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def build_config(name: str, out_dir: str, golden: bool, force: bool):
    cfg = get_config(name)
    cdir = os.path.join(out_dir, name)
    manifest_path = os.path.join(cdir, "manifest.json")
    srcs = [os.path.join(os.path.dirname(__file__), f)
            for f in ("aot.py", "model.py", "train.py", "configs.py")]
    src_mtime = max(os.path.getmtime(s) for s in srcs)
    kdir = os.path.join(os.path.dirname(__file__), "kernels")
    src_mtime = max(src_mtime, max(
        os.path.getmtime(os.path.join(kdir, f))
        for f in os.listdir(kdir) if f.endswith(".py")))
    if (not force and os.path.exists(manifest_path)
            and os.path.getmtime(manifest_path) > src_mtime):
        print(f"[aot] {name}: up to date")
        return

    os.makedirs(cdir, exist_ok=True)
    size = name.split("-")[0]
    batch = TRAIN_BATCH[size]
    seq = cfg.seq_len

    key = jax.random.PRNGKey(SEED)
    params = model.init_params(cfg, key)
    m0, v0 = train.init_opt_state(params)

    param_entries = flat_entries(params, "")
    m_entries = flat_entries(m0, "m.")
    v_entries = flat_entries(v0, "v.")

    # ---- train step -------------------------------------------------------
    step_fn = train.make_train_step(cfg)
    sched_spec = jax.ShapeDtypeStruct((3,), jnp.float32)
    entries = {}
    batches = [batch] + ABLATION_BATCHES.get(size, [])
    for b in batches:
        tok_spec = jax.ShapeDtypeStruct((b, seq + 1), jnp.int32)
        lowered = jax.jit(step_fn, keep_unused=True).lower(params, m0, v0, sched_spec, tok_spec)
        suffix = "" if b == batch else f"_b{b}"
        fname = f"train_step{suffix}.hlo.txt"
        with open(os.path.join(cdir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        entries[f"train_step{suffix}"] = {
            "file": fname,
            "batch": b,
            "inputs": (param_entries + m_entries + v_entries
                       + [array_entry("sched", (3,), "f32"),
                          array_entry("tokens", (b, seq + 1), "s32")]),
            "outputs": ([array_entry("loss", (), "f32")]
                        + param_entries + m_entries + v_entries),
        }
        print(f"[aot] {name}: lowered train_step b={b}")

    # ---- forward (eval/calibration) ---------------------------------------
    def fwd(params, tokens):
        return model.forward(cfg, params, tokens, return_ffn_input=True)

    for fb, fkey in ((1, "fwd"), (8, "fwd_b8")):
        tok_spec = jax.ShapeDtypeStruct((fb, seq), jnp.int32)
        lowered = jax.jit(fwd, keep_unused=True).lower(params, tok_spec)
        fname = f"{fkey}.hlo.txt"
        with open(os.path.join(cdir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        entries[fkey] = {
            "file": fname,
            "batch": fb,
            "inputs": param_entries + [array_entry("tokens", (fb, seq), "s32")],
            "outputs": [
                array_entry("logits", (fb, seq, cfg.vocab), "f32"),
                array_entry("ffn_input", (fb * seq, cfg.d_model), "f32"),
            ],
        }
        print(f"[aot] {name}: lowered {fkey}")

    # ---- init params (.npz, uncompressed for the rust zip reader) ---------
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    np.savez(os.path.join(cdir, "init.npz"),
             **{path_name(p): np.asarray(l) for p, l in leaves})

    # ---- golden trajectory (nano only: cheap, exact) -----------------------
    if golden:
        rng = np.random.default_rng(SEED)
        tokens = rng.integers(0, cfg.vocab, (batch, seq + 1), dtype=np.int32)
        jit_step = jax.jit(step_fn)
        p, m, v = params, m0, v0
        losses = []
        for i in range(3):
            sched = jnp.asarray([i + 1, 1e-3, 0.1], jnp.float32)
            loss, p, m, v = jit_step(p, m, v, sched, jnp.asarray(tokens))
            losses.append(float(loss))
        with open(os.path.join(cdir, "golden.json"), "w") as f:
            json.dump({"tokens": tokens.tolist(), "sched_lr": 1e-3,
                       "sched_wd": 0.1, "losses": losses}, f)
        print(f"[aot] {name}: golden losses {losses}")

    # ---- manifest ----------------------------------------------------------
    import dataclasses
    manifest = {
        "config": dataclasses.asdict(cfg),
        "derived": {
            "param_count": cfg.param_count(),
            "activated_param_count": cfg.activated_param_count(),
            "avg_bits_per_weight": cfg.avg_bits_per_weight(),
            "d_ff_1bit": cfg.d_ff_1bit,
            "head_dim": cfg.head_dim,
        },
        "seed": SEED,
        "train_batch": batch,
        "seq_len": seq,
        "param_layout": param_entries,
        "entries": entries,
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] {name}: manifest written")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", action="append", default=[],
                    help="config name (repeatable); default: DEFAULT_ARTIFACTS")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for n in sorted(CONFIGS):
            c = CONFIGS[n]
            print(f"{n:24s} params={c.param_count()/1e6:7.2f}M "
                  f"bits={c.avg_bits_per_weight():5.2f}")
        return

    names = args.config or DEFAULT_ARTIFACTS
    for n in names:
        build_config(n, args.out_dir, golden=n.startswith("nano"),
                     force=args.force)


if __name__ == "__main__":
    main()
