"""Shared helpers for the Pallas kernels.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls), but the tiling is written as it would be for a real TPU:
blocks sized against the ~16 MiB VMEM budget, last dim a multiple of the
128-lane register width when shapes allow, f32 accumulation (the MXU's
bf16×bf16→f32 contract shape).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Structural targets used when shapes are large enough to tile; tiny test
# shapes fall back to whole-array blocks via ``choose_block``.
TARGET_BM = 128   # rows of the activation tile
TARGET_BN = 128   # output-feature tile (lane dim)
TARGET_BK = 512   # contraction tile

INTERPRET = True  # CPU PJRT: interpret-mode only (see DESIGN.md)


def choose_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is ≤ ``target``.

    Pallas pads ragged edges, but exact-divisor blocks keep the interpret
    path allocation-free and make the VMEM accounting exact.
    """
    if dim <= target:
        return dim
    for b in range(target, 0, -1):
        if dim % b == 0:
            return b
    return dim


def vmem_bytes(*block_shapes_dtypes) -> int:
    """Estimate the VMEM working set of a kernel invocation.

    Takes ``(shape, dtype)`` pairs for every Ref live in the kernel and sums
    their byte sizes — recorded per kernel in EXPERIMENTS.md §Perf.
    """
    total = 0
    for shape, dtype in block_shapes_dtypes:
        n = 1
        for d in shape:
            n *= d
        total += n * jnp.dtype(dtype).itemsize
    return total


def matmul_grid(m: int, k: int, n: int):
    """Common (grid, block) decomposition for the tiled matmul kernels."""
    bm = choose_block(m, TARGET_BM)
    bn = choose_block(n, TARGET_BN)
    bk = choose_block(k, TARGET_BK)
    grid = (m // bm, n // bn, k // bk)
    return grid, (bm, bk, bn)
