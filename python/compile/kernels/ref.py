"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

pytest (python/tests/) asserts allclose between each kernel under
interpret=True and its oracle here, sweeping shapes and value ranges with
hypothesis.  These are deliberately the most naive possible expressions of
the math; no tiling, no fusion.
"""

import jax
import jax.numpy as jnp

from .rmsnorm import RMS_EPS


def quantized_matmul_ref(x_q, w_q, scale):
    """Oracle for bitlinear.quantized_matmul."""
    return (x_q.astype(jnp.float32) @ w_q.astype(jnp.float32)) * scale


def decoupled_matmul_ref(x_q, w1_q, w8_q, scale1, scale8):
    """Oracle for decoupled.decoupled_matmul."""
    x = x_q.astype(jnp.float32)
    y1 = (x @ w1_q.astype(jnp.float32)) * scale1
    y8 = (x @ w8_q.astype(jnp.float32)) * scale8
    return y1, y8


def rmsnorm_ref(x, gain):
    """Oracle for rmsnorm.rmsnorm."""
    x = x.astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + RMS_EPS) * gain


def router_top1_ref(x, w_router):
    """Oracle for router.router_top1."""
    logits = x.astype(jnp.float32) @ w_router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    idx = jnp.argmax(probs, axis=-1).astype(jnp.int32)
    gate = jnp.max(probs, axis=-1)
    return idx, gate


# ---------------------------------------------------------------------------
# Quantizer oracles (manual re-derivations, kept independent of quantize.py)
# ---------------------------------------------------------------------------

def binarize_ref(w):
    mu = w.mean()
    c = w - mu
    lam = jnp.abs(c).mean() + 1e-6
    return jnp.where(c >= 0, 1.0, -1.0), lam


def ternarize_ref(w):
    s = jnp.abs(w).mean() + 1e-6
    return jnp.clip(jnp.round(w / s), -1, 1), s


def absmax_ref(x, axis=-1):
    g = 127.0 / (jnp.max(jnp.abs(x), axis=axis, keepdims=True) + 1e-6)
    return jnp.clip(jnp.round(x * g), -127, 127), g
