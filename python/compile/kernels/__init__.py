"""L1: Pallas kernels for pQuant (interpret=True; see DESIGN.md).

Public surface:
  quantized_matmul / w1a8_matmul / w8a8_matmul   — tiled scaled matmuls
  decoupled_matmul                               — fused dual-branch matmul
  rmsnorm                                        — row-tiled RMSNorm
  router_top1 / router_probs                     — top-1 expert gate
  quantize.*                                     — quantizers + STE
"""

from .bitlinear import quantized_matmul, w1a8_matmul, w8a8_matmul
from .decoupled import decoupled_matmul
from .rmsnorm import rmsnorm, RMS_EPS
from .router import router_top1, router_probs
from . import quantize
from . import ref

__all__ = [
    "quantized_matmul", "w1a8_matmul", "w8a8_matmul", "decoupled_matmul",
    "rmsnorm", "RMS_EPS", "router_top1", "router_probs", "quantize", "ref",
]
