"""Quantization primitives shared by the L1 kernels and the L2 model.

These implement the paper's equations:

  eq. (3)-(6): 1-bit sign/absmean weight quantization with mean-centering
  eq. (7)-(9): INT8 absmax activation quantization along the token dim
  BitNet1.58 : ternary absmean weight quantization (baseline)

Each ``*_ste`` variant wraps the non-differentiable rounding in a
Straight-Through Estimator (Appendix B.1): the forward pass sees the
quantized value, the backward pass sees identity.
"""

from functools import partial

import jax
import jax.numpy as jnp

# Quantization epsilon guarding against division by zero on all-zero
# tensors (paper's eps in eq. 7).
EPS = 1e-6

# Symmetric INT8 clip bound.  The paper writes [-2^7, 2^7]; we use the
# symmetric [-127, 127] so the rust LUT engine can negate activations
# without overflow and the two implementations match bit-exactly.
Q8_BOUND = 127.0


def ste(quantized: jax.Array, full_precision: jax.Array) -> jax.Array:
    """Straight-Through Estimator: forward = quantized, backward = identity.

    Implemented as ``x + stop_grad(q - x)``, the standard trick — gradients
    of non-differentiable ``q`` are approximated as 1 (Bengio et al., 2013).
    """
    return full_precision + jax.lax.stop_gradient(quantized - full_precision)


def round_clip(x: jax.Array, lo: float, hi: float) -> jax.Array:
    """``RoundClip`` of eq. (8): round-to-nearest then clamp to [lo, hi]."""
    return jnp.clip(jnp.round(x), lo, hi)


# ---------------------------------------------------------------------------
# 1-bit weights (eq. 3-6)
# ---------------------------------------------------------------------------

def binarize_weight(w: jax.Array):
    """Sign/absmean 1-bit quantization with mean-centering.

    Returns ``(w_q, lam)`` where ``w_q ∈ {-1, +1}`` (f32) and ``lam`` is the
    per-tensor dequantization scale λ = mean|W - μ| of the centered weights.
    ``sign(0)`` maps to +1 so exactly one bit encodes each weight.
    """
    mu = jnp.mean(w)
    centered = w - mu
    lam = jnp.mean(jnp.abs(centered)) + EPS
    w_q = jnp.where(centered >= 0, 1.0, -1.0).astype(w.dtype)
    return w_q, lam


def binarize_weight_ste(w: jax.Array):
    """STE variant: forward sees λ·sign(W−μ), backward is identity on W."""
    w_q, lam = binarize_weight(w)
    return ste(w_q * lam, w), lam


# ---------------------------------------------------------------------------
# Ternary weights (BitNet1.58 baseline)
# ---------------------------------------------------------------------------

def ternarize_weight(w: jax.Array):
    """AbsMean ternary quantization: W_q ∈ {-1, 0, +1} with scale mean|W|."""
    scale = jnp.mean(jnp.abs(w)) + EPS
    w_q = round_clip(w / scale, -1.0, 1.0)
    return w_q, scale


def ternarize_weight_ste(w: jax.Array):
    w_q, scale = ternarize_weight(w)
    return ste(w_q * scale, w), scale


# ---------------------------------------------------------------------------
# INT8 (eq. 7-9) — activations and the high-precision branch weights
# ---------------------------------------------------------------------------

def absmax_quantize(x: jax.Array, axis=-1):
    """Per-token AbsMax INT8 quantization (eq. 7-9).

    Returns ``(x_q, gamma)``: ``x_q`` holds integers in [-127, 127] (kept in
    the input dtype so it can flow through a matmul), ``gamma`` is the
    per-token scale 127 / max|x| with shape broadcastable against ``x``.
    """
    absmax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    gamma = Q8_BOUND / (absmax + EPS)
    x_q = round_clip(x * gamma, -Q8_BOUND, Q8_BOUND)
    return x_q, gamma


def absmax_quantize_ste(x: jax.Array, axis=-1):
    """STE variant used on activations: forward quantize, backward identity.

    Returns the *dequantized* simulated value ``x̂ = x_q / γ`` with STE, plus
    ``(x_q, gamma)`` for callers that need the raw integers.
    """
    x_q, gamma = absmax_quantize(x, axis=axis)
    return ste(x_q / gamma, x), x_q, gamma


def absmax_quantize_per_tensor(w: jax.Array):
    """Per-tensor AbsMax INT8 — used for the 8-bit branch weights."""
    absmax = jnp.max(jnp.abs(w))
    gamma = Q8_BOUND / (absmax + EPS)
    w_q = round_clip(w * gamma, -Q8_BOUND, Q8_BOUND)
    return w_q, gamma


def int8_weight_ste(w: jax.Array):
    """STE per-tensor INT8 weight quantization for the 8-bit branch."""
    w_q, gamma = absmax_quantize_per_tensor(w)
    return ste(w_q / gamma, w), w_q, gamma


# ---------------------------------------------------------------------------
# Ablation quantizers (paper §4.6: channel-wise / group-wise 1-bit)
# ---------------------------------------------------------------------------

def binarize_weight_channelwise(w: jax.Array):
    """Per-output-channel sign/absmean (ablation, Fig 7 right).

    ``w`` is [in, out]; scales are per column.
    """
    mu = jnp.mean(w, axis=0, keepdims=True)
    centered = w - mu
    lam = jnp.mean(jnp.abs(centered), axis=0, keepdims=True) + EPS
    w_q = jnp.where(centered >= 0, 1.0, -1.0).astype(w.dtype)
    return w_q, lam


def binarize_weight_groupwise(w: jax.Array, group: int = 64):
    """Group-of-``group`` sign/absmean along the input dim (ablation).

    Requires ``in % group == 0``. Returns w_q and a [in/group, out] scale.
    """
    k, n = w.shape
    assert k % group == 0, f"group {group} must divide in-dim {k}"
    wg = w.reshape(k // group, group, n)
    mu = jnp.mean(wg, axis=1, keepdims=True)
    centered = wg - mu
    lam = jnp.mean(jnp.abs(centered), axis=1, keepdims=True) + EPS
    w_q = jnp.where(centered >= 0, 1.0, -1.0).astype(w.dtype)
    return w_q.reshape(k, n), lam[:, 0, :]


def dequant_groupwise(w_q: jax.Array, lam: jax.Array, group: int = 64):
    """Inverse of :func:`binarize_weight_groupwise` (to a dense f32 matrix)."""
    k, n = w_q.shape
    wq = w_q.reshape(k // group, group, n)
    return (wq * lam[:, None, :]).reshape(k, n)


def binarize_weight_groupwise_ste(w: jax.Array, group: int = 64):
    w_q, lam = binarize_weight_groupwise(w, group)
    return ste(dequant_groupwise(w_q, lam, group), w), lam
