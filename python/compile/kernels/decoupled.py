"""Fused decoupled-linear Pallas kernel (paper §3.2 + Appendix A).

The decoupled FFN up-projection multiplies the *same* INT8 activations with
two weight matrices — the wide 1-bit branch W1 [K, N1] and the narrow INT8
branch W8 [K, r].  Appendix A notes the efficient implementation shares the
activation read across both products ("distributed across multiple thread
groups, enabling parallel execution without redundant data reads"); here
the two products are fused into a single kernel so every X tile is loaded
into VMEM once per (i, k) step and feeds both accumulators.

Feature scaling (eq. 11) is applied inside the kernel on the final k step:
the α/λ/γ scalars for each branch are pre-fused by the caller into one
scale per branch.

Grid layout: ``(M/bm, N1/bn1, K/bk)`` with k innermost.  The narrow 8-bit
branch output is only accumulated on the ``j == 0`` slice of the grid so it
is computed exactly once per (i, k).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, choose_block, TARGET_BM, TARGET_BK, TARGET_BN


def _decoupled_kernel(x_ref, w1_ref, w8_ref, s_ref, o1_ref, o8_ref, *, nk: int):
    j = pl.program_id(1)
    k = pl.program_id(2)

    x = x_ref[...].astype(jnp.float32)

    @pl.when(k == 0)
    def _init1():
        o1_ref[...] = jnp.zeros_like(o1_ref)

    # One activation load feeds both MXU contractions.
    o1_ref[...] += jnp.dot(x, w1_ref[...].astype(jnp.float32),
                           preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _rescale1():
        o1_ref[...] *= s_ref[0, 0]   # β · λ / γ

    # The narrow branch is shared across all j tiles: compute it on j == 0.
    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init8():
        o8_ref[...] = jnp.zeros_like(o8_ref)

    @pl.when(j == 0)
    def _acc8():
        o8_ref[...] += jnp.dot(x, w8_ref[...].astype(jnp.float32),
                               preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(j == 0, k == nk - 1))
    def _rescale8():
        o8_ref[...] *= s_ref[0, 1]   # α / (γ_w γ_x)


def decoupled_matmul(x_q: jax.Array, w1_q: jax.Array, w8_q: jax.Array,
                     scale1: jax.Array, scale8: jax.Array):
    """Fused dual-branch matmul.

    Args:
      x_q:    [M, K] INT8 activations (f32 carrier).
      w1_q:   [K, N1] ±1 weights of the 1-bit branch.
      w8_q:   [K, N8] INT8 weights of the high-precision branch, N8 = r.
      scale1: fused scalar for the 1-bit branch output (β·λ/γ).
      scale8: fused scalar for the 8-bit branch output (α/(γ_w·γ_x)).

    Returns:
      (y1 [M, N1], y8 [M, N8]) f32 — the caller concatenates or sums the
      branch outputs per eq. 11.
    """
    m, k = x_q.shape
    k1, n1 = w1_q.shape
    k8, n8 = w8_q.shape
    assert k == k1 == k8, f"contraction mismatch {k}/{k1}/{k8}"
    bm = choose_block(m, TARGET_BM)
    bk = choose_block(k, TARGET_BK)
    bn1 = choose_block(n1, TARGET_BN)
    grid = (m // bm, n1 // bn1, k // bk)   # k innermost
    nk = k // bk

    scales = jnp.stack([jnp.asarray(scale1, jnp.float32).reshape(()),
                        jnp.asarray(scale8, jnp.float32).reshape(())]).reshape(1, 2)

    return pl.pallas_call(
        functools.partial(_decoupled_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn1), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, n8), lambda i, j, kk: (kk, 0)),
            pl.BlockSpec((1, 2), lambda i, j, kk: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn1), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, n8), lambda i, j, kk: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n1), jnp.float32),
            jax.ShapeDtypeStruct((m, n8), jnp.float32),
        ],
        interpret=INTERPRET,
    )(x_q.astype(jnp.float32), w1_q.astype(jnp.float32),
      w8_q.astype(jnp.float32), scales)
