"""Top-1 expert router Pallas kernel (paper §3.3).

The router is "a simple linear layer" with softmax gating and top-1
selection (Shazeer et al., 2017).  The kernel computes, per token:

    logits = x @ W_r          [M, N_experts]
    probs  = softmax(logits)
    idx    = argmax(probs)    (int32)
    gate   = probs[idx]       (the top-1 softmax weight)

N_experts ≤ 8 in every paper config, so the expert dim is always whole per
block; tiling is over tokens.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, choose_block, TARGET_BM


def _router_kernel(x_ref, w_ref, idx_ref, gate_ref):
    x = x_ref[...].astype(jnp.float32)
    logits = jnp.dot(x, w_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    # Numerically stable softmax.
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(z)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    idx = jnp.argmax(probs, axis=-1).astype(jnp.int32)
    idx_ref[...] = idx
    gate_ref[...] = jnp.max(probs, axis=-1)


def router_top1(x: jax.Array, w_router: jax.Array):
    """Top-1 gate. x: [M, D], w_router: [D, N]. Returns (idx i32[M], gate f32[M])."""
    m, d = x.shape
    d2, n = w_router.shape
    assert d == d2
    bm = choose_block(m, TARGET_BM)
    return pl.pallas_call(
        _router_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(x.astype(jnp.float32), w_router.astype(jnp.float32))


def router_probs(x: jax.Array, w_router: jax.Array) -> jax.Array:
    """Dense softmax router probabilities (used by the differentiable
    training path, where the one-hot top-1 mask is applied with STE)."""
    logits = x @ w_router
    return jax.nn.softmax(logits, axis=-1)
