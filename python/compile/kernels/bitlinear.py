"""W1A8 / W8A8 tiled matmul Pallas kernels (paper §3.1, eq. 10).

The hot spot of every pQuant linear layer is

    Y = (λ / γ) · W_q X_q

where ``W_q`` is the quantized weight (±1 for the 1-bit branch, INT8 for
the high-precision branch), ``X_q`` the per-token INT8 activations and the
scalar scales are fused into a single rescale applied to the f32
accumulator.  On a real TPU the quantized operands would live in VMEM as
(u)int8 tiles feeding the MXU via bf16 upcast; under interpret=True we keep
the integers in f32 carriers, which preserves exact integer arithmetic for
|values| < 2^24.

The kernel is a classic 3-level tiled matmul: grid (M/bm, N/bn, K/bk) with
the K dimension innermost so each (i, j) output tile is accumulated across
sequential k steps (TPU grids execute sequentially, matching interpret
mode).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, matmul_grid


def _matmul_kernel(x_ref, w_ref, scale_ref, o_ref, *, nk: int):
    """One (bm × bn) output tile, accumulated over the k grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] += acc

    # Apply the fused dequantization scale exactly once, on the last k step.
    @pl.when(k == nk - 1)
    def _rescale():
        o_ref[...] *= scale_ref[0, 0]


def quantized_matmul(x_q: jax.Array, w_q: jax.Array, scale: jax.Array) -> jax.Array:
    """``scale · (x_q @ w_q)`` with f32 accumulation.

    Args:
      x_q:   [M, K] quantized activations (integer values in an f32 carrier).
      w_q:   [K, N] quantized weights (±1 or INT8 values, f32 carrier).
      scale: scalar fused dequantization factor (λ/γ or 1/(γ_w·γ_x)).

    Returns:
      [M, N] f32.
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    grid, (bm, bk, bn) = matmul_grid(m, k, n)
    nk = grid[2]

    scale2d = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(x_q.astype(jnp.float32), w_q.astype(jnp.float32), scale2d)


def w1a8_matmul(x_q: jax.Array, w_q: jax.Array, lam: jax.Array, gamma_inv: jax.Array) -> jax.Array:
    """1-bit branch matmul: ``(λ · γ⁻¹) · (x_q @ sign_weights)`` (eq. 10).

    ``gamma_inv`` is the mean reciprocal activation scale when a single
    fused scalar is used; per-token γ is applied by the caller when
    row-exact dequantization is needed (the L2 model applies per-token γ
    outside and passes ``gamma_inv = 1``).
    """
    return quantized_matmul(x_q, w_q, lam * gamma_inv)


def w8a8_matmul(x_q: jax.Array, w_q: jax.Array, gamma_w_inv: jax.Array,
                gamma_x_inv: jax.Array) -> jax.Array:
    """8-bit branch matmul: ``(x_q @ w_q) / (γ_w γ_x)`` per-tensor scales."""
    return quantized_matmul(x_q, w_q, gamma_w_inv * gamma_x_inv)
