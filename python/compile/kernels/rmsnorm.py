"""RMSNorm Pallas kernel (Appendix B: compresses activation dynamic range).

Row-tiled: each grid step normalizes a [bm, D] block.  The feature dim is
kept whole per block — RMSNorm is a per-row reduction, and D_model for
every paper config fits VMEM trivially (D ≤ 2880 → ≤ 11.5 KiB/row f32).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, choose_block, TARGET_BM

RMS_EPS = 1e-5


def _rmsnorm_kernel(x_ref, g_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + RMS_EPS) * g_ref[...]


def _rmsnorm_jnp(x, gain):
    """Plain-jnp RMSNorm used to derive the backward pass (the Pallas call
    itself has no reverse-mode rule)."""
    x = x.astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + RMS_EPS) * gain


@jax.custom_vjp
def rmsnorm(x: jax.Array, gain: jax.Array) -> jax.Array:
    """``x / rms(x) * gain`` over the last dim; x: [M, D], gain: [D].

    Forward runs the tiled Pallas kernel; backward is the analytic VJP of
    the plain-jnp expression (identical math).
    """
    return _rmsnorm_pallas(x, gain)


def _rmsnorm_fwd(x, gain):
    return _rmsnorm_pallas(x, gain), (x, gain)


def _rmsnorm_bwd(res, g):
    x, gain = res
    _, vjp = jax.vjp(_rmsnorm_jnp, x, gain)
    return vjp(g)


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def _rmsnorm_pallas(x: jax.Array, gain: jax.Array) -> jax.Array:
    m, d = x.shape
    bm = choose_block(m, TARGET_BM)
    return pl.pallas_call(
        _rmsnorm_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=INTERPRET,
    )(x.astype(jnp.float32), gain.reshape(1, d).astype(jnp.float32))
