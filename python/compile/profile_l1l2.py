"""L1/L2 performance profile (EXPERIMENTS.md §Perf).

L1: structural VMEM/MXU analysis of each Pallas kernel's BlockSpec at the
paper's 7B shapes and at our testbed shapes. interpret=True gives no
meaningful wallclock, so the optimization target is structural: block
working set within the ~16 MiB/core VMEM budget, last dim a multiple of
the 128-lane width, K-innermost accumulation feeding the MXU.

L2: op-census of the lowered HLO text per artifact — fusion counts,
convert/quantize chains, dot counts — to verify no redundant
quantize-dequantize pairs survive lowering.

Usage: cd python && python -m compile.profile_l1l2
"""

import json
import os
import re
import sys

from .kernels.common import matmul_grid, vmem_bytes, choose_block, TARGET_BM

MXU = (128, 128)  # systolic array tile
VMEM_BUDGET = 16 * 1024 * 1024


def l1_profile():
    rows = []
    # (name, m, k, n) — decode GEMV and train-matmul shapes
    shapes = [
        ("7B attn proj (decode)", 1, 4096, 4096),
        ("7B ffn up (decode)", 1, 4096, 11008),
        ("7B ffn up (train b8xs2048)", 8 * 2048, 4096, 11008),
        ("micro attn (train b8xs128)", 8 * 129, 128, 128),
        ("micro ffn up (train)", 8 * 129, 128, 336),
        ("tiny ffn up (train)", 8 * 129, 256, 672),
    ]
    for name, m, k, n in shapes:
        grid, (bm, bk, bn) = matmul_grid(m, k, n)
        vmem = vmem_bytes(((bm, bk), "float32"), ((bk, bn), "float32"),
                          ((bm, bn), "float32"), ((1, 1), "float32"))
        # MXU utilization estimate: fraction of the 128x128 tile the block
        # shapes fill (bm and bn lanes; bk streams through).
        mxu_util = min(bm, MXU[0]) * min(bn, MXU[1]) / (MXU[0] * MXU[1])
        rows.append({
            "kernel": "quantized_matmul",
            "shape": name,
            "grid": list(grid),
            "block": [bm, bk, bn],
            "vmem_bytes": vmem,
            "vmem_frac": vmem / VMEM_BUDGET,
            "mxu_tile_util": mxu_util,
        })
    return rows


def l2_profile(artifacts_dir):
    rows = []
    if not os.path.isdir(artifacts_dir):
        return rows
    for cfg in sorted(os.listdir(artifacts_dir)):
        hlo_path = os.path.join(artifacts_dir, cfg, "train_step.hlo.txt")
        if not os.path.exists(hlo_path):
            continue
        text = open(hlo_path).read()
        ops = re.findall(r"= \w+\[[^\]]*\][^ ]* (\w+)\(", text)
        from collections import Counter
        census = Counter(ops)
        rows.append({
            "config": cfg,
            "hlo_bytes": len(text),
            "dot": census.get("dot", 0),
            "while": census.get("while", 0),
            "fusion": census.get("fusion", 0),
            "convert": census.get("convert", 0),
            "round": census.get("round-nearest-afz", 0) + census.get("round-nearest-even", 0),
            "total_ops": sum(census.values()),
        })
    return rows


def main():
    out = {"l1": l1_profile(), "l2": l2_profile("../artifacts")}
    os.makedirs("../results", exist_ok=True)
    path = "../results/l1l2_profile.json"
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    print("\nL1 kernel structural profile:")
    print(f"{'shape':36} {'grid':>14} {'block (m,k,n)':>16} {'VMEM':>10} {'MXU':>6}")
    for r in out["l1"]:
        print(f"{r['shape']:36} {str(r['grid']):>14} {str(r['block']):>16} "
              f"{r['vmem_bytes']/1024:>8.0f}Ki {r['mxu_tile_util']:>6.2f}")
    print("\nL2 HLO census (train_step):")
    print(f"{'config':24} {'bytes':>10} {'dots':>6} {'while':>6} {'convert':>8} {'ops':>7}")
    for r in out["l2"]:
        print(f"{r['config']:24} {r['hlo_bytes']:>10} {r['dot']:>6} "
              f"{r['while']:>6} {r['convert']:>8} {r['total_ops']:>7}")


if __name__ == "__main__":
    main()
