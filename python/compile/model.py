"""L2: the pQuant transformer family in JAX, calling the L1 Pallas kernels.

Four variants share one code path (configs.VARIANTS):

  fp16      - full-precision LLaMA-style baseline (f32 on this testbed)
  bitnet    - every linear 1-bit sign/absmean, W1A8 (Wang et al., 2023)
  bitnet158 - every linear ternary absmean, W1.58A8 (Ma et al., 2024b)
  pquant    - MHA pure 1-bit (sec 3.1); FFN decoupled: wide 1-bit branch +
              N sparsely-activated INT8 expert branches with feature
              scaling alpha/beta and a top-1 softmax router (sec 3.2-3.3)

Quantized linears execute the L1 Pallas kernels on *integer carriers* in
the forward pass (the exact arithmetic the rust inference engine performs)
and use the standard simulated-QAT straight-through gradient in the
backward pass, wired up with ``jax.custom_vjp`` (Appendix B.1).

Architecture: decoder-only, pre-RMSNorm, RoPE attention, SiLU FFN,
untied full-precision embedding + head (the paper keeps embeddings and
norms high-precision - Table 3 counts them in the memory footprint).
"""

from functools import partial

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from . import kernels
from .kernels import quantize as qz


# ---------------------------------------------------------------------------
# Quantized linear primitives (custom_vjp around the Pallas kernels)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def binary_linear(x, w):
    """1-bit W1A8 linear (sec 3.1, eq. 10): y = (lambda/gamma) W_q Q(x).

    x: [M, K] f32 (already normalized), w: [K, N] latent f32 weights.
    Forward runs the Pallas integer matmul; backward is the simulated-QAT
    STE gradient using the dequantized operands.
    """
    y, _ = _binary_linear_fwd(x, w)
    return y


def _binary_linear_fwd(x, w):
    x_q, gamma = qz.absmax_quantize(x)            # per-token INT8
    w_q, lam = qz.binarize_weight(w)              # +-1 + per-tensor lambda
    y = kernels.quantized_matmul(x_q, w_q, 1.0) * (lam / gamma)
    # residuals: dequantized operands for the STE backward
    return y, (x_q / gamma, w_q * lam)


def _binary_linear_bwd(res, g):
    x_hat, w_hat = res
    return g @ w_hat.T, x_hat.T @ g


binary_linear.defvjp(_binary_linear_fwd, _binary_linear_bwd)


@jax.custom_vjp
def ternary_linear(x, w):
    """W1.58A8 linear (BitNet1.58 baseline): y = (s/gamma) W_t Q(x)."""
    y, _ = _ternary_linear_fwd(x, w)
    return y


def _ternary_linear_fwd(x, w):
    x_q, gamma = qz.absmax_quantize(x)
    w_q, scale = qz.ternarize_weight(w)
    y = kernels.quantized_matmul(x_q, w_q, 1.0) * (scale / gamma)
    return y, (x_q / gamma, w_q * scale)


def _ternary_linear_bwd(res, g):
    x_hat, w_hat = res
    return g @ w_hat.T, x_hat.T @ g


ternary_linear.defvjp(_ternary_linear_fwd, _ternary_linear_bwd)


@jax.custom_vjp
def int8_linear(x, w):
    """W8A8 linear for the high-precision branch (sec 3.2): per-tensor INT8
    weights, per-token INT8 activations, exact integer matmul."""
    y, _ = _int8_linear_fwd(x, w)
    return y


def _int8_linear_fwd(x, w):
    x_q, gamma_x = qz.absmax_quantize(x)
    w_q, gamma_w = qz.absmax_quantize_per_tensor(w)
    y = kernels.quantized_matmul(x_q, w_q, 1.0 / gamma_w) / gamma_x
    return y, (x_q / gamma_x, w_q / gamma_w)


def _int8_linear_bwd(res, g):
    x_hat, w_hat = res
    return g @ w_hat.T, x_hat.T @ g


int8_linear.defvjp(_int8_linear_fwd, _int8_linear_bwd)


def fp_linear(x, w):
    """Full-precision linear (fp16 baseline)."""
    return x @ w


LINEAR_FOR_VARIANT = {
    "fp16": fp_linear,
    "bitnet": binary_linear,
    "bitnet158": ternary_linear,
    # pquant MHA is pure 1-bit (sec 3.1); its FFN is handled separately
    "pquant": binary_linear,
}


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array):
    """Random initialization (QAT-from-scratch: no pre-trained weights).

    Returns a nested dict pytree.  Layout must stay in sync with
    ``aot.py``'s manifest emission (it flattens with tree_flatten_with_path,
    which sorts dict keys - names are chosen so that order is stable).
    """
    d, v = cfg.d_model, cfg.vocab

    def dense(key, fan_in, shape):
        return jax.random.normal(key, shape, jnp.float32) * (fan_in ** -0.5)

    n_keys = 4 + cfg.n_layers * 16
    keys = iter(jax.random.split(key, n_keys))

    params = {
        "tok_embed": jax.random.normal(next(keys), (v, d), jnp.float32) * 0.02,
        "lm_head": dense(next(keys), d, (d, v)),
        "final_norm": jnp.ones((d,), jnp.float32),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        layer = {
            "attn_norm": jnp.ones((d,), jnp.float32),
            "ffn_norm": jnp.ones((d,), jnp.float32),
            "wq": dense(next(keys), d, (d, d)),
            "wk": dense(next(keys), d, (d, d)),
            "wv": dense(next(keys), d, (d, d)),
            "wo": dense(next(keys), d, (d, d)),
        }
        if cfg.variant == "pquant":
            n1 = cfg.d_ff_1bit
            layer.update({
                "ffn_up_1bit": dense(next(keys), d, (d, n1)),
                "ffn_down_1bit": dense(next(keys), n1, (n1, d)),
                # N expert branches, stacked on a leading axis
                "ffn_up_8bit": dense(next(keys), d, (cfg.n_experts, d, cfg.r)),
                "ffn_down_8bit": dense(next(keys), cfg.r, (cfg.n_experts, cfg.r, d)),
                "router": dense(next(keys), d, (d, cfg.n_experts)),
                # feature scaling (sec 3.2): alpha >> beta at init steers
                # sensitive parameters into the high-precision pathway
                "alpha": jnp.asarray(cfg.alpha_init, jnp.float32),
                "beta": jnp.asarray(cfg.beta_init, jnp.float32),
            })
        else:
            layer.update({
                "ffn_up": dense(next(keys), d, (d, cfg.d_ff)),
                "ffn_down": dense(next(keys), cfg.d_ff, (cfg.d_ff, d)),
            })
        params["layers"].append(layer)
    return params


# ---------------------------------------------------------------------------
# RoPE + attention
# ---------------------------------------------------------------------------

def rope_tables(seq_len: int, head_dim: int):
    """Rotary position-embedding cos/sin tables [T, head_dim/2]."""
    half = head_dim // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = jnp.arange(seq_len, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, cos, sin):
    """x: [B, T, H, Dh] -> rotated. Tables broadcast over batch and heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def attention(cfg: ModelConfig, layer, x, linear):
    """Pre-norm multi-head attention; all four projections quantized per
    variant (pQuant MHA: pure 1-bit, sec 3.1)."""
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    xn = kernels.rmsnorm(x.reshape(b * t, d), layer["attn_norm"]).reshape(b, t, d)

    flat = xn.reshape(b * t, d)
    q = linear(flat, layer["wq"]).reshape(b, t, h, hd)
    k = linear(flat, layer["wk"]).reshape(b, t, h, hd)
    v = linear(flat, layer["wv"]).reshape(b, t, h, hd)

    cos, sin = rope_tables(t, hd)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    scores = jnp.einsum("bthd,bshd->bhts", q, k) / (hd ** 0.5)
    causal = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(causal[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhts,bshd->bthd", probs, v).reshape(b * t, d)
    return x + linear(ctx, layer["wo"]).reshape(b, t, d)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def ffn_dense(cfg: ModelConfig, layer, x, linear):
    """Standard 2-matrix FFN for the fp16/bitnet/bitnet158 variants."""
    b, t, d = x.shape
    xn = kernels.rmsnorm(x.reshape(b * t, d), layer["ffn_norm"])
    h = jax.nn.silu(linear(xn, layer["ffn_up"]))
    return x + linear(h, layer["ffn_down"]).reshape(b, t, d)


def ffn_decoupled(cfg: ModelConfig, layer, x):
    """pQuant decoupled FFN (sec 3.2-3.3, eq. 11).

    y = beta*FFN_1bit(xn) + alpha*gate*FFN_8bit[e*](xn), e* = top-1 choice.

    During training all N experts are computed densely and combined with a
    one-hot top-1 mask (gradients reach the router through the gate
    probability, Switch-transformer style); the rust inference engine
    activates only the selected expert.
    """
    b, t, d = x.shape
    xn = kernels.rmsnorm(x.reshape(b * t, d), layer["ffn_norm"])

    # 1-bit branch
    h1 = jax.nn.silu(binary_linear(xn, layer["ffn_up_1bit"]))
    y1 = binary_linear(h1, layer["ffn_down_1bit"])

    # 8-bit expert branches with top-1 gating
    n_exp = cfg.n_experts
    if n_exp == 1:
        h8 = jax.nn.silu(int8_linear(xn, layer["ffn_up_8bit"][0]))
        y8 = int8_linear(h8, layer["ffn_down_8bit"][0])
    else:
        probs = kernels.router_probs(xn, layer["router"])        # [M, N]
        top = jnp.argmax(probs, axis=-1)                         # [M]
        mask = jax.nn.one_hot(top, n_exp, dtype=xn.dtype)        # [M, N]
        gate = jnp.sum(probs * mask, axis=-1, keepdims=True)     # [M, 1]
        expert_outs = []
        for e in range(n_exp):
            h8 = jax.nn.silu(int8_linear(xn, layer["ffn_up_8bit"][e]))
            expert_outs.append(int8_linear(h8, layer["ffn_down_8bit"][e]))
        stacked = jnp.stack(expert_outs, axis=1)                 # [M, N, D]
        y8 = jnp.sum(stacked * mask[..., None], axis=1) * gate   # [M, D]

    y = layer["beta"] * y1 + layer["alpha"] * y8
    return x + y.reshape(b, t, d)


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params, tokens, return_ffn_input: bool = False):
    """Logits for next-token prediction.

    tokens: i32 [B, T].  Returns logits f32 [B, T, V]; with
    ``return_ffn_input`` also the final block's normalized FFN input
    [B*T, D] (the calibration activations for the sensitivity analysis,
    Fig 2 / Fig 5a).
    """
    linear = LINEAR_FOR_VARIANT[cfg.variant]
    x = params["tok_embed"][tokens]          # [B, T, D] full precision
    last_ffn_input = None
    for li, layer in enumerate(params["layers"]):
        x = attention(cfg, layer, x, linear)
        if li == cfg.n_layers - 1 and return_ffn_input:
            b, t, d = x.shape
            last_ffn_input = kernels.rmsnorm(
                x.reshape(b * t, d), layer["ffn_norm"])
        if cfg.variant == "pquant":
            x = ffn_decoupled(cfg, layer, x)
        else:
            x = ffn_dense(cfg, layer, x, linear)
    b, t, d = x.shape
    x = kernels.rmsnorm(x.reshape(b * t, d), params["final_norm"])
    logits = (x @ params["lm_head"]).reshape(b, t, cfg.vocab)
    if return_ffn_input:
        return logits, last_ffn_input
    return logits


def loss_fn(cfg: ModelConfig, params, tokens):
    """Mean next-token cross-entropy.  tokens: i32 [B, T+1]."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
