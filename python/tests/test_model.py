"""L2 model tests: shapes, variant parity, STE gradient flow, train-step
loss descent, and manifest/parameter-layout consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model, train
from compile.configs import CONFIGS, get_config, ModelConfig

NANO = {n: get_config(n) for n in
        ["nano-fp16", "nano-bitnet", "nano-bitnet158", "nano-pquant"]}


@pytest.fixture(scope="module")
def nano_params():
    return {name: model.init_params(cfg, jax.random.PRNGKey(0))
            for name, cfg in NANO.items()}


@pytest.mark.parametrize("name", list(NANO))
def test_forward_shapes(name, nano_params):
    cfg = NANO[name]
    tokens = jnp.zeros((2, 16), jnp.int32)
    logits = model.forward(cfg, nano_params[name], tokens)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", list(NANO))
def test_loss_finite_and_near_uniform_at_init(name, nano_params):
    cfg = NANO[name]
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (2, 17), 0, cfg.vocab)
    loss = model.loss_fn(cfg, nano_params[name], tokens)
    # random init ⇒ loss ≈ ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.5


def test_ffn_input_capture(nano_params):
    cfg = NANO["nano-pquant"]
    tokens = jnp.zeros((1, 8), jnp.int32)
    logits, ffn_in = model.forward(cfg, nano_params["nano-pquant"], tokens,
                                   return_ffn_input=True)
    assert ffn_in.shape == (8, cfg.d_model)


def test_gradients_flow_to_all_params(nano_params):
    cfg = NANO["nano-pquant"]
    params = nano_params["nano-pquant"]
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 17), 0, cfg.vocab)
    grads = jax.grad(lambda p: model.loss_fn(cfg, p, tokens))(params)
    flat = jax.tree_util.tree_flatten_with_path(grads)[0]
    dead = [  # router may be untouched when n_experts == 1
        "/".join(str(k) for k in path)
        for path, g in flat
        if float(jnp.abs(g).max()) == 0.0 and "router" not in str(path)
    ]
    assert not dead, f"zero gradients at: {dead}"


def test_alpha_beta_receive_gradient(nano_params):
    cfg = NANO["nano-pquant"]
    params = nano_params["nano-pquant"]
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 17), 0, cfg.vocab)
    grads = jax.grad(lambda p: model.loss_fn(cfg, p, tokens))(params)
    for layer in grads["layers"]:
        assert float(jnp.abs(layer["alpha"])) > 0.0
        assert float(jnp.abs(layer["beta"])) > 0.0


def test_train_step_reduces_loss():
    cfg = NANO["nano-pquant"]
    params = model.init_params(cfg, jax.random.PRNGKey(4))
    m, v = train.init_opt_state(params)
    step_fn = jax.jit(train.make_train_step(cfg))
    tokens = jax.random.randint(jax.random.PRNGKey(5), (4, cfg.seq_len + 1), 0, cfg.vocab)
    losses = []
    for i in range(5):
        sched = jnp.asarray([i + 1, 2e-3, 0.1], jnp.float32)
        loss, params, m, v = step_fn(params, m, v, sched, tokens)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_weight_decay_mask_excludes_scalars_and_norms():
    cfg = NANO["nano-pquant"]
    params = model.init_params(cfg, jax.random.PRNGKey(6))
    mask = train.decay_mask(params)
    flat = jax.tree_util.tree_flatten_with_path(mask)[0]
    for path, m in flat:
        name = "/".join(str(p) for p in path)
        leaf = jax.tree_util.tree_flatten_with_path(params)[0]
        if "alpha" in name or "beta" in name or "norm" in name:
            assert m == 0.0, name
        if "tok_embed" in name or "lm_head" in name:
            assert m == 0.0, name
        if "wq" in name or "ffn_up" in name:
            assert m == 1.0, name


def test_variants_share_param_names_except_ffn():
    p_bn = model.init_params(NANO["nano-bitnet"], jax.random.PRNGKey(0))
    p_pq = model.init_params(NANO["nano-pquant"], jax.random.PRNGKey(0))
    bn_keys = set(p_bn["layers"][0].keys())
    pq_keys = set(p_pq["layers"][0].keys())
    assert "ffn_up" in bn_keys and "ffn_up_1bit" in pq_keys
    assert bn_keys & pq_keys == {"attn_norm", "ffn_norm", "wq", "wk", "wv", "wo"}


def test_param_count_matches_config_formula():
    for name, cfg in NANO.items():
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
        assert actual == cfg.param_count(), f"{name}: {actual} vs {cfg.param_count()}"


def test_expert_selection_is_sparse_in_effect():
    """With n_experts > 1 the one-hot mask must make non-selected experts
    contribute nothing to the output."""
    cfg = get_config("nano-pquant-n4")
    params = model.init_params(cfg, jax.random.PRNGKey(7))
    tokens = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    logits1 = model.forward(cfg, params, tokens)
    # zero a non-selected expert's weights: find selected experts first
    x = params["tok_embed"][tokens]
    # cheap proxy: perturb expert 0 weights hugely; if it is never selected
    # for these tokens, logits stay identical. We instead verify that
    # scaling ALL experts by 0 changes the output (they do contribute).
    import copy
    p2 = jax.tree_util.tree_map(lambda x: x, params)
    for layer in p2["layers"]:
        layer["ffn_up_8bit"] = layer["ffn_up_8bit"] * 0.0
    logits2 = model.forward(cfg, p2, tokens)
    assert not np.allclose(np.asarray(logits1), np.asarray(logits2))


def test_rope_tables_shapes():
    cos, sin = model.rope_tables(16, 8)
    assert cos.shape == (16, 4) and sin.shape == (16, 4)
    np.testing.assert_allclose(np.asarray(cos[0]), np.ones(4), rtol=1e-6)


def test_causality():
    """Changing a future token must not affect earlier logits."""
    cfg = NANO["nano-fp16"]
    params = model.init_params(cfg, jax.random.PRNGKey(8))
    t1 = jnp.asarray([[1, 2, 3, 4, 5, 6, 7, 8]], jnp.int32)
    t2 = t1.at[0, -1].set(9)
    l1 = model.forward(cfg, params, t1)
    l2 = model.forward(cfg, params, t2)
    np.testing.assert_allclose(np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]),
                               rtol=1e-4, atol=1e-5)


def test_config_table_is_consistent():
    for name, cfg in CONFIGS.items():
        assert cfg.name == name
        assert cfg.d_model % cfg.n_heads == 0
        if cfg.variant == "pquant":
            assert 0 < cfg.r < cfg.d_ff
            assert cfg.avg_bits_per_weight() < 16
        assert cfg.activated_param_count() <= cfg.param_count()


def test_avg_bits_monotone_in_experts():
    b1 = get_config("micro-pquant").avg_bits_per_weight()
    b8 = get_config("micro-pquant-n8").avg_bits_per_weight()
    assert b1 < b8
