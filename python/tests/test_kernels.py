"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes and value regimes; every kernel must match its
oracle to float32 tolerance (the integer-carrier matmuls must match to
rtol 1e-6 — they are exact integer sums below 2^24).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    quantized_matmul, decoupled_matmul, rmsnorm, router_top1, ref, quantize,
)
from compile.kernels.common import choose_block, matmul_grid, vmem_bytes

DIMS = st.sampled_from([1, 2, 3, 4, 7, 8, 16, 24, 48, 96, 128, 160])
SMALL_DIMS = st.sampled_from([1, 2, 4, 8, 16, 32])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)

HSET = settings(max_examples=8, deadline=None)


def _rand_int8(key, shape):
    return jax.random.randint(key, shape, -127, 128).astype(jnp.float32)


def _rand_sign(key, shape):
    return jnp.where(jax.random.normal(key, shape) >= 0, 1.0, -1.0)


# ---------------------------------------------------------------------------
# quantized_matmul
# ---------------------------------------------------------------------------

@HSET
@given(m=DIMS, k=DIMS, n=DIMS, seed=SEEDS,
       scale=st.floats(min_value=1e-4, max_value=10.0))
def test_quantized_matmul_matches_ref(m, k, n, seed, scale):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = _rand_int8(k1, (m, k))
    w = _rand_sign(k2, (k, n))
    got = quantized_matmul(x, w, scale)
    want = ref.quantized_matmul_ref(x, w, scale)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_quantized_matmul_int8_weights_exact():
    key = jax.random.PRNGKey(7)
    x = _rand_int8(key, (33, 65))
    w = _rand_int8(jax.random.PRNGKey(8), (65, 17))
    got = quantized_matmul(x, w, 1.0)
    want = ref.quantized_matmul_ref(x, w, 1.0)
    # pure integer arithmetic: exact
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantized_matmul_zero_scale():
    x = jnp.ones((4, 4))
    w = jnp.ones((4, 4))
    assert float(jnp.abs(quantized_matmul(x, w, 0.0)).max()) == 0.0


def test_quantized_matmul_shape_mismatch_raises():
    with pytest.raises(AssertionError):
        quantized_matmul(jnp.ones((4, 5)), jnp.ones((6, 4)), 1.0)


# ---------------------------------------------------------------------------
# decoupled_matmul (the fused dual-branch kernel)
# ---------------------------------------------------------------------------

@HSET
@given(m=DIMS, k=DIMS, n1=DIMS, r=SMALL_DIMS, seed=SEEDS)
def test_decoupled_matmul_matches_ref(m, k, n1, r, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand_int8(keys[0], (m, k))
    w1 = _rand_sign(keys[1], (k, n1))
    w8 = _rand_int8(keys[2], (k, r))
    got1, got8 = decoupled_matmul(x, w1, w8, 0.2, 2.0)
    want1, want8 = ref.decoupled_matmul_ref(x, w1, w8, 0.2, 2.0)
    np.testing.assert_allclose(got1, want1, rtol=1e-6)
    np.testing.assert_allclose(got8, want8, rtol=1e-6)


def test_decoupled_matmul_branch_independence():
    """Zeroing one branch's weights must not change the other's output."""
    key = jax.random.PRNGKey(3)
    x = _rand_int8(key, (16, 32))
    w1 = _rand_sign(jax.random.PRNGKey(4), (32, 48))
    w8 = _rand_int8(jax.random.PRNGKey(5), (32, 8))
    y1a, _ = decoupled_matmul(x, w1, w8, 1.0, 1.0)
    y1b, _ = decoupled_matmul(x, w1, jnp.zeros_like(w8), 1.0, 1.0)
    np.testing.assert_array_equal(np.asarray(y1a), np.asarray(y1b))


def test_decoupled_matmul_scales_apply_once():
    """With scale=2 the output must be exactly 2× the scale=1 output —
    catches double-rescaling across grid steps."""
    key = jax.random.PRNGKey(11)
    x = _rand_int8(key, (32, 128))   # forces multiple k and j tiles
    w1 = _rand_sign(jax.random.PRNGKey(12), (128, 160))
    w8 = _rand_int8(jax.random.PRNGKey(13), (128, 16))
    y1a, y8a = decoupled_matmul(x, w1, w8, 1.0, 1.0)
    y1b, y8b = decoupled_matmul(x, w1, w8, 2.0, 3.0)
    np.testing.assert_allclose(np.asarray(y1b), 2 * np.asarray(y1a), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(y8b), 3 * np.asarray(y8a), rtol=1e-6)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@HSET
@given(m=DIMS, d=DIMS, seed=SEEDS)
def test_rmsnorm_matches_ref(m, d, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(keys[0], (m, d)) * 3.0
    g = jax.random.normal(keys[1], (d,))
    np.testing.assert_allclose(rmsnorm(x, g), ref.rmsnorm_ref(x, g),
                               rtol=1e-5, atol=1e-6)


def test_rmsnorm_unit_rows():
    """Unit-gain RMSNorm output rows have RMS ≈ 1."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 10
    y = np.asarray(rmsnorm(x, jnp.ones(64)))
    rms = np.sqrt((y ** 2).mean(axis=-1))
    np.testing.assert_allclose(rms, np.ones(8), rtol=1e-3)


def test_rmsnorm_scale_invariance():
    """RMSNorm(c·x) == RMSNorm(x) for c > 0 (dynamic-range compression —
    the property Appendix B relies on)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    g = jnp.ones(32)
    a = np.asarray(rmsnorm(x, g))
    b = np.asarray(rmsnorm(x * 100.0, g))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------

@HSET
@given(m=DIMS, d=DIMS, n=st.sampled_from([1, 2, 4, 8]), seed=SEEDS)
def test_router_matches_ref(m, d, n, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(keys[0], (m, d))
    w = jax.random.normal(keys[1], (d, n))
    gi, gg = router_top1(x, w)
    ri, rg = ref.router_top1_ref(x, w)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))
    np.testing.assert_allclose(gg, rg, rtol=1e-5)


def test_router_gate_bounds():
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
    w = jax.random.normal(jax.random.PRNGKey(3), (32, 8))
    idx, gate = router_top1(x, w)
    idx, gate = np.asarray(idx), np.asarray(gate)
    assert ((idx >= 0) & (idx < 8)).all()
    # top-1 softmax over 8 experts is at least 1/8 and at most 1
    assert (gate >= 1.0 / 8 - 1e-6).all() and (gate <= 1.0 + 1e-6).all()


# ---------------------------------------------------------------------------
# quantizers
# ---------------------------------------------------------------------------

@HSET
@given(m=DIMS, n=DIMS, seed=SEEDS, scale=st.floats(min_value=0.01, max_value=100.0))
def test_binarize_matches_ref(m, n, seed, scale):
    w = jax.random.normal(jax.random.PRNGKey(seed), (m, n)) * scale
    wq, lam = quantize.binarize_weight(w)
    rq, rlam = ref.binarize_ref(w)
    np.testing.assert_array_equal(np.asarray(wq), np.asarray(rq))
    np.testing.assert_allclose(float(lam), float(rlam), rtol=1e-5)
    assert set(np.unique(np.asarray(wq))) <= {-1.0, 1.0}


@HSET
@given(m=DIMS, n=DIMS, seed=SEEDS)
def test_ternarize_matches_ref(m, n, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (m, n))
    wq, s = quantize.ternarize_weight(w)
    rq, rs = ref.ternarize_ref(w)
    np.testing.assert_array_equal(np.asarray(wq), np.asarray(rq))
    assert set(np.unique(np.asarray(wq))) <= {-1.0, 0.0, 1.0}


@HSET
@given(m=DIMS, n=DIMS, seed=SEEDS, scale=st.floats(min_value=0.01, max_value=1000.0))
def test_absmax_matches_ref(m, n, seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, n)) * scale
    xq, g = quantize.absmax_quantize(x)
    rq, rg = ref.absmax_ref(x)
    np.testing.assert_array_equal(np.asarray(xq), np.asarray(rq))
    np.testing.assert_allclose(np.asarray(g), np.asarray(rg), rtol=1e-5)
    assert np.abs(np.asarray(xq)).max() <= 127


def test_absmax_integers():
    x = jax.random.normal(jax.random.PRNGKey(9), (16, 16)) * 5
    xq, _ = quantize.absmax_quantize(x)
    xq = np.asarray(xq)
    np.testing.assert_array_equal(xq, np.round(xq))


def test_absmax_zero_input():
    xq, g = quantize.absmax_quantize(jnp.zeros((4, 8)))
    assert np.abs(np.asarray(xq)).max() == 0.0
    assert np.isfinite(np.asarray(g)).all()


def test_binarize_zero_input():
    wq, lam = quantize.binarize_weight(jnp.zeros((4, 4)))
    assert np.isfinite(float(lam))
    assert set(np.unique(np.asarray(wq))) <= {-1.0, 1.0}


# STE gradient identities ----------------------------------------------------

def test_ste_gradient_is_identity():
    def f(w):
        wq, _ = quantize.binarize_weight_ste(w)
        return jnp.sum(wq)
    w = jax.random.normal(jax.random.PRNGKey(0), (8, 8))
    g = jax.grad(f)(w)
    np.testing.assert_allclose(np.asarray(g), np.ones((8, 8)), rtol=1e-6)


def test_ste_activation_gradient_is_identity():
    def f(x):
        xh, _, _ = quantize.absmax_quantize_ste(x)
        return jnp.sum(xh * 2.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    g = jax.grad(f)(x)
    np.testing.assert_allclose(np.asarray(g), 2 * np.ones((4, 16)), rtol=1e-6)


def test_ternarize_ste_gradient_is_identity():
    def f(w):
        wq = quantize.ternarize_weight_ste(w)[0]
        return jnp.sum(wq * 3.0)
    w = jax.random.normal(jax.random.PRNGKey(2), (6, 6))
    np.testing.assert_allclose(np.asarray(jax.grad(f)(w)),
                               3 * np.ones((6, 6)), rtol=1e-6)


# groupwise / channelwise ablation quantizers --------------------------------

def test_groupwise_roundtrip_shapes():
    w = jax.random.normal(jax.random.PRNGKey(4), (128, 24))
    wq, lam = quantize.binarize_weight_groupwise(w, group=64)
    assert wq.shape == (128, 24) and lam.shape == (2, 24)
    deq = quantize.dequant_groupwise(wq, lam, group=64)
    assert deq.shape == (128, 24)
    # every dequantized entry is ±λ of its group
    deq_abs = np.abs(np.asarray(deq)).reshape(2, 64, 24)
    for gi in range(2):
        np.testing.assert_allclose(deq_abs[gi], np.broadcast_to(
            np.asarray(lam)[gi], (64, 24)), rtol=1e-5)
    # groupwise error ≤ per-tensor error on *centered* weights, where both
    # quantizers share the same zero point (finer scales can only help)
    wc = w - jnp.mean(w)
    wq_g, lam_g = quantize.binarize_weight_groupwise(wc, group=64)
    wq_t, lam_t = quantize.binarize_weight(wc)
    err_g = float(jnp.mean((quantize.dequant_groupwise(wq_g, lam_g, 64) - wc) ** 2))
    err_t = float(jnp.mean((wq_t * lam_t - wc) ** 2))
    assert err_g <= err_t * 1.05 + 1e-6


def test_channelwise_scales_per_column():
    w = jnp.concatenate([jnp.ones((16, 1)) * 10.0, jnp.ones((16, 1)) * 0.1], axis=1)
    w = w * jnp.sign(jax.random.normal(jax.random.PRNGKey(5), (16, 2)))
    _, lam = quantize.binarize_weight_channelwise(w)
    assert lam.shape == (1, 2)
    assert float(lam[0, 0]) > float(lam[0, 1])


def test_groupwise_requires_divisible():
    with pytest.raises(AssertionError):
        quantize.binarize_weight_groupwise(jnp.ones((100, 4)), group=64)


# ---------------------------------------------------------------------------
# tiling helpers
# ---------------------------------------------------------------------------

@given(dim=st.integers(min_value=1, max_value=4096),
       target=st.integers(min_value=1, max_value=512))
@settings(max_examples=100, deadline=None)
def test_choose_block_divides(dim, target):
    b = choose_block(dim, target)
    assert dim % b == 0
    assert b >= 1
    if dim <= target:
        assert b == dim


def test_matmul_grid_covers():
    grid, (bm, bk, bn) = matmul_grid(96, 512, 160)
    assert grid[0] * bm == 96 and grid[2] * bk == 512 and grid[1] * bn == 160


def test_vmem_bytes():
    assert vmem_bytes(((128, 512), jnp.float32)) == 128 * 512 * 4
    assert vmem_bytes(((128, 512), jnp.int8), ((1, 1), jnp.float32)) == 128 * 512 + 4
