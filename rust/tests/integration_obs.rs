//! Integration tests for the observability core (ISSUE 8): histogram
//! quantiles property-tested against the exact nearest-rank sort, the
//! trace completeness invariant (every ticketed request lands exactly one
//! terminal span, with correct reason codes, across normal / zero-budget /
//! cancelled / preempted paths), and the HTTP surface — `/v1/metrics`
//! content negotiation (JSON vs Prometheus text) and the `/v1/trace/<id>`
//! Chrome trace-event round-trip.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use pquant::config::{ModelConfig, Variant};
use pquant::infer::PackedModel;
use pquant::kvcache::KvPoolOptions;
use pquant::obs::hist::REL_ERROR;
use pquant::obs::prom::parse_text;
use pquant::obs::trace::validate_chrome_json;
use pquant::obs::{Histogram, SpanKind};
use pquant::serve::{
    Engine, EngineOptions, Event, FinishReason, GenRequest, HttpServer, ModelRegistry,
    Percentiles, Router, SubmitError, Ticket,
};
use pquant::util::json::Json;
use pquant::util::prop::check;
use pquant::util::rng::Rng;

fn nano_cfg(name: &str) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        variant: Variant::PQuant,
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 96,
        r: 16,
        n_experts: 2,
        seq_len: 32,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

fn registry_with(name: &str, model: PackedModel) -> Arc<ModelRegistry> {
    let registry = Arc::new(ModelRegistry::new());
    registry.register(name, model, None);
    registry
}

/// Submit, absorbing KvExhausted/QueueFull backpressure (bounded by a
/// timeout so a bug fails the test instead of hanging it).
fn submit_blocking(engine: &Engine, mut req: GenRequest) -> Ticket {
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        match engine.submit(req) {
            Ok(t) => return t,
            Err(SubmitError::KvExhausted(r, _)) | Err(SubmitError::QueueFull(r, _)) => {
                assert!(Instant::now() < deadline, "admission never drained");
                req = r;
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
}

// ----------------------------------------------- histogram vs exact sort

#[test]
fn prop_histogram_quantiles_match_exact_percentiles() {
    // Across sample counts, scales, and distribution shapes (uniform,
    // low-skewed, heavy-tailed), the log-bucketed histogram's nearest-rank
    // quantile must sit within the documented bucket-width bound of the
    // exact sorted nearest-rank value computed from the same samples.
    check(
        0x0B5,
        40,
        |r| {
            let shape = r.below(3);
            let scale = [0.25f64, 3.0, 250.0, 12_000.0][r.below(4)];
            let n = 50 + r.below(1500);
            (shape, scale, n, r.next_u64())
        },
        |&(shape, scale, n, seed)| {
            let mut rng = Rng::new(seed);
            let h = Histogram::new();
            let mut samples = Vec::with_capacity(n);
            for _ in 0..n {
                let x = rng.f64();
                let v = match shape {
                    0 => x * scale,
                    1 => x * x * scale, // skewed toward zero
                    _ => scale / (1.0 - x).max(1e-4), // heavy tail
                };
                h.record(v);
                samples.push(v);
            }
            if h.count() != n as u64 {
                return Err(format!("count {} != {n}", h.count()));
            }
            let exact = Percentiles::of(&samples);
            let est = Percentiles::of_histogram(&h);
            for (q, e, v) in [
                (50, exact.p50, est.p50),
                (95, exact.p95, est.p95),
                (99, exact.p99, est.p99),
            ] {
                // Bucket midpoint is within half a bucket width (REL_ERROR
                // relative) of the rank sample, plus fixed-point rounding.
                let tol = e * 2.0 * REL_ERROR + 4.0 / 1024.0;
                if (v - e).abs() > tol {
                    return Err(format!("p{q}: histogram {v} vs exact {e} (tol {tol})"));
                }
            }
            let mean = samples.iter().sum::<f64>() / n as f64;
            let mean_tol = 0.001 + mean.abs() * 1e-9;
            if (h.mean() - mean).abs() > mean_tol {
                return Err(format!("mean {} vs exact {mean}", h.mean()));
            }
            Ok(())
        },
    );
}

// ------------------------------------------- trace completeness invariant

#[test]
fn every_ticketed_request_lands_exactly_one_terminal_span() {
    let registry = registry_with("m", PackedModel::random(&nano_cfg("obs-trace"), 11));
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "m".into(),
            trace: true,
            kv: Some(KvPoolOptions { n_blocks: 256, block_size: 16, ..Default::default() }),
            ..EngineOptions::default()
        },
    )
    .unwrap();

    // (id, terminal reason code, token count) per ticketed request.
    let mut expected: Vec<(u64, u64, u64)> = Vec::new();
    // Three plain completions (reason 1 = length).
    for i in 0..3u32 {
        let t = engine.submit(GenRequest::greedy(vec![1 + i, 2, 3], 5)).unwrap();
        let id = t.id;
        let stats = t.wait();
        assert_eq!(stats.finish, FinishReason::Length);
        expected.push((id, 1, stats.tokens.len() as u64));
    }
    // Zero-budget completes at admission and must still trace.
    let t = engine.submit(GenRequest::greedy(vec![4, 5], 0)).unwrap();
    let id = t.id;
    assert_eq!(t.wait().finish, FinishReason::Length);
    expected.push((id, 1, 0));
    // Cancelled mid-decode (reason 2).
    let t = engine.submit(GenRequest::greedy(vec![6, 7, 8, 9], 600)).unwrap();
    let id = t.id;
    loop {
        match t.recv().expect("stream open") {
            Event::Token(_) => break,
            _ => {}
        }
    }
    t.cancel();
    let stats = t.wait();
    assert_eq!(stats.finish, FinishReason::Cancelled);
    expected.push((id, 2, stats.tokens.len() as u64));

    let metrics = engine.shutdown();
    let tr = metrics.trace().expect("engine started with trace: true");
    assert_eq!(tr.completed_count(), expected.len());
    assert_eq!(tr.dropped_traces(), 0);
    for (id, reason, tokens) in &expected {
        let trace = tr.find(*id).unwrap_or_else(|| panic!("no trace for request {id}"));
        let terminals =
            trace.spans.iter().filter(|sp| sp.kind == SpanKind::Terminal).count();
        assert_eq!(terminals, 1, "request {id} must land exactly one terminal span");
        let term = trace.terminal().unwrap();
        assert_eq!(term.a, *reason, "request {id} terminal reason code");
        assert_eq!(term.b, *tokens, "request {id} terminal token count");
        assert_eq!(trace.spans.first().unwrap().kind, SpanKind::Submit);
        assert_eq!(trace.spans.last().unwrap().kind, SpanKind::Terminal);
        assert!(trace.spans.iter().all(|sp| sp.t1_us >= sp.t0_us));
    }
    // The whole ring exports as structurally valid Chrome trace JSON with
    // per-tid monotone timestamps and one terminal per request.
    let summary = validate_chrome_json(&tr.to_chrome_json())
        .expect("trace ring must export valid Chrome trace-event JSON");
    assert_eq!(summary.terminals, expected.len());
    assert!(summary.events > expected.len());
}

#[test]
fn rejected_submissions_leave_no_trace_behind() {
    // A request the pool can never fit fails at submit with KvTooLarge —
    // no ticket, so the completeness invariant demands no trace either.
    let registry = registry_with("m", PackedModel::random(&nano_cfg("obs-reject"), 13));
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "m".into(),
            trace: true,
            kv: Some(KvPoolOptions { n_blocks: 4, block_size: 8, ..Default::default() }),
            ..EngineOptions::default()
        },
    )
    .unwrap();
    match engine.submit(GenRequest::greedy(vec![1, 2, 3, 4], 1000)) {
        Err(SubmitError::KvTooLarge(_)) => {}
        other => {
            panic!("expected KvTooLarge, got {:?}", other.map(|_| ()).map_err(|e| e.to_string()))
        }
    }
    let stats = engine.submit(GenRequest::greedy(vec![1, 2], 4)).unwrap().wait();
    assert_eq!(stats.tokens.len(), 4);
    let metrics = engine.shutdown();
    let tr = metrics.trace().unwrap();
    assert_eq!(tr.completed_count(), 1, "only the admitted request traces");
}

#[test]
fn preempted_request_traces_preempt_resume_and_one_terminal() {
    // Mirror of the kvcache preemption test, with tracing on: the pool
    // fits exactly one long request (4 + 400 tokens over 8-token blocks
    // -> 51 logical x 2 layers = 102 blocks), so the high-priority
    // submission must preempt the low one.
    let model = PackedModel::random(&nano_cfg("obs-preempt"), 9);
    let registry = registry_with("m", model);
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "m".into(),
            max_batch: 4,
            trace: true,
            kv: Some(KvPoolOptions { n_blocks: 102, block_size: 8, ..Default::default() }),
            ..EngineOptions::default()
        },
    )
    .unwrap();
    let low = engine.submit(GenRequest::greedy(vec![1, 2, 3, 4], 400)).unwrap();
    let low_id = low.id;
    loop {
        match low.recv().expect("stream open") {
            Event::Token(_) => break,
            _ => {}
        }
    }
    let high_req = GenRequest::greedy(vec![9, 8, 7, 6], 400).with_priority(5);
    let high = match engine.submit(high_req) {
        Err(SubmitError::KvExhausted(req, _)) => submit_blocking(&engine, req),
        Ok(t) => t,
        Err(e) => panic!("unexpected submit error: {e}"),
    };
    let high_id = high.id;
    assert_eq!(high.wait().finish, FinishReason::Length);
    assert_eq!(low.wait().finish, FinishReason::Length);

    let metrics = engine.shutdown();
    let tr = metrics.trace().unwrap();
    assert_eq!(tr.completed_count(), 2);
    for id in [low_id, high_id] {
        let trace = tr.find(id).unwrap_or_else(|| panic!("no trace for request {id}"));
        let terminals =
            trace.spans.iter().filter(|sp| sp.kind == SpanKind::Terminal).count();
        assert_eq!(terminals, 1, "request {id} must land exactly one terminal span");
        assert_eq!(trace.terminal().unwrap().a, 1, "both finish by length");
    }
    // The preempted request's trace records the preempt and the resume.
    let low_trace = tr.find(low_id).unwrap();
    let kinds: Vec<SpanKind> = low_trace.spans.iter().map(|sp| sp.kind).collect();
    assert!(kinds.contains(&SpanKind::Preempt), "low request must trace a Preempt: {kinds:?}");
    assert!(kinds.contains(&SpanKind::Resume), "low request must trace a Resume: {kinds:?}");
    let summary = validate_chrome_json(&tr.to_chrome_json()).expect("valid Chrome JSON");
    assert_eq!(summary.terminals, 2);
}

// ---------------------------------------------------------- HTTP surface

/// One-shot GET: (status, content-type, body-to-EOF).
fn get(addr: SocketAddr, path: &str, accept: Option<&str>) -> (u16, String, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    let accept_line = accept.map(|a| format!("Accept: {a}\r\n")).unwrap_or_default();
    write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\n{accept_line}Connection: close\r\n\r\n")
        .unwrap();
    s.flush().unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let (head, body) = raw.split_once("\r\n\r\n").expect("response has a header block");
    let mut lines = head.lines();
    let status: u16 = lines.next().unwrap().split_whitespace().nth(1).unwrap().parse().unwrap();
    let content_type = lines
        .filter_map(|l| l.split_once(':'))
        .find(|(k, _)| k.eq_ignore_ascii_case("content-type"))
        .map(|(_, v)| v.trim().to_string())
        .unwrap_or_default();
    (status, content_type, body.to_string())
}

fn post_generate(addr: SocketAddr, body: &str) -> u16 {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "POST /v1/generate HTTP/1.1\r\nHost: test\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    s.flush().unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    raw.split_whitespace().nth(1).unwrap().parse().unwrap()
}

#[test]
fn metrics_negotiation_and_trace_route_round_trip() {
    // Two engines behind one router: "m" traced, "plain" not — the trace
    // route must serve the former and 404 the latter.
    let registry = Arc::new(ModelRegistry::new());
    registry.register("m", PackedModel::random(&nano_cfg("obs-http"), 17), None);
    registry.register("plain", PackedModel::random(&nano_cfg("obs-plain"), 19), None);
    let traced = Arc::new(
        Engine::start(
            &registry,
            EngineOptions { model: "m".into(), trace: true, ..EngineOptions::default() },
        )
        .unwrap(),
    );
    let plain = Arc::new(
        Engine::start(
            &registry,
            EngineOptions { model: "plain".into(), ..EngineOptions::default() },
        )
        .unwrap(),
    );
    let server = HttpServer::bind(
        "127.0.0.1:0",
        Router::new(registry).route("m", traced.clone()).route("plain", plain),
    )
    .unwrap();
    let addr = server.local_addr();

    // One completed request on the traced engine gives the scrape and the
    // trace ring something to report.
    assert_eq!(post_generate(addr, r#"{"prompt": [5, 9, 2], "n_new": 8, "model": "m"}"#), 200);

    // Default (no Accept header) stays JSON, keyed per routed engine plus
    // the front end's own "http" block.
    let (status, ctype, body) = get(addr, "/v1/metrics", None);
    assert_eq!(status, 200);
    assert!(ctype.starts_with("application/json"), "got {ctype}");
    let j = Json::parse(&body).unwrap();
    let m = j.get("m").unwrap();
    assert_eq!(m.get("completed").unwrap().as_usize().unwrap(), 1);
    assert!(m.get("uptime_ms").unwrap().as_f64().unwrap() >= 0.0);
    assert!(m.get("started_unix_ms").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("plain").is_ok());
    let gen_row = j.get("http").unwrap().get("generate").unwrap();
    assert!(gen_row.get("requests").unwrap().as_f64().unwrap() >= 1.0);

    // ?format=prometheus switches to the text exposition; so does an
    // Accept header asking for text/plain.
    let (status, ctype, text) = get(addr, "/v1/metrics?format=prometheus", None);
    assert_eq!(status, 200);
    assert!(ctype.starts_with("text/plain"), "got {ctype}");
    let samples = parse_text(&text).expect("exposition must parse");
    let completed = samples
        .iter()
        .find(|s| s.name == "pquant_requests_completed_total" && s.label("model") == Some("m"))
        .expect("per-model completed counter present");
    assert!(completed.value >= 1.0);
    assert!(samples
        .iter()
        .any(|s| s.name == "pquant_http_requests_total"
            && s.label("route") == Some("generate")
            && s.value >= 1.0));
    let (status, ctype, via_accept) = get(addr, "/v1/metrics", Some("text/plain"));
    assert_eq!(status, 200);
    assert!(ctype.starts_with("text/plain"), "got {ctype}");
    assert!(parse_text(&via_accept).is_ok());

    // Trace round-trip: latest / all are Perfetto-loadable Chrome JSON
    // with exactly the one completed terminal.
    for path in ["/v1/trace/latest", "/v1/trace/all"] {
        let (status, ctype, body) = get(addr, path, None);
        assert_eq!(status, 200, "{path}");
        assert!(ctype.starts_with("application/json"));
        let summary = validate_chrome_json(&Json::parse(&body).unwrap())
            .unwrap_or_else(|e| panic!("{path}: {e}"));
        assert_eq!(summary.terminals, 1, "{path}");
    }
    // Unknown id -> 404, garbage selector -> 400, untraced engine -> 404.
    assert_eq!(get(addr, "/v1/trace/999999999", None).0, 404);
    assert_eq!(get(addr, "/v1/trace/bogus", None).0, 400);
    assert_eq!(get(addr, "/v1/trace/latest?model=plain", None).0, 404);

    server.shutdown();
}
