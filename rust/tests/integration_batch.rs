//! Integration tests for the weight-stationary batched decode path:
//! batch-of-B fused steps must be bit-identical to per-sequence
//! single-token decoding, across variants, batch sizes, prefill-chunk
//! row counts, and contiguous/paged KV mixes — and the serving engine
//! must produce identical greedy generations whether its worker batches
//! one request or many.

use std::sync::Arc;

use pquant::config::{ModelConfig, Variant};
use pquant::infer::{BatchKv, KvCache, PackedModel, Scratch, SeqStep};
use pquant::kvcache::{BlockPool, KvPoolOptions, PagedSeq, PrefixTag};
use pquant::serve::{Engine, EngineOptions, GenRequest, ModelRegistry};
use pquant::util::prop;
use pquant::util::rng::Rng;

fn nano_cfg(variant: Variant) -> ModelConfig {
    ModelConfig {
        name: format!("batch-{}", variant.name()),
        variant,
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 96,
        r: if variant == Variant::PQuant { 16 } else { 0 },
        n_experts: if variant == Variant::PQuant { 2 } else { 1 },
        seq_len: 32,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

/// Sequential reference: logits of each sequence decoded one token at a
/// time on its own contiguous caches.
fn reference_logits(model: &mut PackedModel, seqs: &[Vec<u32>]) -> Vec<Vec<f32>> {
    seqs.iter()
        .map(|toks| {
            let mut caches = model.new_caches(toks.len() + 1);
            let mut logits = Vec::new();
            for (pos, &t) in toks.iter().enumerate() {
                logits = model.decode_step(t, pos, &mut caches);
            }
            logits
        })
        .collect()
}

#[test]
fn batched_decode_matches_sequential_bitexactly_across_variants() {
    for variant in [Variant::Fp16, Variant::BitNet, Variant::BitNet158, Variant::PQuant] {
        let cfg = nano_cfg(variant);
        let mut model = PackedModel::random(&cfg, 21);
        let mut batched = PackedModel::random(&cfg, 21);
        // 3 sequences of different lengths, decoded together step by step.
        let seqs: Vec<Vec<u32>> =
            vec![vec![1, 5, 9, 2, 7], vec![3, 3, 60, 11, 8], vec![40, 0, 2, 63, 30]];
        let want = reference_logits(&mut model, &seqs);

        let mut caches: Vec<Vec<KvCache>> =
            (0..seqs.len()).map(|_| batched.new_caches(8)).collect();
        let mut scratch = Scratch::new();
        let mut got: Vec<Vec<f32>> = vec![Vec::new(); seqs.len()];
        for pos in 0..5 {
            let toks: Vec<u32> = seqs.iter().map(|s| s[pos]).collect();
            let mut steps: Vec<SeqStep> = caches
                .iter_mut()
                .zip(&toks)
                .map(|(c, t)| {
                    SeqStep::new(std::slice::from_ref(t), pos, BatchKv::Contig(&mut c[..]), true)
                })
                .collect();
            batched.decode_step_batch(&mut steps, &mut scratch);
            for (si, step) in steps.iter().enumerate() {
                assert!(step.err.is_none(), "{variant:?} seq {si} errored");
            }
            drop(steps);
            for (si, g) in got.iter_mut().enumerate() {
                *g = scratch.logits_row(si).to_vec();
            }
        }
        for (si, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g, w, "{variant:?} seq {si}: batched logits diverge");
        }
    }
}

#[test]
fn prefill_chunk_rows_match_token_at_a_time_bitexactly() {
    // A chunk of M prompt tokens fed as M rows of one SeqStep must produce
    // the same final logits as M sequential decode_steps.
    let cfg = nano_cfg(Variant::PQuant);
    let mut reference = PackedModel::random(&cfg, 5);
    let mut batched = PackedModel::random(&cfg, 5);
    let prompt: Vec<u32> = vec![9, 1, 33, 7, 12, 40, 2];
    let want = reference_logits(&mut reference, &[prompt.clone()]);

    let mut caches = batched.new_caches(prompt.len() + 1);
    let mut scratch = Scratch::new();
    // Feed in two chunks: 4 rows then 3 rows (the second wants logits).
    for (start, end) in [(0usize, 4usize), (4, 7)] {
        let mut steps = [SeqStep::new(
            &prompt[start..end],
            start,
            BatchKv::Contig(&mut caches[..]),
            end == prompt.len(),
        )];
        batched.decode_step_batch(&mut steps, &mut scratch);
        assert!(steps[0].err.is_none());
    }
    assert_eq!(scratch.logits_row(0), &want[0][..], "chunked prefill diverges");
}

#[test]
fn mixed_contiguous_and_paged_rows_decode_bitexactly() {
    prop::check(81, 8, |r: &mut Rng| {
        let n_seqs = 2 + r.below(3);
        let len = 3 + r.below(5);
        let seqs: Vec<Vec<u32>> =
            (0..n_seqs).map(|_| (0..len).map(|_| r.below(64) as u32).collect()).collect();
        (n_seqs, len, seqs)
    }, |(n_seqs, len, seqs)| {
        let cfg = nano_cfg(Variant::PQuant);
        let mut reference = PackedModel::random(&cfg, 9);
        let mut batched = PackedModel::random(&cfg, 9);
        let want = reference_logits(&mut reference, seqs);

        let pool = Arc::new(BlockPool::new(
            KvPoolOptions { n_blocks: 128, block_size: 4, ..Default::default() },
            cfg.n_layers,
            cfg.d_model,
        ));
        // Even-indexed sequences get paged KV, odd get contiguous.
        let mut paged: Vec<Option<PagedSeq>> = (0..*n_seqs)
            .map(|si| {
                (si % 2 == 0).then(|| {
                    let adm = pool.admit(&[], len + 1, PrefixTag::default()).unwrap();
                    PagedSeq::new(&pool, adm)
                })
            })
            .collect();
        let mut contig: Vec<Vec<KvCache>> =
            (0..*n_seqs).map(|_| batched.new_caches(len + 1)).collect();
        let mut scratch = Scratch::new();
        let mut got: Vec<Vec<f32>> = vec![Vec::new(); *n_seqs];
        for pos in 0..*len {
            let toks: Vec<u32> = seqs.iter().map(|s| s[pos]).collect();
            let mut steps: Vec<SeqStep> = Vec::new();
            for (si, (p, c)) in paged.iter_mut().zip(contig.iter_mut()).enumerate() {
                let kv = match p {
                    Some(seq) => BatchKv::Paged(seq),
                    None => BatchKv::Contig(&mut c[..]),
                };
                steps.push(SeqStep::new(std::slice::from_ref(&toks[si]), pos, kv, true));
            }
            batched.decode_step_batch(&mut steps, &mut scratch);
            for (si, step) in steps.iter().enumerate() {
                if step.err.is_some() {
                    return Err(format!("seq {si} errored at pos {pos}"));
                }
            }
            drop(steps);
            for (si, g) in got.iter_mut().enumerate() {
                *g = scratch.logits_row(si).to_vec();
            }
        }
        for (si, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            if g != w {
                return Err(format!("seq {si}: mixed-layout batched logits diverge"));
            }
        }
        Ok(())
    });
}

#[test]
fn batch_of_one_matches_batch_of_many_bitexactly() {
    let cfg = nano_cfg(Variant::PQuant);
    let mut solo = PackedModel::random(&cfg, 31);
    let mut many = PackedModel::random(&cfg, 31);
    let seqs: Vec<Vec<u32>> = (0..4).map(|s| (0..6).map(|t| (s * 11 + t) as u32 % 64).collect()).collect();

    // batch-of-1 fused steps per sequence
    let mut scratch = Scratch::new();
    let mut want: Vec<Vec<f32>> = Vec::new();
    for toks in &seqs {
        let mut caches = solo.new_caches(toks.len() + 1);
        let mut last = Vec::new();
        for (pos, t) in toks.iter().enumerate() {
            let mut steps = [SeqStep::new(
                std::slice::from_ref(t),
                pos,
                BatchKv::Contig(&mut caches[..]),
                true,
            )];
            solo.decode_step_batch(&mut steps, &mut scratch);
            assert!(steps[0].err.is_none());
            drop(steps);
            last = scratch.logits_row(0).to_vec();
        }
        want.push(last);
    }

    // batch-of-4 fused steps
    let mut caches: Vec<Vec<KvCache>> = (0..seqs.len()).map(|_| many.new_caches(8)).collect();
    let mut scratch = Scratch::new();
    let mut got: Vec<Vec<f32>> = vec![Vec::new(); seqs.len()];
    for pos in 0..6 {
        let toks: Vec<u32> = seqs.iter().map(|s| s[pos]).collect();
        let mut steps: Vec<SeqStep> = caches
            .iter_mut()
            .zip(&toks)
            .map(|(c, t)| {
                SeqStep::new(std::slice::from_ref(t), pos, BatchKv::Contig(&mut c[..]), true)
            })
            .collect();
        many.decode_step_batch(&mut steps, &mut scratch);
        drop(steps);
        for (si, g) in got.iter_mut().enumerate() {
            *g = scratch.logits_row(si).to_vec();
        }
    }
    assert_eq!(got, want, "batch-of-1 vs batch-of-4 logits diverge");
}

#[test]
fn kv_failure_of_one_row_does_not_poison_the_batch() {
    let cfg = nano_cfg(Variant::PQuant);
    let mut reference = PackedModel::random(&cfg, 13);
    let mut batched = PackedModel::random(&cfg, 13);
    let seqs: Vec<Vec<u32>> = vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8]];
    let want = reference_logits(&mut reference, &seqs);

    // Sequence 0 gets a cache that overflows at pos 2; sequence 1 is fine.
    let mut tiny = batched.new_caches(2);
    let mut fine = batched.new_caches(8);
    let mut scratch = Scratch::new();
    let mut last1 = Vec::new();
    let mut seq0_err_at = None;
    for pos in 0..4 {
        let toks = [seqs[0][pos], seqs[1][pos]];
        let mut steps = vec![
            SeqStep::new(&toks[0..1], pos, BatchKv::Contig(&mut tiny[..]), true),
            SeqStep::new(&toks[1..2], pos, BatchKv::Contig(&mut fine[..]), true),
        ];
        batched.decode_step_batch(&mut steps, &mut scratch);
        if steps[0].err.is_some() && seq0_err_at.is_none() {
            seq0_err_at = Some(pos);
        }
        assert!(steps[1].err.is_none(), "healthy row must not fail");
        drop(steps);
        last1 = scratch.logits_row(1).to_vec();
    }
    assert_eq!(seq0_err_at, Some(2), "overflow must surface at capacity");
    assert_eq!(last1, want[1], "survivor's logits must stay bit-exact");
}

// ---------------------------------------------------------------- engine

#[test]
fn concurrent_greedy_requests_are_bitexact_regardless_of_batching() {
    let model = PackedModel::random(&nano_cfg(Variant::PQuant), 41);
    let mut reference = model.clone();

    let prompts: Vec<Vec<u32>> = (0..6)
        .map(|s| (0..3 + s % 3).map(|t| ((s * 17 + t * 5) % 64) as u32).collect())
        .collect();
    let n_new = 8;
    let want: Vec<Vec<u32>> =
        prompts.iter().map(|p| reference.generate(p, n_new)).collect();

    for max_batch in [1usize, 6] {
        let registry = Arc::new(ModelRegistry::new());
        registry.register("m", model.clone(), None);
        let engine = Engine::start(
            &registry,
            EngineOptions { model: "m".into(), max_batch, ..EngineOptions::default() },
        )
        .unwrap();
        let tickets: Vec<_> = prompts
            .iter()
            .map(|p| engine.submit_blocking(GenRequest::greedy(p.clone(), n_new)).unwrap())
            .collect();
        let got: Vec<Vec<u32>> = tickets.into_iter().map(|t| t.wait().tokens).collect();
        assert_eq!(
            got, want,
            "engine (max_batch={max_batch}) must match unbatched generate()"
        );
        let metrics = engine.shutdown();
        assert!(
            metrics.batch_steps.load(std::sync::atomic::Ordering::Relaxed) > 0,
            "fused batch steps must be recorded"
        );
        if max_batch > 1 {
            assert!(
                metrics.mean_batch_rows() > 0.0,
                "occupancy stats must be populated"
            );
        }
    }
}
