//! Integration tests for the `Engine` session API: streaming agreement,
//! cancellation, backpressure, stop tokens, seeded sampling, chunked
//! prefill batch-invariants, and registry hot-swap under live traffic.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use pquant::config::{ModelConfig, Variant};
use pquant::infer::PackedModel;
use pquant::serve::{
    Engine, EngineOptions, Event, FinishReason, GenRequest, ModelRegistry, SamplingParams,
    SubmitError,
};

fn nano_cfg(variant: Variant, name: &str) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        variant,
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 96,
        r: if variant == Variant::PQuant { 16 } else { 0 },
        n_experts: if variant == Variant::PQuant { 2 } else { 1 },
        seq_len: 32,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

fn registry_with(name: &str, model: PackedModel) -> Arc<ModelRegistry> {
    let registry = Arc::new(ModelRegistry::new());
    registry.register(name, model, None);
    registry
}

fn engine_on(registry: &Arc<ModelRegistry>, name: &str, max_batch: usize) -> Engine {
    Engine::start(
        registry,
        EngineOptions { model: name.into(), max_batch, ..EngineOptions::default() },
    )
    .unwrap()
}

// ---------------------------------------------------------------- streaming

#[test]
fn streamed_tokens_match_batch_result_and_reference_decode() {
    let model = PackedModel::random(&nano_cfg(Variant::PQuant, "stream"), 11);
    let mut reference = model.clone();
    let registry = registry_with("m", model);
    let engine = engine_on(&registry, "m", 2);

    let ticket = engine.submit(GenRequest::greedy(vec![5, 9, 2], 10)).unwrap();
    let mut streamed = Vec::new();
    let mut prefilled = false;
    let stats = loop {
        match ticket.recv().expect("stream must end with Done") {
            Event::Prefilled { prompt_len } => {
                assert_eq!(prompt_len, 3);
                assert!(streamed.is_empty(), "Prefilled must precede tokens");
                prefilled = true;
            }
            Event::Token(t) => streamed.push(t),
            Event::Done(stats) => break stats,
        }
    };
    assert!(prefilled);
    // Streamed tokens, the batch result, and the single-request reference
    // decode loop must all agree bit-exactly under greedy sampling.
    assert_eq!(streamed, stats.tokens);
    assert_eq!(stats.tokens, reference.generate(&[5, 9, 2], 10));
    assert_eq!(stats.finish, FinishReason::Length);
    assert!(stats.ttft.is_some());
    engine.shutdown();
}

// ------------------------------------------------------------- cancellation

#[test]
fn cancel_mid_generation_stops_early() {
    let registry =
        registry_with("m", PackedModel::random(&nano_cfg(Variant::PQuant, "cancel"), 3));
    let engine = engine_on(&registry, "m", 2);

    let ticket = engine.submit(GenRequest::greedy(vec![1, 2], 5000)).unwrap();
    // Let it stream a few tokens so cancellation lands mid-generation.
    let mut seen = 0;
    while seen < 3 {
        if let Event::Token(_) = ticket.recv().unwrap() {
            seen += 1;
        }
    }
    ticket.cancel();
    let stats = ticket.wait();
    assert_eq!(stats.finish, FinishReason::Cancelled);
    assert!(stats.tokens.len() >= 3);
    assert!(stats.tokens.len() < 5000, "cancellation must cut the budget short");
    let metrics = engine.shutdown();
    assert_eq!(metrics.cancelled.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.completed.load(Ordering::Relaxed), 0);
}

// ------------------------------------------------------------- backpressure

#[test]
fn tiny_queue_rejects_with_queue_full() {
    let registry =
        registry_with("m", PackedModel::random(&nano_cfg(Variant::PQuant, "queue"), 5));
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "m".into(),
            max_batch: 1,
            workers: 1,
            queue_depth: 1,
            prefill_chunk: 16,
            ..EngineOptions::default()
        },
    )
    .unwrap();

    // One slot decoding + one queued: a fast burst must overflow.
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for _ in 0..8 {
        match engine.submit(GenRequest::greedy(vec![1, 2, 3, 4], 64)) {
            Ok(t) => accepted.push(t),
            Err(SubmitError::QueueFull(req, _)) => {
                assert_eq!(req.n_new, 64, "rejected request rides back intact");
                rejected += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(rejected > 0, "burst of 8 must overflow a depth-1 queue on 1 slot");
    assert!(!accepted.is_empty());
    for t in accepted {
        assert_eq!(t.wait().tokens.len(), 64, "accepted requests still complete");
    }
    engine.shutdown();
}

#[test]
fn fresh_engine_on_same_registry_keeps_serving() {
    let registry =
        registry_with("m", PackedModel::random(&nano_cfg(Variant::PQuant, "shut"), 5));
    let engine = engine_on(&registry, "m", 2);
    engine.submit(GenRequest::greedy(vec![1], 2)).unwrap().wait();
    // After shutdown the engine is consumed; a fresh engine on the same
    // registry keeps serving — sessions are cheap, models are not.
    engine.shutdown();
    let engine = engine_on(&registry, "m", 2);
    assert_eq!(engine.submit(GenRequest::greedy(vec![1], 2)).unwrap().wait().tokens.len(), 2);
}

// -------------------------------------------------------------- stop tokens

#[test]
fn stop_token_exits_early() {
    let model = PackedModel::random(&nano_cfg(Variant::BitNet158, "stop"), 9);
    let mut reference = model.clone();
    let full = reference.generate(&[3, 1], 12);
    let stop = full[2];
    let cut = full.iter().position(|&t| t == stop).unwrap();

    let registry = registry_with("m", model);
    let engine = engine_on(&registry, "m", 2);
    let req = GenRequest::sampled(
        vec![3, 1],
        12,
        SamplingParams { stop_tokens: vec![stop], ..SamplingParams::greedy() },
    );
    let stats = engine.submit(req).unwrap().wait();
    assert_eq!(stats.finish, FinishReason::Stop);
    assert_eq!(stats.tokens, full[..=cut].to_vec(), "stop token is included, then exit");
    engine.shutdown();
}

// ----------------------------------------------------------------- sampling

#[test]
fn seeded_sampling_is_deterministic_across_sessions() {
    let registry =
        registry_with("m", PackedModel::random(&nano_cfg(Variant::PQuant, "sample"), 21));
    let sampled = |seed: u64| {
        let engine = engine_on(&registry, "m", 4);
        let req = GenRequest::sampled(
            vec![7, 4],
            8,
            SamplingParams { temperature: 0.8, top_k: 8, seed, stop_tokens: vec![] },
        );
        let stats = engine.submit(req).unwrap().wait();
        engine.shutdown();
        stats.tokens
    };
    let a = sampled(1234);
    let b = sampled(1234);
    assert_eq!(a, b, "same seed must reproduce the same stream across engines");
    assert_eq!(a.len(), 8);
    assert!(a.iter().all(|&t| t < 64));
}

// ----------------------------------------------- chunked prefill invariants

#[test]
fn chunked_prefill_never_exceeds_max_batch() {
    let registry =
        registry_with("m", PackedModel::random(&nano_cfg(Variant::PQuant, "chunk"), 7));
    // Prompts much longer than the chunk, so several requests sit in
    // prefill at once while others decode.
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "m".into(),
            max_batch: 3,
            workers: 1,
            queue_depth: 16,
            prefill_chunk: 4,
            ..EngineOptions::default()
        },
    )
    .unwrap();
    let tickets: Vec<_> = (0..12)
        .map(|id| {
            let prompt: Vec<u32> = (0..20).map(|i| (id + i) % 64).collect();
            engine.submit(GenRequest::greedy(prompt, 4)).unwrap()
        })
        .collect();
    for t in tickets {
        assert_eq!(t.wait().tokens.len(), 4);
    }
    let metrics = engine.shutdown();
    // The active set counts prefilling requests too — interleaving must
    // never grow it past max_batch (peak_active uses fetch_max, so racing
    // workers cannot lose updates).
    assert!(metrics.peak_active.load(Ordering::Relaxed) <= 3);
    assert_eq!(metrics.completed.load(Ordering::Relaxed), 12);
    let qw = metrics.queue_wait_percentiles();
    assert_eq!(qw.n, 12);
    assert!(qw.p50 <= qw.p95 && qw.p95 <= qw.p99);
    assert_eq!(metrics.ttft_percentiles().n, 12);
}

#[test]
fn prefill_chunking_is_bit_exact_with_full_prefill() {
    let model = PackedModel::random(&nano_cfg(Variant::PQuant, "exact"), 13);
    let mut reference = model.clone();
    let prompt: Vec<u32> = (0..23).map(|i| (i * 3) % 64).collect();
    let want = reference.generate(&prompt, 6);
    let registry = registry_with("m", model);
    for chunk in [1, 4, 64] {
        let engine = Engine::start(
            &registry,
            EngineOptions {
                model: "m".into(),
                max_batch: 2,
                workers: 1,
                queue_depth: 8,
                prefill_chunk: chunk,
                ..EngineOptions::default()
            },
        )
        .unwrap();
        let stats = engine.submit(GenRequest::greedy(prompt.clone(), 6)).unwrap().wait();
        assert_eq!(stats.tokens, want, "prefill_chunk={chunk} changed the stream");
        engine.shutdown();
    }
}

// ----------------------------------------------------- hot-swap under load

#[test]
fn hot_swap_drains_inflight_on_old_generation_and_admits_on_new() {
    let model_a = PackedModel::random(&nano_cfg(Variant::PQuant, "gen-a"), 31);
    let model_b = PackedModel::random(&nano_cfg(Variant::PQuant, "gen-b"), 32);
    let mut ref_a = model_a.clone();
    let mut ref_b = model_b.clone();

    let registry = registry_with("m", model_a);
    let engine = engine_on(&registry, "m", 2);

    // Get a request actively decoding on generation 1.
    let inflight = engine.submit(GenRequest::greedy(vec![1, 2], 40)).unwrap();
    loop {
        match inflight.recv().unwrap() {
            Event::Token(_) => break,
            Event::Prefilled { .. } => {}
            Event::Done(_) => panic!("finished before the swap raced it"),
        }
    }

    // Install generation 2 without waiting for the drain.
    let report = registry.hot_swap("m", model_b, None, Duration::ZERO);
    assert_eq!(report.generation, 2);

    // New admission lands on the new generation while the old one drains.
    let post = engine.submit(GenRequest::greedy(vec![1, 2], 5)).unwrap();
    let old = inflight.wait();
    let new = post.wait();
    assert_eq!(old.generation, 1);
    assert_eq!(old.finish, FinishReason::Length);
    assert_eq!(old.tokens, ref_a.generate(&[1, 2], 40), "drained on old weights");
    assert_eq!(new.generation, 2);
    assert_eq!(new.tokens, ref_b.generate(&[1, 2], 5), "admitted on new weights");

    // With the old generation's work finished, its lease is released — a
    // further swap drains promptly even though the engine sits idle.
    let report = registry.hot_swap(
        "m",
        PackedModel::random(&nano_cfg(Variant::PQuant, "gen-c"), 33),
        None,
        Duration::from_secs(10),
    );
    assert_eq!(report.generation, 3);
    assert!(report.drained, "idle engine must not hold the drain barrier open");
    engine.shutdown();
}

// -------------------------------------------------------------- multi-model

#[test]
fn engines_on_different_names_serve_their_own_models() {
    let a = PackedModel::random(&nano_cfg(Variant::Fp16, "name-a"), 41);
    let b = PackedModel::random(&nano_cfg(Variant::BitNet158, "name-b"), 42);
    let mut ref_a = a.clone();
    let mut ref_b = b.clone();
    let registry = Arc::new(ModelRegistry::new());
    registry.register("a", a, None);
    registry.register("b", b, None);

    let ea = engine_on(&registry, "a", 2);
    let eb = engine_on(&registry, "b", 2);
    let ta = ea.submit(GenRequest::greedy(vec![9, 9], 6)).unwrap();
    let tb = eb.submit(GenRequest::greedy(vec![9, 9], 6)).unwrap();
    assert_eq!(ta.wait().tokens, ref_a.generate(&[9, 9], 6));
    assert_eq!(tb.wait().tokens, ref_b.generate(&[9, 9], 6));
    assert!(Engine::start(&registry, EngineOptions::default()).is_err(), "unknown name");
}
