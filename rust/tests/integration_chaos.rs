//! Chaos harness: seeded fault injection against the full serving stack.
//!
//! Each test arms `pquant::util::failpoint` sites (worker panics, KV
//! reservation failures, spill I/O errors, degraded draft proposals) and
//! asserts the fault-domain invariants the engine promises:
//!
//!   * every submitted ticket reaches exactly one terminal event — faults
//!     fail requests, they never hang them;
//!   * the KV pool drains back to `in_use == 0` after the run, so no
//!     fault path leaks blocks;
//!   * server-side counters reconcile with the client-side tally;
//!   * a worker panic degrades `Engine::health` and then recovers.
//!
//! The failpoint registry is process-global, so the tests serialize on
//! `CHAOS_LOCK` and disarm everything on entry and exit (a panicking
//! test must not leave faults armed for its neighbors). The CI chaos
//! lane reruns this binary across several `PQUANT_CHAOS_SEED` values.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use pquant::config::{ModelConfig, Variant};
use pquant::infer::PackedModel;
use pquant::kvcache::KvPoolOptions;
use pquant::serve::loadgen::{self, Target, TraceConfig};
use pquant::serve::{
    Engine, EngineOptions, FinishReason, GenRequest, HealthState, ModelRegistry,
};
use pquant::util::failpoint;

static CHAOS_LOCK: Mutex<()> = Mutex::new(());

/// Holds the global chaos lock for one test and guarantees a clean
/// failpoint registry on both entry and exit (even when the test body
/// panics, Drop still disarms before the lock is released).
struct ChaosGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for ChaosGuard {
    fn drop(&mut self) {
        failpoint::disarm_all();
    }
}

fn chaos_guard() -> ChaosGuard {
    // A panicking chaos test poisons the lock by design; the registry is
    // re-zeroed below, so the poison carries no state worth refusing.
    let g = CHAOS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    failpoint::disarm_all();
    ChaosGuard(g)
}

fn chaos_seed() -> u64 {
    std::env::var("PQUANT_CHAOS_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(11)
}

fn nano_cfg(name: &str) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        variant: Variant::PQuant,
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 96,
        r: 16,
        n_experts: 2,
        seq_len: 32,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

// ------------------------------------------------------ worker supervision

#[test]
fn worker_panic_is_survivable_and_health_recovers() {
    let _g = chaos_guard();
    let model = PackedModel::random(&nano_cfg("chaos-panic"), 11);
    let mut reference = model.clone();
    let registry = Arc::new(ModelRegistry::new());
    registry.register("m", model, None);
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "m".into(),
            // Long cooldown so the degraded window is observable without
            // racing the wall clock; recovery is polled below.
            fault_cooldown: Duration::from_secs(2),
            ..EngineOptions::default()
        },
    )
    .unwrap();
    assert!(engine.health().is_ready(), "a fresh engine starts ready");

    // Exactly one injected panic. The failpoint sits after the idle
    // check, so idle spinning cannot consume the single fire: the first
    // round that actually carries the submitted request dies.
    failpoint::arm_limited("worker.step", 1.0, 0xC0FFEE, 1);
    let stats = engine.submit(GenRequest::greedy(vec![1, 2, 3], 8)).unwrap().wait();
    assert_eq!(
        stats.finish,
        FinishReason::WorkerFault,
        "the in-flight row fails with a terminal event instead of hanging"
    );
    assert_eq!(engine.metrics().worker_faults.load(Ordering::Relaxed), 1);
    assert_eq!(engine.metrics().worker_respawns.load(Ordering::Relaxed), 1);
    assert!(
        matches!(engine.health(), HealthState::Degraded { .. }),
        "a fresh worker fault reports degraded during the cooldown"
    );

    // The respawned worker must serve bit-identical greedy output while
    // the health cooldown is still running — degraded still serves.
    let out = engine.submit(GenRequest::greedy(vec![4, 5], 6)).unwrap().wait();
    assert_eq!(out.finish, FinishReason::Length);
    assert_eq!(out.tokens, reference.generate(&[4, 5], 6));

    let t0 = Instant::now();
    while !engine.health().is_ready() {
        assert!(t0.elapsed() < Duration::from_secs(10), "health must return to ready");
        std::thread::sleep(Duration::from_millis(20));
    }
    let metrics = engine.shutdown();
    let kv = metrics.kv().expect("paged engine reports pool stats");
    assert_eq!(kv.in_use, 0, "the faulted row's blocks drained back to the pool");
}

// -------------------------------------------------------- chaos invariants

#[test]
fn chaos_invariants_under_seeded_faults() {
    let _g = chaos_guard();
    let seed = chaos_seed();
    let spill_dir = std::env::temp_dir()
        .join(format!("pquant-chaos-{}-{seed}", std::process::id()));
    let registry = Arc::new(ModelRegistry::new());
    registry.register("m", PackedModel::random(&nano_cfg("chaos-target"), 21), None);
    registry.register("draft", PackedModel::random(&nano_cfg("chaos-draft"), 22), None);
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "m".into(),
            max_batch: 4,
            queue_depth: 256,
            // Small pool + spill tier so the KV failpoints actually sit
            // on hot paths (reservation pressure, shed-to-disk writes).
            kv: Some(KvPoolOptions { n_blocks: 64, block_size: 8, ..Default::default() }),
            kv_spill_dir: Some(spill_dir.clone()),
            ..EngineOptions::default()
        },
    )
    .unwrap();

    failpoint::arm("kv.reserve", 0.05, seed);
    failpoint::arm("spill.write", 0.5, seed ^ 0xA5);
    failpoint::arm("spec.propose", 0.25, seed ^ 0x5A);
    // Worker panics are bounded so a run cannot spend all its wall clock
    // respawning; two mid-traffic crashes is plenty of coverage.
    failpoint::arm_limited("worker.step", 0.02, seed ^ 0xF0, 2);

    let cfg = TraceConfig {
        seed,
        n_requests: 48,
        rate: 400.0,
        prompt_lens: vec![(4, 0.6), (8, 0.4)],
        output_lens: vec![(4, 0.5), (8, 0.5)],
        shared_prefix_len: 8,
        draft_frac: 0.25,
        draft_model: Some("draft".into()),
        spec_k: 2,
        ..TraceConfig::default()
    };
    let (report, records) = loadgen::run_recorded(Target::Engine(&engine), &cfg).unwrap();
    failpoint::disarm_all();

    // Invariant 1: exactly one terminal outcome per submitted request.
    assert_eq!(report.submitted, cfg.n_requests);
    assert_eq!(records.len(), cfg.n_requests);
    let known =
        ["length", "stop", "cancelled", "failed", "worker_fault", "deadline", "rejected"];
    for r in &records {
        assert!(
            known.contains(&r.finish.as_str()),
            "request {} ended {:?} — streams must terminate, not trail off",
            r.index,
            r.finish
        );
    }

    // Invariant 2: server-side counters reconcile with the client tally.
    // `rejected` never got past submit, so it has no server-side twin;
    // everything admitted must land in exactly one terminal counter.
    let count = |name: &str| records.iter().filter(|r| r.finish == name).count();
    let pool = engine.kv_pool().cloned();
    let metrics = engine.shutdown();
    assert_eq!(metrics.completed.load(Ordering::Relaxed), count("length") + count("stop"));
    assert_eq!(metrics.cancelled.load(Ordering::Relaxed), count("cancelled"));
    assert_eq!(metrics.failed.load(Ordering::Relaxed), count("failed"));
    assert_eq!(metrics.worker_faults.load(Ordering::Relaxed), count("worker_fault"));
    assert_eq!(metrics.deadline_exceeded.load(Ordering::Relaxed), count("deadline"));
    assert!(
        metrics.worker_respawns.load(Ordering::Relaxed)
            >= failpoint::fire_count("worker.step"),
        "every injected worker panic produced a respawn"
    );

    // Invariant 3: after the drain plus explicit eviction of the shared
    // prefix cache, every block is back in the pool — no fault path
    // (panic drain, deadline cut, failed spill, rejected reservation)
    // may leak KV.
    let pool = pool.expect("engine was started with a paged pool");
    pool.evict_unused();
    assert_eq!(pool.stats().in_use, 0, "chaos run leaked KV blocks");
    std::fs::remove_dir_all(&spill_dir).ok();
}

// ------------------------------------------------------------- deadlines

#[test]
fn expired_deadlines_shed_in_queue_and_cut_in_flight() {
    let _g = chaos_guard();
    let registry = Arc::new(ModelRegistry::new());
    registry.register("m", PackedModel::random(&nano_cfg("chaos-deadline"), 31), None);
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "m".into(),
            max_batch: 4,
            // One prompt token per scheduling slice stretches prefill
            // across many fused rounds, giving the in-flight deadline
            // sweep a realistic window to fire in.
            prefill_chunk: 1,
            ..EngineOptions::default()
        },
    )
    .unwrap();

    // (a) Already expired at admission: shed from the queue before any
    // prefill work, deterministically.
    let req = GenRequest::greedy(vec![1, 2, 3, 4], 8).with_deadline(Duration::ZERO);
    let stats = engine.submit(req).unwrap().wait();
    assert_eq!(stats.finish, FinishReason::DeadlineExceeded);
    assert!(stats.tokens.is_empty(), "queue-shed requests never produce tokens");

    // (b) Tight-but-plausible deadlines under concurrent load: every
    // ticket still reaches a terminal state. A deadline cut must be
    // partial output; anything that beat the clock must be complete.
    let tickets: Vec<_> = (0u32..4)
        .map(|i| {
            let prompt: Vec<u32> = (0u32..24).map(|j| (i + j) % 64).collect();
            let req = GenRequest::greedy(prompt, 8).with_deadline(Duration::from_millis(3));
            engine.submit(req).unwrap()
        })
        .collect();
    let mut cut = 0usize;
    for t in tickets {
        let s = t.wait();
        match s.finish {
            FinishReason::DeadlineExceeded => {
                cut += 1;
                assert!(s.tokens.len() < 8, "a deadline cut cannot be a full budget");
            }
            FinishReason::Length => assert_eq!(s.tokens.len(), 8),
            other => panic!("unexpected finish {other:?}"),
        }
    }
    assert!(engine.health().is_ready(), "deadline shedding is not a fault");
    let metrics = engine.shutdown();
    assert_eq!(metrics.deadline_exceeded.load(Ordering::Relaxed), 1 + cut);
    let kv = metrics.kv().expect("paged engine reports pool stats");
    assert_eq!(kv.in_use, 0, "deadline cuts drained their blocks");
}

// ----------------------------------------------- failpoints compiled out

#[test]
fn disarmed_failpoints_never_fire() {
    let _g = chaos_guard();
    // The serving stack is compiled with failpoints in place; with the
    // registry empty they must be inert, i.e. a plain run is untouched.
    let model = PackedModel::random(&nano_cfg("chaos-off"), 41);
    let mut reference = model.clone();
    let registry = Arc::new(ModelRegistry::new());
    registry.register("m", model, None);
    let engine = Engine::start(
        &registry,
        EngineOptions { model: "m".into(), ..EngineOptions::default() },
    )
    .unwrap();
    let stats = engine.submit(GenRequest::greedy(vec![7, 9], 5)).unwrap().wait();
    assert_eq!(stats.finish, FinishReason::Length);
    assert_eq!(stats.tokens, reference.generate(&[7, 9], 5));
    assert_eq!(engine.metrics().worker_faults.load(Ordering::Relaxed), 0);
    assert_eq!(failpoint::fire_count("worker.step"), 0);
    assert!(engine.health().is_ready());
    engine.shutdown();
}
