//! SIMD == scalar bit-exactness property tests for the dispatch layer
//! (`gemm::simd`).
//!
//! Two layers of coverage:
//!
//! 1. **Backend parity** — the AVX2 kernels are called directly (when the
//!    CPU has AVX2) against the `*_cols_scalar` oracles, bit-for-bit, on
//!    shapes chosen to hit every remainder path: k and n that are not
//!    vector-width multiples, b from 1 up, chunks starting at a nonzero
//!    `col0`, and k large enough to cross the cache-block / column-tile
//!    boundaries. This does not touch the process-global mode, so it runs
//!    concurrently with everything else.
//! 2. **Dispatch parity** — one test (the only mode writer in this
//!    binary) forces every mode `available_modes()` reports through
//!    `set_simd_mode` and checks the four public batched kernels and both
//!    LUT-family GEMVs give bit-identical outputs in each. Concurrent
//!    kernel calls from test (1) are safe under the flipping mode
//!    precisely because every backend is bit-identical — which is what
//!    these tests establish.

use pquant::gemm::batched::{
    f32_cols_scalar, f32_gemm_batch_into, i8_cols_scalar, i8_gemm_batch_into, lut_cols_scalar,
    lut_gemm_into, ternary_cols_scalar, ternary_gemm_into,
};
use pquant::gemm::{
    build_luts, build_ternary_luts, lut_gemv_into, simd, ternary_gemv_into, SimdMode,
};
use pquant::quant::{pack_signs, pack_ternary};
use pquant::util::prop;
use pquant::util::rng::Rng;

/// Fixed shapes hitting the structural edges: single element, sub-vector
/// k and n, exact vector widths, remainder lanes, a max-ish batch, and
/// (last two) k big enough that the LUT byte-blocking and the dense
/// column tiling actually split (byte_block < bytes_per_col,
/// col_tile < n).
const EDGE_SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (7, 3, 2),
    (8, 16, 1),
    (9, 17, 16),
    (33, 40, 3),
    (130, 23, 5),
    (64, 64, 8),
    (2304, 5, 16),
    (8192, 35, 2),
];

fn rand_i8(r: &mut Rng, n: usize) -> Vec<i8> {
    (0..n).map(|_| (r.below(255) as i32 - 127) as i8).collect()
}

fn avx2() -> bool {
    simd::available_modes().contains(&SimdMode::Avx2)
}

fn check_lut_shape(r: &mut Rng, k: usize, n: usize, b: usize) {
    let signs: Vec<bool> = (0..k * n).map(|_| r.below(2) == 1).collect();
    let xs = rand_i8(r, b * k);
    let w = pack_signs(&signs, k, n);
    let luts: Vec<_> = (0..b).map(|row| build_luts(&xs[row * k..(row + 1) * k], k)).collect();

    let mut want = vec![0i32; n * b];
    lut_cols_scalar(&luts, &w, 0, &mut want);

    let mut got = vec![0i32; n * b];
    lut_gemm_into(&luts, &w, &mut got);
    assert_eq!(got, want, "dispatch vs oracle, k={k} n={n} b={b}");

    #[cfg(target_arch = "x86_64")]
    if avx2() {
        let mut ys = vec![0i32; n * b];
        unsafe { simd::x86::lut_cols(&luts, &w, 0, &mut ys) };
        assert_eq!(ys, want, "avx2 full, k={k} n={n} b={b}");
        // Nonzero col0: split the accumulator at a column boundary.
        if n > 1 {
            let c = 1 + (k + n) % (n - 1); // deterministic split in 1..n
            let mut ys2 = vec![0i32; n * b];
            let (head, tail) = ys2.split_at_mut(c * b);
            unsafe {
                simd::x86::lut_cols(&luts, &w, 0, head);
                simd::x86::lut_cols(&luts, &w, c, tail);
            }
            assert_eq!(ys2, want, "avx2 split at {c}, k={k} n={n} b={b}");
        }
    }
}

fn check_ternary_shape(r: &mut Rng, k: usize, n: usize, b: usize) {
    let vals: Vec<i8> = (0..k * n).map(|_| r.below(3) as i8 - 1).collect();
    let xs = rand_i8(r, b * k);
    let w = pack_ternary(&vals, k, n);
    let luts: Vec<_> =
        (0..b).map(|row| build_ternary_luts(&xs[row * k..(row + 1) * k], k)).collect();

    let mut want = vec![0i32; n * b];
    ternary_cols_scalar(&luts, &w, 0, &mut want);

    let mut got = vec![0i32; n * b];
    ternary_gemm_into(&luts, &w, &mut got);
    assert_eq!(got, want, "dispatch vs oracle, k={k} n={n} b={b}");

    #[cfg(target_arch = "x86_64")]
    if avx2() {
        let mut ys = vec![0i32; n * b];
        unsafe { simd::x86::ternary_cols(&luts, &w, 0, &mut ys) };
        assert_eq!(ys, want, "avx2 full, k={k} n={n} b={b}");
        if n > 1 {
            let c = 1 + (k + n) % (n - 1);
            let mut ys2 = vec![0i32; n * b];
            let (head, tail) = ys2.split_at_mut(c * b);
            unsafe {
                simd::x86::ternary_cols(&luts, &w, 0, head);
                simd::x86::ternary_cols(&luts, &w, c, tail);
            }
            assert_eq!(ys2, want, "avx2 split at {c}, k={k} n={n} b={b}");
        }
    }
}

fn check_i8_shape(r: &mut Rng, k: usize, n: usize, b: usize) {
    let w = rand_i8(r, k * n);
    let mut xs = rand_i8(r, b * k);
    for i in (0..xs.len()).step_by(5) {
        xs[i] = 0; // exercise the skip-zero predicate
    }

    let mut want = vec![0i32; n * b];
    i8_cols_scalar(&xs, &w, b, k, n, 0, &mut want);

    let mut got = vec![0i32; n * b];
    i8_gemm_batch_into(&xs, &w, b, k, n, &mut got);
    assert_eq!(got, want, "dispatch vs oracle, k={k} n={n} b={b}");

    #[cfg(target_arch = "x86_64")]
    if avx2() {
        let mut ys = vec![0i32; n * b];
        unsafe { simd::x86::i8_cols(&xs, &w, b, k, n, 0, &mut ys) };
        assert_eq!(ys, want, "avx2 full, k={k} n={n} b={b}");
        if n > 1 {
            let c = 1 + (k + n) % (n - 1);
            let mut ys2 = vec![0i32; n * b];
            let (head, tail) = ys2.split_at_mut(c * b);
            unsafe {
                simd::x86::i8_cols(&xs, &w, b, k, n, 0, head);
                simd::x86::i8_cols(&xs, &w, b, k, n, c, tail);
            }
            assert_eq!(ys2, want, "avx2 split at {c}, k={k} n={n} b={b}");
        }
    }
}

fn check_f32_shape(r: &mut Rng, k: usize, n: usize, b: usize) {
    let mut w = r.normal_vec(k * n);
    let mut xs = r.normal_vec(b * k);
    for i in (0..w.len()).step_by(7) {
        w[i] = 0.0;
    }
    for i in (0..xs.len()).step_by(5) {
        xs[i] = 0.0;
    }

    let mut want = vec![0f32; n * b];
    f32_cols_scalar(&xs, &w, b, k, n, 0, &mut want);

    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();

    let mut got = vec![0f32; n * b];
    f32_gemm_batch_into(&xs, &w, b, k, n, &mut got);
    assert_eq!(bits(&got), bits(&want), "dispatch vs oracle, k={k} n={n} b={b}");

    #[cfg(target_arch = "x86_64")]
    if avx2() {
        let mut ys = vec![0f32; n * b];
        unsafe { simd::x86::f32_cols(&xs, &w, b, k, n, 0, &mut ys) };
        assert_eq!(bits(&ys), bits(&want), "avx2 full, k={k} n={n} b={b}");
        if n > 1 {
            let c = 1 + (k + n) % (n - 1);
            let mut ys2 = vec![0f32; n * b];
            let (head, tail) = ys2.split_at_mut(c * b);
            unsafe {
                simd::x86::f32_cols(&xs, &w, b, k, n, 0, head);
                simd::x86::f32_cols(&xs, &w, b, k, n, c, tail);
            }
            assert_eq!(bits(&ys2), bits(&want), "avx2 split at {c}, k={k} n={n} b={b}");
        }
    }
}

fn rand_shape(r: &mut Rng) -> (usize, usize, usize) {
    (1 + r.below(180), 1 + r.below(40), 1 + r.below(16))
}

#[test]
fn lut_gemm_simd_bitexact_vs_scalar() {
    let mut r = Rng::new(81);
    for &(k, n, b) in EDGE_SHAPES {
        check_lut_shape(&mut r, k, n, b);
    }
    prop::check(82, 30, rand_shape, |&(k, n, b)| {
        check_lut_shape(&mut Rng::new((k * 1009 + n * 31 + b) as u64), k, n, b);
        Ok(())
    });
}

#[test]
fn ternary_gemm_simd_bitexact_vs_scalar() {
    let mut r = Rng::new(83);
    for &(k, n, b) in EDGE_SHAPES {
        check_ternary_shape(&mut r, k, n, b);
    }
    prop::check(84, 30, rand_shape, |&(k, n, b)| {
        check_ternary_shape(&mut Rng::new((k * 1013 + n * 37 + b) as u64), k, n, b);
        Ok(())
    });
}

#[test]
fn i8_gemm_batch_simd_bitexact_vs_scalar() {
    let mut r = Rng::new(85);
    for &(k, n, b) in EDGE_SHAPES {
        check_i8_shape(&mut r, k, n, b);
    }
    prop::check(86, 30, rand_shape, |&(k, n, b)| {
        check_i8_shape(&mut Rng::new((k * 1019 + n * 41 + b) as u64), k, n, b);
        Ok(())
    });
}

#[test]
fn f32_gemm_batch_simd_bitexact_vs_scalar() {
    let mut r = Rng::new(87);
    for &(k, n, b) in EDGE_SHAPES {
        check_f32_shape(&mut r, k, n, b);
    }
    prop::check(88, 30, rand_shape, |&(k, n, b)| {
        check_f32_shape(&mut Rng::new((k * 1021 + n * 43 + b) as u64), k, n, b);
        Ok(())
    });
}

/// The GEMV walks dispatch through the same backends as the batched
/// kernels (b = 1); check them against the b = 1 oracles.
#[test]
fn gemv_walks_bitexact_vs_scalar() {
    let mut r = Rng::new(89);
    for &(k, n, _) in EDGE_SHAPES {
        let signs: Vec<bool> = (0..k * n).map(|_| r.below(2) == 1).collect();
        let x = rand_i8(&mut r, k);
        let w = pack_signs(&signs, k, n);
        let luts = build_luts(&x, k);
        let mut want = vec![0i32; n];
        lut_cols_scalar(std::slice::from_ref(&luts), &w, 0, &mut want);
        let mut got = vec![0i32; n];
        lut_gemv_into(&luts, &w, &mut got);
        assert_eq!(got, want, "lut gemv, k={k} n={n}");

        let vals: Vec<i8> = (0..k * n).map(|_| r.below(3) as i8 - 1).collect();
        let wt = pack_ternary(&vals, k, n);
        let tluts = build_ternary_luts(&x, k);
        let mut wantt = vec![0i32; n];
        ternary_cols_scalar(std::slice::from_ref(&tluts), &wt, 0, &mut wantt);
        let mut gott = vec![0i32; n];
        ternary_gemv_into(&tluts, &wt, &mut gott);
        assert_eq!(gott, wantt, "ternary gemv, k={k} n={n}");
    }
}

/// Force every mode the CPU can honor and require bit-identical outputs
/// from the public entry points. Sole writer of the process-global mode
/// in this binary; concurrent kernel calls elsewhere are unaffected
/// because all backends are bit-identical (the invariant under test).
#[test]
fn every_available_mode_is_bit_identical() {
    let mut r = Rng::new(90);
    let (k, n, b) = (130, 23, 5);
    let signs: Vec<bool> = (0..k * n).map(|_| r.below(2) == 1).collect();
    let tern: Vec<i8> = (0..k * n).map(|_| r.below(3) as i8 - 1).collect();
    let wi = rand_i8(&mut r, k * n);
    let wf = r.normal_vec(k * n);
    let xs = rand_i8(&mut r, b * k);
    let xf = r.normal_vec(b * k);

    let wp = pack_signs(&signs, k, n);
    let wt = pack_ternary(&tern, k, n);
    let luts: Vec<_> = (0..b).map(|row| build_luts(&xs[row * k..(row + 1) * k], k)).collect();
    let tluts: Vec<_> =
        (0..b).map(|row| build_ternary_luts(&xs[row * k..(row + 1) * k], k)).collect();

    let modes = simd::available_modes();
    assert!(modes.contains(&SimdMode::Scalar));
    let mut outs: Vec<(Vec<i32>, Vec<i32>, Vec<i32>, Vec<u32>, Vec<i32>)> = Vec::new();
    for &m in &modes {
        simd::set_simd_mode(m);
        let mut y1 = vec![0i32; n * b];
        lut_gemm_into(&luts, &wp, &mut y1);
        let mut y2 = vec![0i32; n * b];
        ternary_gemm_into(&tluts, &wt, &mut y2);
        let mut y3 = vec![0i32; n * b];
        i8_gemm_batch_into(&xs, &wi, b, k, n, &mut y3);
        let mut y4 = vec![0f32; n * b];
        f32_gemm_batch_into(&xf, &wf, b, k, n, &mut y4);
        let mut y5 = vec![0i32; n];
        lut_gemv_into(&luts[0], &wp, &mut y5);
        outs.push((y1, y2, y3, y4.iter().map(|v| v.to_bits()).collect(), y5));
    }
    simd::set_simd_mode(SimdMode::Auto);
    for (i, o) in outs.iter().enumerate().skip(1) {
        assert_eq!(o, &outs[0], "mode {:?} differs from {:?}", modes[i], modes[0]);
    }
}
