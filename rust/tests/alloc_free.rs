//! Allocation-freedom of the steady-state fused decode loop: once the
//! per-worker [`Scratch`] is warm, `decode_step_batch` must perform zero
//! heap allocations in the linear layers (ISSUE 4 acceptance), and the
//! speculative draft → verify → rollback round must stay allocation-free
//! too (ISSUE 5): proposals reuse the run/catch-up buffers, and rollback
//! recycles truncated KV blocks through the pool instead of freeing them.
//! The observability record path rides the same window (ISSUE 8): with
//! tracing disabled the engine's per-step metric writes are histogram
//! records and counter adds, and both must be lock- and allocation-free.
//! Verified with a counting global allocator; the kernel thread pool is
//! capped at one thread so scoped-thread spawning (a property of the
//! threading substrate, not of the decode path) doesn't obscure the
//! measurement.
//!
//! This file holds exactly one test: the counter is process-global, and a
//! sibling test allocating concurrently would make the window noisy.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use pquant::config::{ModelConfig, Variant};
use pquant::infer::{BatchKv, KvCache, PackedModel, Scratch, SeqStep};
use pquant::kvcache::{BlockPool, KvPoolOptions};
use pquant::obs::{Histogram, Registry};
use pquant::serve::SpecDecoder;

struct Counting;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static COUNTER: Counting = Counting;

fn step_once(
    model: &mut PackedModel,
    caches: &mut [Vec<KvCache>],
    scratch: &mut Scratch,
    pos: usize,
) {
    // Stack-only step construction: tokens and the step array must not
    // allocate, or the measurement would blame the caller, not the loop.
    let toks = [
        ((pos * 7) % 64) as u32,
        ((pos * 7 + 1) % 64) as u32,
        ((pos * 7 + 2) % 64) as u32,
        ((pos * 7 + 3) % 64) as u32,
    ];
    let [c0, c1, c2, c3] = caches else { panic!("expected 4 sequences") };
    let mut steps = [
        SeqStep::new(&toks[0..1], pos, BatchKv::Contig(&mut c0[..]), true),
        SeqStep::new(&toks[1..2], pos, BatchKv::Contig(&mut c1[..]), true),
        SeqStep::new(&toks[2..3], pos, BatchKv::Contig(&mut c2[..]), true),
        SeqStep::new(&toks[3..4], pos, BatchKv::Contig(&mut c3[..]), true),
    ];
    model.decode_step_batch(&mut steps, scratch);
    for s in &steps {
        assert!(s.err.is_none());
    }
}

#[test]
fn steady_state_batched_decode_is_allocation_free() {
    pquant::util::threads::set_thread_cap(1);
    let cfg = ModelConfig {
        name: "alloc-free".into(),
        variant: Variant::PQuant,
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 96,
        r: 16,
        n_experts: 2,
        seq_len: 64,
        alpha_init: 2.0,
        beta_init: 0.2,
    };
    let mut model = PackedModel::random(&cfg, 3);
    let cap = 64usize;
    let mut caches: Vec<Vec<KvCache>> = (0..4).map(|_| model.new_caches(cap)).collect();
    let mut scratch = Scratch::new();

    // Warm up past the power-of-two growth boundaries of the scores buffer
    // and the RoPE table (both jump 32 → 64 at position 32), so the
    // measured window 48..56 sits strictly inside existing capacity.
    for pos in 0..48 {
        step_once(&mut model, &mut caches, &mut scratch, pos);
    }
    let _ = scratch.take_grew(); // drain the warmup growth flag

    // The engine's per-step metric writes with tracing disabled: histogram
    // records + counter adds. Construction allocates (bucket array, name
    // interning), so both live outside the measured window.
    let hist = Histogram::new();
    let reg = Registry::new();
    let ctr = reg.counter_with("alloc_free_steps_total", &[("phase", "window")], "test counter");

    let before = ALLOCS.load(Ordering::SeqCst);
    for pos in 48..56 {
        step_once(&mut model, &mut caches, &mut scratch, pos);
        hist.record(pos as f64 * 0.37);
        ctr.add(1);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state fused decode (+ metric writes) allocated {} times in 8 steps",
        after - before
    );
    assert!(!scratch.take_grew(), "scratch must not have grown in the window");
    assert_eq!(hist.count(), 8);
    assert_eq!(ctr.get(), 8);

    // ---- speculative draft → verify → rollback loop (ISSUE 5) ----
    // A mismatched draft makes rejection (and therefore KV rollback) the
    // common case; the target pages KV so truncation exercises the
    // block-recycle path, not just a length rewind. The prompt is sized
    // past the 64-entry pow2 boundaries of the RoPE table and score
    // buffers, so the measured window sits strictly inside warm capacity.
    let mut draft = PackedModel::random(&cfg, 4);
    let pool = Arc::new(BlockPool::new(
        KvPoolOptions { n_blocks: 256, block_size: 16, ..Default::default() },
        cfg.n_layers,
        cfg.d_model,
    ));
    let prompt: Vec<u32> = (0..70).map(|i| ((i * 5) % 64) as u32).collect();
    let mut dec = SpecDecoder::new(3);
    // Throwaway session: warms the decoder's buffers and — by dropping its
    // paged sequence at the next begin — stocks the pool's recycle list,
    // so block materialization in the measured window pops instead of
    // allocating.
    dec.begin(&mut model, &mut draft, &prompt, 60, Some(&pool)).unwrap();
    for _ in 0..30 {
        if !dec.round(&mut model, &mut draft) {
            break;
        }
    }
    // Measured session: warm rounds, then the window.
    dec.begin(&mut model, &mut draft, &prompt, 200, Some(&pool)).unwrap();
    for _ in 0..6 {
        assert!(dec.round(&mut model, &mut draft), "budget must outlast the warmup");
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..6 {
        assert!(dec.round(&mut model, &mut draft), "budget must outlast the window");
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state speculative rounds allocated {} times in 6 rounds",
        after - before
    );
    assert!(dec.stats.verify_steps > 0 && dec.stats.proposed > 0);
    pquant::util::threads::set_thread_cap(0);
}
