//! End-to-end tests for the HTTP/SSE front end over a real socket:
//! bit-exact streaming vs. the reference decode, disconnect-cancel with
//! KV-pool drain, 429/503 backpressure round-trips, malformed-body 400s,
//! multi-model routing, and graceful-shutdown drain.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use pquant::config::{ModelConfig, Variant};
use pquant::infer::PackedModel;
use pquant::kvcache::KvPoolOptions;
use pquant::serve::{Engine, EngineOptions, HttpServer, ModelRegistry, Router};
use pquant::util::json::Json;

fn nano_cfg(name: &str) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        variant: Variant::PQuant,
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 96,
        r: 16,
        n_experts: 2,
        seq_len: 32,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

fn registry_with(name: &str, model: PackedModel) -> Arc<ModelRegistry> {
    let registry = Arc::new(ModelRegistry::new());
    registry.register(name, model, None);
    registry
}

fn engine_on(registry: &Arc<ModelRegistry>, name: &str) -> Arc<Engine> {
    Arc::new(
        Engine::start(
            registry,
            EngineOptions { model: name.into(), ..EngineOptions::default() },
        )
        .unwrap(),
    )
}

fn serve_one(model: PackedModel) -> (HttpServer, Arc<Engine>) {
    let registry = registry_with("m", model);
    let engine = engine_on(&registry, "m");
    let server =
        HttpServer::bind("127.0.0.1:0", Router::new(registry).route("m", engine.clone())).unwrap();
    (server, engine)
}

/// One-shot request: returns (status, headers, body-to-EOF).
fn http(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> (u16, HashMap<String, String>, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    s.flush().unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let (head, payload) = raw.split_once("\r\n\r\n").expect("response has a header block");
    let mut lines = head.lines();
    let status: u16 =
        lines.next().unwrap().split_whitespace().nth(1).unwrap().parse().unwrap();
    let headers: HashMap<String, String> = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    (status, headers, payload.to_string())
}

fn post_generate(addr: SocketAddr, body: &str) -> (u16, HashMap<String, String>, String) {
    http(addr, "POST", "/v1/generate", body)
}

/// Parse an SSE payload into (event-kind, data-json) frames.
fn sse_events(payload: &str) -> Vec<(String, Json)> {
    let mut out = Vec::new();
    for frame in payload.split("\n\n").filter(|f| !f.trim().is_empty()) {
        let mut kind = String::new();
        let mut data = None;
        for line in frame.lines() {
            if let Some(k) = line.strip_prefix("event: ") {
                kind = k.to_string();
            } else if let Some(d) = line.strip_prefix("data: ") {
                data = Some(Json::parse(d).expect("SSE data frames are JSON"));
            }
        }
        out.push((kind, data.expect("every frame carries data")));
    }
    out
}

fn streamed_tokens(events: &[(String, Json)]) -> Vec<u32> {
    events
        .iter()
        .filter(|(k, _)| k == "token")
        .map(|(_, d)| d.get("token").unwrap().as_usize().unwrap() as u32)
        .collect()
}

// --------------------------------------------------------------- streaming

#[test]
fn concurrent_sse_streams_are_bit_identical_to_reference_decode() {
    let model = PackedModel::random(&nano_cfg("http-stream"), 17);
    let mut reference = model.clone();
    let want = reference.generate(&[5, 9, 2], 10);
    let (server, engine) = serve_one(model);
    let addr = server.local_addr();

    let handles: Vec<_> = (0..3)
        .map(|_| {
            std::thread::spawn(move || {
                post_generate(addr, r#"{"prompt": [5, 9, 2], "n_new": 10}"#)
            })
        })
        .collect();
    for h in handles {
        let (status, headers, payload) = h.join().unwrap();
        assert_eq!(status, 200);
        assert_eq!(headers.get("content-type").unwrap(), "text/event-stream");
        let events = sse_events(&payload);
        // Frame order: prefilled, then tokens, then exactly one done.
        assert_eq!(events[0].0, "prefilled");
        assert_eq!(events[0].1.get("prompt_len").unwrap().as_usize().unwrap(), 3);
        assert_eq!(events.last().unwrap().0, "done");
        assert_eq!(streamed_tokens(&events), want);
        let done = &events.last().unwrap().1;
        assert_eq!(done.get("finish").unwrap().as_str().unwrap(), "length");
        assert_eq!(done.get("n_tokens").unwrap().as_usize().unwrap(), want.len());
        let done_tokens: Vec<u32> = done
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.as_usize().unwrap() as u32)
            .collect();
        assert_eq!(done_tokens, want, "done recap matches the streamed tokens");
    }
    server.shutdown();
    let metrics = engine.metrics();
    assert_eq!(metrics.completed.load(Ordering::Relaxed), 3);
}

#[test]
fn sampling_and_stop_fields_round_trip() {
    let model = PackedModel::random(&nano_cfg("http-stop"), 23);
    let mut reference = model.clone();
    let full = reference.generate(&[3, 1], 12);
    let stop = full[2];
    let cut = full.iter().position(|&t| t == stop).unwrap();
    let (server, _engine) = serve_one(model);

    let body = format!(r#"{{"prompt": [3, 1], "n_new": 12, "stop_tokens": [{stop}]}}"#);
    let (status, _, payload) = post_generate(server.local_addr(), &body);
    assert_eq!(status, 200);
    let events = sse_events(&payload);
    assert_eq!(streamed_tokens(&events), full[..=cut].to_vec());
    assert_eq!(
        events.last().unwrap().1.get("finish").unwrap().as_str().unwrap(),
        "stop"
    );
    server.shutdown();
}

// ------------------------------------------------------- disconnect-cancel

#[test]
fn mid_stream_disconnect_cancels_request_and_drains_kv_pool() {
    let registry = registry_with("m", PackedModel::random(&nano_cfg("http-cancel"), 29));
    // A pool sized so the long request fits (prompt 8 + 2000 new → 126
    // blocks of 16) but is clearly occupied while it runs. The 8-token
    // prompt stays under block_size, so completion registers no shared
    // prefix and the pool must drain all the way back to empty.
    let engine = Arc::new(
        Engine::start(
            &registry,
            EngineOptions {
                model: "m".into(),
                kv: Some(KvPoolOptions { n_blocks: 256, block_size: 16, ..Default::default() }),
                ..EngineOptions::default()
            },
        )
        .unwrap(),
    );
    let server = HttpServer::bind(
        "127.0.0.1:0",
        Router::new(registry).route("m", engine.clone()),
    )
    .unwrap();

    // Stream by hand: read a few token frames, then drop the socket.
    let mut s = TcpStream::connect(server.local_addr()).unwrap();
    let body = r#"{"prompt": [1, 2, 3, 4, 5, 6, 7, 8], "n_new": 2000}"#;
    write!(
        s,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut reader = BufReader::new(s);
    let mut tokens_seen = 0;
    let mut line = String::new();
    while tokens_seen < 3 {
        line.clear();
        assert!(reader.read_line(&mut line).unwrap() > 0, "stream ended early: {line:?}");
        if line.starts_with("event: token") {
            tokens_seen += 1;
        }
    }
    drop(reader); // client vanishes mid-stream

    // The server must notice, cancel the ticket, and the engine must hand
    // every KV block back (no shared prefix pins any — prompt < block).
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let cancelled = engine.metrics().cancelled.load(Ordering::Relaxed);
        let in_use = engine.metrics().kv().map(|kv| kv.in_use).unwrap_or(0);
        if cancelled == 1 && in_use == 0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "disconnect not reaped: cancelled={cancelled} kv_in_use={in_use}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(engine.metrics().completed.load(Ordering::Relaxed), 0);
    server.shutdown();
}

// ------------------------------------------------------------ backpressure

#[test]
fn queue_full_maps_to_429_with_retry_after() {
    let registry = registry_with("m", PackedModel::random(&nano_cfg("http-429"), 31));
    let engine = Arc::new(
        Engine::start(
            &registry,
            EngineOptions {
                model: "m".into(),
                max_batch: 1,
                workers: 1,
                queue_depth: 1,
                ..EngineOptions::default()
            },
        )
        .unwrap(),
    );
    let server = HttpServer::bind(
        "127.0.0.1:0",
        Router::new(registry).route("m", engine.clone()),
    )
    .unwrap();
    let addr = server.local_addr();

    // Park one long request on the single slot (read until its first
    // token so it is demonstrably decoding, keep the socket open).
    let mut held = TcpStream::connect(addr).unwrap();
    let body = r#"{"prompt": [1, 2], "n_new": 2000}"#;
    write!(
        held,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut held_reader = BufReader::new(&mut held);
    let mut line = String::new();
    loop {
        line.clear();
        assert!(held_reader.read_line(&mut line).unwrap() > 0);
        if line.starts_with("event: token") {
            break;
        }
    }

    // Burst more: with the slot busy and a depth-1 queue, at most one can
    // be absorbed — a 429 with retry guidance must appear.
    let mut saw_429 = false;
    let mut absorbed = Vec::new();
    for _ in 0..8 {
        let mut s = TcpStream::connect(addr).unwrap();
        let b = r#"{"prompt": [4, 5], "n_new": 500}"#;
        write!(
            s,
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{b}",
            b.len()
        )
        .unwrap();
        let mut r = BufReader::new(s);
        let mut status_line = String::new();
        r.read_line(&mut status_line).unwrap();
        let status: u16 =
            status_line.split_whitespace().nth(1).unwrap().parse().unwrap();
        if status == 429 {
            let mut headers = HashMap::new();
            loop {
                let mut h = String::new();
                r.read_line(&mut h).unwrap();
                if h.trim_end().is_empty() {
                    break;
                }
                if let Some((k, v)) = h.trim_end().split_once(':') {
                    headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
                }
            }
            let retry: u64 = headers
                .get("retry-after")
                .expect("429 carries Retry-After")
                .parse()
                .expect("Retry-After is integer seconds");
            assert!(retry >= 1, "HTTP floor is one second");
            let mut rest = String::new();
            r.read_to_string(&mut rest).unwrap();
            let j = Json::parse(rest.trim()).unwrap();
            assert!(
                j.get("retry_after_ms").unwrap().as_f64().unwrap() > 0.0,
                "body carries the precise millisecond hint"
            );
            saw_429 = true;
            break;
        }
        // Absorbed into the queue: keep the stream open so the slot stays
        // contended for the next attempt.
        absorbed.push(r);
    }
    assert!(saw_429, "burst against a depth-1 queue never overflowed");

    // Dropping every client lets the handlers cancel and the server drain.
    drop(held_reader);
    drop(held);
    drop(absorbed);
    server.shutdown();
}

// ---------------------------------------------------------- malformed input

#[test]
fn malformed_bodies_and_bad_routes_are_rejected() {
    let (server, _engine) = serve_one(PackedModel::random(&nano_cfg("http-400"), 37));
    let addr = server.local_addr();

    for bad in [
        "{not json",
        r#"{"n_new": 4}"#,                       // neither prompt nor text
        r#"{"prompt": "five"}"#,                // prompt not an array
        r#"{"prompt": [1.5]}"#,                 // non-integer token id
        r#"{"prompt": [1], "n_new": -3}"#,      // negative budget
        r#"{"text": "hi"}"#,                    // no tokenizer embedded
    ] {
        let (status, _, payload) = post_generate(addr, bad);
        assert_eq!(status, 400, "body {bad:?} must 400, got {status}: {payload}");
        assert!(Json::parse(&payload).unwrap().get("error").is_ok());
    }
    // Unknown model names are a routing miss, not a parse failure.
    let (status, _, _) = post_generate(addr, r#"{"prompt": [1], "model": "nope"}"#);
    assert_eq!(status, 404);
    let (status, _, _) = http(addr, "GET", "/v1/generate", "");
    assert_eq!(status, 405);
    let (status, _, _) = http(addr, "GET", "/v1/nope", "");
    assert_eq!(status, 404);
    server.shutdown();
}

// ----------------------------------------------------------- multi-model

#[test]
fn model_key_routes_between_engines() {
    let a = PackedModel::random(&nano_cfg("route-a"), 41);
    let b = PackedModel::random(&nano_cfg("route-b"), 43);
    let mut ref_a = a.clone();
    let mut ref_b = b.clone();
    let registry = Arc::new(ModelRegistry::new());
    registry.register("a", a, None);
    registry.register("b", b, None);
    let ea = engine_on(&registry, "a");
    let eb = engine_on(&registry, "b");
    let server = HttpServer::bind(
        "127.0.0.1:0",
        Router::new(registry).route("a", ea.clone()).route("b", eb.clone()),
    )
    .unwrap();
    let addr = server.local_addr();

    // Explicit routing, plus the first route as default.
    let (_, _, payload) = post_generate(addr, r#"{"prompt": [9, 9], "n_new": 6, "model": "b"}"#);
    assert_eq!(streamed_tokens(&sse_events(&payload)), ref_b.generate(&[9, 9], 6));
    let (_, _, payload) = post_generate(addr, r#"{"prompt": [9, 9], "n_new": 6}"#);
    assert_eq!(streamed_tokens(&sse_events(&payload)), ref_a.generate(&[9, 9], 6));

    // The registry listing marks both as routed.
    let (status, _, payload) = http(addr, "GET", "/v1/models", "");
    assert_eq!(status, 200);
    let models = Json::parse(&payload).unwrap();
    let listed = models.get("models").unwrap().as_arr().unwrap().to_vec();
    assert_eq!(listed.len(), 2);
    assert!(listed
        .iter()
        .all(|m| m.get("routed").unwrap().as_bool().unwrap()));

    // Metrics are keyed per routed engine and reflect the traffic split.
    let (status, _, payload) = http(addr, "GET", "/v1/metrics", "");
    assert_eq!(status, 200);
    let metrics = Json::parse(&payload).unwrap();
    assert_eq!(metrics.get("a").unwrap().get("completed").unwrap().as_usize().unwrap(), 1);
    assert_eq!(metrics.get("b").unwrap().get("completed").unwrap().as_usize().unwrap(), 1);
    assert!(metrics.get("a").unwrap().get("tpot_ms").is_ok());
    server.shutdown();
}

// ------------------------------------------------------- graceful shutdown

#[test]
fn graceful_shutdown_drains_inflight_streams() {
    let model = PackedModel::random(&nano_cfg("http-drain"), 47);
    let mut reference = model.clone();
    let want = reference.generate(&[2, 4], 150);
    let (server, engine) = serve_one(model);
    let addr = server.local_addr();

    // A client mid-stream when shutdown begins...
    let client = std::thread::spawn(move || {
        post_generate(addr, r#"{"prompt": [2, 4], "n_new": 150}"#)
    });
    // ...wait until its request is demonstrably in flight (tokens_out
    // ticks per emitted token, not at completion).
    let deadline = Instant::now() + Duration::from_secs(10);
    while engine.metrics().tokens_out.load(Ordering::Relaxed) == 0 {
        assert!(Instant::now() < deadline, "request never reached first token");
        std::thread::sleep(Duration::from_millis(5));
    }
    // shutdown() blocks until the handler finishes — the stream must have
    // run to its done frame, not been chopped.
    server.shutdown();
    let (status, _, payload) = client.join().unwrap();
    assert_eq!(status, 200);
    let events = sse_events(&payload);
    assert_eq!(events.last().unwrap().0, "done");
    assert_eq!(streamed_tokens(&events), want);
    assert_eq!(engine.metrics().completed.load(Ordering::Relaxed), 1);

    // The listener is gone: a new connection is refused, or at best
    // accepted by a dying socket that serves nothing.
    if let Ok(mut s) = TcpStream::connect(addr) {
        let _ = s.write_all(b"GET /v1/models HTTP/1.1\r\nConnection: close\r\n\r\n");
        let mut buf = [0u8; 1];
        let n = s.read(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "post-shutdown connections must get nothing");
    }
}
