//! Tests for the trace-driven load generator: seeded determinism of the
//! schedule, and smoke runs (in-process and over HTTP) whose SLO report
//! must reconcile with the engine's own metrics.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use pquant::config::{ModelConfig, Variant};
use pquant::infer::PackedModel;
use pquant::serve::loadgen::{self, Target, TraceConfig};
use pquant::serve::{build_trace, Engine, EngineOptions, HttpServer, ModelRegistry, Router};

fn nano_cfg(name: &str) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        variant: Variant::PQuant,
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 96,
        r: 16,
        n_experts: 2,
        seq_len: 32,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

fn engine_for(model: PackedModel) -> Engine {
    let registry = Arc::new(ModelRegistry::new());
    registry.register("m", model, None);
    Engine::start(
        &registry,
        EngineOptions { model: "m".into(), queue_depth: 256, ..EngineOptions::default() },
    )
    .unwrap()
}

/// A trace small and fast enough for CI: high arrival rate so the whole
/// schedule spans well under a second of wall clock.
fn smoke_cfg(seed: u64, n: usize) -> TraceConfig {
    TraceConfig {
        seed,
        n_requests: n,
        rate: 400.0,
        prompt_lens: vec![(4, 0.6), (8, 0.4)],
        output_lens: vec![(4, 0.5), (8, 0.5)],
        shared_prefix_len: 8,
        ..TraceConfig::default()
    }
}

// ------------------------------------------------------------- determinism

#[test]
fn same_seed_and_config_yield_identical_schedules() {
    let cfg = smoke_cfg(42, 200);
    let a = build_trace(&cfg);
    let b = build_trace(&cfg);
    assert_eq!(a, b, "trace must be a pure function of (config, seed)");
    assert_eq!(a.len(), 200);
    // Arrivals are sorted by construction and lengths come from the mix.
    assert!(a.windows(2).all(|w| w[0].at <= w[1].at));
    assert!(a.iter().all(|e| e.n_new == 4 || e.n_new == 8));
    assert!(a.iter().all(|e| e.tier < cfg.tiers.len()));
    assert!(a.iter().all(|e| e.prompt.iter().all(|&t| t < 64)));
}

#[test]
fn different_seeds_yield_different_schedules() {
    let a = build_trace(&smoke_cfg(1, 64));
    let b = build_trace(&smoke_cfg(2, 64));
    assert_ne!(a, b);
}

#[test]
fn shared_fraction_reuses_one_prefix() {
    let cfg = TraceConfig { shared_frac: 1.0, ..smoke_cfg(7, 32) };
    let trace = build_trace(&cfg);
    assert!(trace.iter().all(|e| e.shared));
    let prefix = &trace[0].prompt[..cfg.shared_prefix_len];
    assert!(
        trace.iter().all(|e| &e.prompt[..cfg.shared_prefix_len] == prefix),
        "every shared request opens with the same system prompt"
    );
    // Tails still differ (they carry the per-request payload).
    assert_ne!(trace[0].prompt, trace[1].prompt);
}

#[test]
fn mixture_spec_parses() {
    assert_eq!(loadgen::parse_mixture("4:0.5,8:0.5").unwrap(), vec![(4, 0.5), (8, 0.5)]);
    assert_eq!(loadgen::parse_mixture("16").unwrap(), vec![(16, 1.0)]);
    assert!(loadgen::parse_mixture("a:b").is_err());
}

// -------------------------------------------------------------- smoke runs

#[test]
fn engine_smoke_run_reconciles_with_serve_metrics() {
    let engine = engine_for(PackedModel::random(&nano_cfg("lg-engine"), 51));
    let cfg = smoke_cfg(3, 24);
    let report = loadgen::run(Target::Engine(&engine), &cfg).unwrap();

    assert_eq!(report.submitted, 24);
    assert_eq!(
        report.tiers.iter().map(|t| t.n).sum::<usize>(),
        24,
        "every request lands in exactly one tier"
    );
    let metrics = engine.shutdown();
    // Client-side and server-side accounting must agree: the generator
    // saw every completion the engine recorded, and every token.
    assert_eq!(report.completed, metrics.completed.load(Ordering::Relaxed));
    assert_eq!(report.tokens_out, metrics.tokens_out.load(Ordering::Relaxed));
    assert_eq!(report.completed + report.rejected, 24);
    for t in &report.tiers {
        assert!(t.slo_met <= t.completed);
        assert!(t.goodput >= 0.0 && t.goodput <= 1.0);
        assert_eq!(t.ttft.n, t.completed, "every completed request has a TTFT sample");
    }
    // The report serializes with the percentile fields the bench publishes.
    let j = report.to_json();
    assert!(j.get("goodput").is_ok());
    let tier0 = &j.get("tiers").unwrap().as_arr().unwrap()[0];
    assert!(tier0.get("ttft_ms").unwrap().get("p99").is_ok());
    assert!(tier0.get("tpot_ms").unwrap().get("p50").is_ok());
}

#[test]
fn http_smoke_run_reconciles_with_serve_metrics() {
    let registry = Arc::new(ModelRegistry::new());
    registry.register("m", PackedModel::random(&nano_cfg("lg-http"), 53), None);
    let engine = Arc::new(
        Engine::start(
            &registry,
            EngineOptions { model: "m".into(), queue_depth: 256, ..EngineOptions::default() },
        )
        .unwrap(),
    );
    let server =
        HttpServer::bind("127.0.0.1:0", Router::new(registry).route("m", engine.clone()))
            .unwrap();
    let cfg = smoke_cfg(5, 12);
    let report =
        loadgen::run(Target::Http(server.local_addr().to_string()), &cfg).unwrap();
    server.shutdown();

    assert_eq!(report.submitted, 12);
    assert_eq!(report.completed, engine.metrics().completed.load(Ordering::Relaxed));
    assert_eq!(report.tokens_out, engine.metrics().tokens_out.load(Ordering::Relaxed));
    assert!(report.completed > 0, "an uncontended engine must complete requests");
}
