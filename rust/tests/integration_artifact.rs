//! `.pqm` artifact integration tests: save→load must be *bit-identical* on
//! every packed plane and produce identical decode logits for all variants;
//! damaged files must be rejected (truncation, foreign magic, future
//! version, CRC corruption) instead of yielding garbage weights.  Also
//! covers the ModelRegistry serving path fed from a `.pqm` on disk.

use std::sync::Arc;
use std::time::Duration;

use pquant::artifact::{self, load_pqm_bytes, save_pqm_bytes};
use pquant::config::{ModelConfig, Variant};
use pquant::infer::block::Ffn;
use pquant::infer::PackedModel;
use pquant::serve::{Engine, EngineOptions, GenRequest, ModelRegistry};
use pquant::util::prop::check;
use pquant::util::rng::Rng;

const ALL_VARIANTS: [Variant; 4] =
    [Variant::Fp16, Variant::BitNet, Variant::BitNet158, Variant::PQuant];

fn nano_cfg(variant: Variant) -> ModelConfig {
    ModelConfig {
        name: format!("artifact-{}", variant.name()),
        variant,
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 96,
        r: if variant == Variant::PQuant { 16 } else { 0 },
        n_experts: if variant == Variant::PQuant { 2 } else { 1 },
        seq_len: 16,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

/// Assert every weight container of two models is exactly equal (packed
/// planes byte-for-byte, scales bit-for-bit).
fn assert_models_identical(a: &PackedModel, b: &PackedModel) {
    assert_eq!(a.cfg, b.cfg);
    assert_eq!(a.embed, b.embed);
    assert_eq!(a.lm_head, b.lm_head);
    assert_eq!(a.final_norm, b.final_norm);
    assert_eq!(a.blocks.len(), b.blocks.len());
    for (l, (ba, bb)) in a.blocks.iter().zip(&b.blocks).enumerate() {
        assert_eq!(ba.attn_norm, bb.attn_norm, "block {l} attn_norm");
        assert_eq!(ba.ffn_norm, bb.ffn_norm, "block {l} ffn_norm");
        assert_eq!(ba.n_heads, bb.n_heads, "block {l} n_heads");
        assert!(ba.wq == bb.wq, "block {l} wq plane mismatch");
        assert!(ba.wk == bb.wk, "block {l} wk plane mismatch");
        assert!(ba.wv == bb.wv, "block {l} wv plane mismatch");
        assert!(ba.wo == bb.wo, "block {l} wo plane mismatch");
        match (&ba.ffn, &bb.ffn) {
            (Ffn::Dense { up: ua, down: da }, Ffn::Dense { up: ub, down: db }) => {
                assert!(ua == ub && da == db, "block {l} dense ffn mismatch");
            }
            (Ffn::Decoupled(da), Ffn::Decoupled(db)) => {
                assert!(da.up_1bit == db.up_1bit, "block {l} up_1bit mismatch");
                assert!(da.down_1bit == db.down_1bit, "block {l} down_1bit mismatch");
                assert_eq!(da.experts.len(), db.experts.len());
                for (e, (ea, eb)) in da.experts.iter().zip(&db.experts).enumerate() {
                    assert!(ea.0 == eb.0 && ea.1 == eb.1, "block {l} expert {e} mismatch");
                }
                assert_eq!(da.router, db.router, "block {l} router");
                assert_eq!(da.alpha, db.alpha, "block {l} alpha");
                assert_eq!(da.beta, db.beta, "block {l} beta");
            }
            _ => panic!("block {l}: FFN kind changed across save/load"),
        }
    }
}

#[test]
fn roundtrip_is_bit_identical_for_all_variants() {
    for v in ALL_VARIANTS {
        let model = PackedModel::random(&nano_cfg(v), 21);
        let loaded = load_pqm_bytes(&save_pqm_bytes(&model, None))
            .unwrap_or_else(|e| panic!("{v:?}: {e:#}"))
            .model;
        assert_models_identical(&model, &loaded);
    }
}

#[test]
fn roundtrip_preserves_decode_logits_exactly() {
    for v in ALL_VARIANTS {
        let mut model = PackedModel::random(&nano_cfg(v), 33);
        let mut loaded = load_pqm_bytes(&save_pqm_bytes(&model, None)).unwrap().model;
        let mut caches_a = model.new_caches(8);
        let mut caches_b = loaded.new_caches(8);
        for (pos, &tok) in [3u32, 1, 4, 1, 5].iter().enumerate() {
            let la = model.decode_step(tok, pos, &mut caches_a);
            let lb = loaded.decode_step(tok, pos, &mut caches_b);
            assert_eq!(la, lb, "{v:?}: logits diverge at pos {pos}");
        }
    }
}

#[test]
fn roundtrip_property_over_random_geometries() {
    check(
        31,
        12,
        |r: &mut Rng| {
            let variant = ALL_VARIANTS[r.below(4)];
            let n_heads = 1 + r.below(3);
            let d_model = n_heads * 2 * (1 + r.below(4)); // even head_dim for RoPE
            let rr = if variant == Variant::PQuant { 4 * (1 + r.below(3)) } else { 0 };
            let cfg = ModelConfig {
                name: "prop-artifact".into(),
                variant,
                vocab: 32 + r.below(64),
                d_model,
                n_layers: 1 + r.below(3),
                n_heads,
                d_ff: rr + 8 + r.below(40),
                r: rr,
                n_experts: if variant == Variant::PQuant { 1 + r.below(3) } else { 1 },
                seq_len: 16,
                alpha_init: 2.0,
                beta_init: 0.2,
            };
            (cfg, r.next_u64())
        },
        |(cfg, seed)| {
            let mut model = PackedModel::random(cfg, *seed);
            let bytes = save_pqm_bytes(&model, None);
            let mut loaded = match load_pqm_bytes(&bytes) {
                Ok(l) => l.model,
                Err(e) => return Err(format!("load failed: {e:#}")),
            };
            if loaded.generate(&[1, 2], 4) != model.generate(&[1, 2], 4) {
                return Err("generation diverged after round-trip".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------- damage

#[test]
fn truncated_files_are_rejected() {
    let bytes = save_pqm_bytes(&PackedModel::random(&nano_cfg(Variant::PQuant), 1), None);
    // Every prefix that cuts the header, the table, or a payload must fail
    // with a truncation error — never panic, never return a model.
    for cut in [0, 1, 7, 8, 15, 16, 40, bytes.len() / 2, bytes.len() - 1] {
        let err = load_pqm_bytes(&bytes[..cut])
            .err()
            .unwrap_or_else(|| panic!("cut at {cut} bytes must fail"));
        assert!(err.to_string().contains("truncated"), "cut {cut}: {err:#}");
    }
}

#[test]
fn bad_magic_is_rejected() {
    let mut bytes = save_pqm_bytes(&PackedModel::random(&nano_cfg(Variant::BitNet), 2), None);
    bytes[1] = b'X';
    let err = load_pqm_bytes(&bytes).unwrap_err().to_string();
    assert!(err.contains("magic"), "{err}");
    // A checkpoint-like file is also refused up front.
    let err = load_pqm_bytes(b"PQCK1\0not-a-packed-model-artifact")
        .unwrap_err()
        .to_string();
    assert!(err.contains("magic"), "{err}");
}

#[test]
fn future_version_is_rejected() {
    let mut bytes = save_pqm_bytes(&PackedModel::random(&nano_cfg(Variant::Fp16), 3), None);
    bytes[8..12].copy_from_slice(&2u32.to_le_bytes());
    let err = load_pqm_bytes(&bytes).unwrap_err().to_string();
    assert!(err.contains("version 2"), "{err}");
}

#[test]
fn corrupted_payload_fails_crc_not_garbage() {
    let model = PackedModel::random(&nano_cfg(Variant::PQuant), 4);
    let clean = save_pqm_bytes(&model, None);
    // Flip one bit in several payload positions (past header + table);
    // every corruption must surface as a CRC error.
    let payload_start = clean.len() - 64;
    for (i, pos) in [payload_start, payload_start + 17, clean.len() - 1].iter().enumerate() {
        let mut bytes = clean.clone();
        bytes[*pos] ^= 1 << (i % 8);
        let err = load_pqm_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("CRC"), "corruption at {pos}: {err}");
    }
}

#[test]
fn disk_roundtrip_and_corruption_via_files() {
    let dir = std::env::temp_dir().join(format!("pqm_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.pqm");

    let mut model = PackedModel::random(&nano_cfg(Variant::PQuant), 5);
    let written = artifact::save_pqm(&model, None, &path).unwrap();
    assert_eq!(written, std::fs::metadata(&path).unwrap().len());

    let mut loaded = artifact::load_pqm(&path).unwrap().model;
    assert_eq!(loaded.generate(&[7, 3], 6), model.generate(&[7, 3], 6));

    // Corrupt the file on disk: load must fail with a CRC error.
    let mut bytes = std::fs::read(&path).unwrap();
    let last = bytes.len() - 5;
    bytes[last] ^= 0x10;
    std::fs::write(&path, &bytes).unwrap();
    let err = artifact::load_pqm(&path).err().expect("corrupt file must fail");
    assert!(format!("{err:#}").contains("CRC"), "{err:#}");

    std::fs::remove_dir_all(&dir).ok();
}

// -------------------------------------------------------------- registry

#[test]
fn registry_serves_identical_tokens_from_disk_artifact() {
    let dir = std::env::temp_dir().join(format!("pqm_reg_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("serve.pqm");

    let mut source = PackedModel::random(&nano_cfg(Variant::PQuant), 6);
    artifact::save_pqm(&source, None, &path).unwrap();

    let registry = Arc::new(ModelRegistry::new());
    registry.load_pqm("pquant", &path).unwrap();

    // Serve through the engine with two workers…
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "pquant".into(),
            max_batch: 2,
            workers: 2,
            ..EngineOptions::default()
        },
    )
    .unwrap();
    let tickets: Vec<_> = (0..6)
        .map(|_| engine.submit(GenRequest::greedy(vec![2, 8], 5)).unwrap())
        .collect();

    // …and every response must match the in-memory source model exactly
    // (the export → load → serve acceptance criterion).
    let want = source.generate(&[2, 8], 5);
    for t in tickets {
        assert_eq!(t.wait().tokens, want, "served tokens diverge from in-memory model");
    }
    engine.shutdown();

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn registry_hot_swap_from_disk_changes_served_model() {
    let dir = std::env::temp_dir().join(format!("pqm_swap_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let a = PackedModel::random(&nano_cfg(Variant::BitNet), 7);
    let b = PackedModel::random(&nano_cfg(Variant::BitNet158), 8);
    let path_a = dir.join("a.pqm");
    let path_b = dir.join("b.pqm");
    artifact::save_pqm(&a, None, &path_a).unwrap();
    artifact::save_pqm(&b, None, &path_b).unwrap();

    let registry = ModelRegistry::new();
    registry.load_pqm("edge", &path_a).unwrap();
    assert_eq!(registry.acquire("edge").unwrap().model.cfg.variant, Variant::BitNet);

    let report = registry
        .hot_swap_pqm("edge", &path_b, Duration::from_secs(2))
        .unwrap();
    assert_eq!(report.generation, 2);
    assert!(report.drained, "no leases were outstanding");
    assert_eq!(registry.acquire("edge").unwrap().model.cfg.variant, Variant::BitNet158);

    std::fs::remove_dir_all(&dir).ok();
}
