//! Regression for the row-straddling parallel GEMM split, driven through
//! the public entry points with a real thread cap.
//!
//! The old `f32_gemm`/`i8_gemm` split their output with the plain
//! (non-granular) splitter and derived each chunk's first row as
//! `start / n` — only correct when chunk boundaries happen to land on row
//! boundaries. With 2 threads and m=3, n=10 the 30-element output split
//! 15+15: the second chunk started mid-row, computed with the wrong
//! activation row, and dropped the trailing half-row. This binary owns
//! the process-global thread cap (`set_thread_cap`), so it lives alone —
//! sibling tests inside it must tolerate the cap while it's held.

use pquant::gemm::{f32_gemm, i8_gemm};
use pquant::util::rng::Rng;
use pquant::util::threads::set_thread_cap;

fn naive_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            c[i * n + j] = (0..k).map(|kk| a[i * k + kk] * b[kk * n + j]).sum();
        }
    }
    c
}

fn naive_i8(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        for j in 0..n {
            c[i * n + j] = (0..k).map(|kk| a[i * k + kk] as i32 * b[kk * n + j] as i32).sum();
        }
    }
    c
}

#[test]
fn capped_threads_never_straddle_rows() {
    let mut r = Rng::new(55);
    // Shapes where chunk size is not a multiple of n under small caps —
    // exactly the geometries the old splitter got wrong. (3, _, 10) with
    // cap 2 is the minimal reproducer: 30 elems → 15+15.
    let shapes = [(3usize, 8usize, 10usize), (5, 16, 6), (7, 4, 9), (2, 3, 3), (4, 10, 25)];
    for cap in [2usize, 3] {
        set_thread_cap(cap);
        for &(m, k, n) in &shapes {
            let a = r.normal_vec(m * k);
            let b = r.normal_vec(k * n);
            let got = f32_gemm(&a, &b, m, k, n);
            let want = naive_f32(&a, &b, m, k, n);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-3 * (1.0 + w.abs()),
                    "cap={cap} m={m} k={k} n={n} elem {i}: {g} vs {w}"
                );
            }

            let ai: Vec<i8> = (0..m * k).map(|_| (r.below(255) as i32 - 127) as i8).collect();
            let bi: Vec<i8> = (0..k * n).map(|_| (r.below(255) as i32 - 127) as i8).collect();
            assert_eq!(
                i8_gemm(&ai, &bi, m, k, n),
                naive_i8(&ai, &bi, m, k, n),
                "cap={cap} m={m} k={k} n={n}"
            );
        }
    }
    set_thread_cap(0);
}
