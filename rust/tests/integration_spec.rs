//! Speculative decoding end to end: greedy bit-exactness properties,
//! seeded-sampling determinism across batch sizes, fault injection
//! (cancel mid-verify, stop token inside an accepted run, draft-KV
//! exhaustion, preemption — each must return every draft *and* target
//! block to its pool and emit a correct terminal event), and
//! registry-side draft validation / hot-swap.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use pquant::config::{ModelConfig, Variant};
use pquant::infer::PackedModel;
use pquant::kvcache::{BlockPool, KvPoolOptions};
use pquant::serve::{
    DraftError, Engine, EngineOptions, Event, FinishReason, GenRequest, ModelRegistry,
    SamplingParams, SpecDecoder, SubmitError,
};
use pquant::util::prop::check;

fn nano_cfg(name: &str, vocab: usize, n_layers: usize, d_model: usize) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        variant: Variant::PQuant,
        vocab,
        d_model,
        n_layers,
        n_heads: 2,
        d_ff: 3 * d_model,
        r: d_model / 2,
        n_experts: 2,
        seq_len: 32,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

fn registry_with(name: &str, model: PackedModel) -> Arc<ModelRegistry> {
    let registry = Arc::new(ModelRegistry::new());
    registry.register(name, model, None);
    registry
}

/// Every pool the engine draws KV from must be fully free after a drain.
/// Frozen prompt prefixes retained by the target pool's share map are
/// cache, not leaks — evicting the (now unused) entries must return the
/// pool to `free == total`; anything left is a leaked request block.
/// Draft pools never register prefixes, so they must already be free.
fn assert_pools_drained(pool: Option<Arc<BlockPool>>, metrics: &pquant::serve::ServeMetrics) {
    if let Some(p) = pool {
        p.evict_unused();
        let kv = p.stats();
        assert_eq!(kv.in_use, 0, "target pool holds {} blocks after drain", kv.in_use);
    }
    for kv in metrics.draft_kv() {
        assert_eq!(kv.in_use, 0, "a draft pool holds {} blocks after drain", kv.in_use);
    }
}

// ------------------------------------------------- greedy bit-exactness

/// One generated property case: seeds, geometry, and request shape.
#[derive(Debug)]
struct Case {
    target_seed: u64,
    draft_seed: u64,
    self_draft: bool,
    vocab: usize,
    k: usize,
    prompt: Vec<u32>,
    n_new: usize,
}

#[test]
fn spec_greedy_is_bit_identical_to_generate_property() {
    // Random (target, draft, prompt, K) combinations — including
    // draft == target — through a live engine: speculative greedy output
    // must equal the unbatched reference decode exactly.
    check(
        0x5bec,
        6,
        |rng| {
            let vocab = 48 + rng.below(32); // 48..80
            Case {
                target_seed: rng.next_u64(),
                draft_seed: rng.next_u64(),
                self_draft: rng.below(3) == 0,
                vocab,
                k: 1 + rng.below(5),
                prompt: (0..2 + rng.below(10)).map(|_| rng.below(vocab) as u32).collect(),
                n_new: 1 + rng.below(24),
            }
        },
        |case| {
            let cfg = nano_cfg("spec-prop-t", case.vocab, 2, 32);
            let target = PackedModel::random(&cfg, case.target_seed);
            let mut reference = target.clone();
            let draft = if case.self_draft {
                target.clone()
            } else {
                // Different weights, depth and width — only vocab matters.
                PackedModel::random(
                    &nano_cfg("spec-prop-d", case.vocab, 1, 16),
                    case.draft_seed,
                )
            };
            let want = reference.generate(&case.prompt, case.n_new);

            let registry = registry_with("m", target);
            registry.register("d", draft, None);
            let engine = Engine::start(
                &registry,
                EngineOptions { model: "m".into(), max_batch: 3, ..EngineOptions::default() },
            )
            .unwrap();
            // Mixed speculative and plain requests in one fused round.
            let spec_t = engine
                .submit(GenRequest::greedy(case.prompt.clone(), case.n_new).with_spec("d", case.k))
                .unwrap();
            let plain_t =
                engine.submit(GenRequest::greedy(case.prompt.clone(), case.n_new)).unwrap();
            let spec2_t = engine
                .submit(GenRequest::greedy(case.prompt.clone(), case.n_new).with_spec("d", case.k))
                .unwrap();
            let (spec, plain, spec2) = (spec_t.wait(), plain_t.wait(), spec2_t.wait());
            if spec.tokens != want {
                return Err(format!("speculative greedy diverged (k={})", case.k));
            }
            if plain.tokens != want {
                return Err("plain greedy diverged next to speculation".into());
            }
            if spec2.tokens != want {
                return Err("second speculative stream diverged".into());
            }
            if spec.finish != FinishReason::Length {
                return Err(format!("wrong finish {:?}", spec.finish));
            }
            let pool = engine.kv_pool().cloned();
            let metrics = engine.shutdown();
            assert_pools_drained(pool, &metrics);
            if case.self_draft
                && metrics.accepted_tokens.load(Ordering::Relaxed)
                    != metrics.draft_tokens.load(Ordering::Relaxed)
            {
                return Err("draft == target must accept every proposal".into());
            }
            Ok(())
        },
    );
}

#[test]
fn self_draft_acceptance_is_total_and_multiplies_tokens_per_verify() {
    let cfg = nano_cfg("spec-self", 64, 2, 32);
    let target = PackedModel::random(&cfg, 17);
    let mut reference = target.clone();
    let registry = registry_with("m", target.clone());
    registry.register("d", target, None);
    let engine = Engine::start(
        &registry,
        EngineOptions { model: "m".into(), max_batch: 2, ..EngineOptions::default() },
    )
    .unwrap();
    let stats = engine
        .submit(GenRequest::greedy(vec![7, 3, 1], 40).with_spec("d", 4))
        .unwrap()
        .wait();
    assert_eq!(stats.tokens, reference.generate(&[7, 3, 1], 40));
    let pool = engine.kv_pool().cloned();
    let metrics = engine.shutdown();
    assert!(metrics.draft_tokens.load(Ordering::Relaxed) > 0);
    assert_eq!(
        metrics.acceptance_rate(),
        1.0,
        "identical draft and target must agree on every token"
    );
    // All-accepted verify runs emit k+1 tokens each (modulo the clamped
    // final round), so the mean must sit well above plain decode's 1.
    assert!(
        metrics.spec_tokens_per_verify() > 3.0,
        "tokens/verify {} too low for a perfect draft",
        metrics.spec_tokens_per_verify()
    );
    assert_eq!(metrics.spec_requests.load(Ordering::Relaxed), 1);
    assert_pools_drained(pool, &metrics);
}

// ------------------------------------------- seeded-sampling determinism

#[test]
fn seeded_spec_sampling_is_deterministic_across_max_batch_1_vs_6() {
    let cfg = nano_cfg("spec-seeded", 64, 2, 32);
    let target = PackedModel::random(&cfg, 23);
    let draft = PackedModel::random(&nano_cfg("spec-seeded-d", 64, 1, 16), 24);
    let registry = registry_with("m", target);
    registry.register("d", draft, None);
    let run = |max_batch: usize| -> Vec<Vec<u32>> {
        let engine = Engine::start(
            &registry,
            EngineOptions { model: "m".into(), max_batch, ..EngineOptions::default() },
        )
        .unwrap();
        let tickets: Vec<_> = (0..4)
            .map(|i| {
                let sampling = SamplingParams {
                    temperature: 0.8,
                    top_k: 8,
                    seed: 1000 + i,
                    stop_tokens: vec![],
                };
                engine
                    .submit(
                        GenRequest::sampled(vec![5, 9, 2], 12, sampling).with_spec("d", 3),
                    )
                    .unwrap()
            })
            .collect();
        let out: Vec<Vec<u32>> = tickets.into_iter().map(|t| t.wait().tokens).collect();
        let pool = engine.kv_pool().cloned();
        let metrics = engine.shutdown();
        assert_pools_drained(pool, &metrics);
        out
    };
    let solo = run(1);
    let batched = run(6);
    assert_eq!(solo, batched, "seeded speculative streams must not depend on batching");
    for s in &solo {
        assert_eq!(s.len(), 12);
        assert!(s.iter().all(|&t| t < 64));
    }
}

// ------------------------------------------------------- fault injection

#[test]
fn cancel_mid_verify_returns_all_draft_and_target_blocks() {
    let cfg = nano_cfg("spec-cancel", 64, 2, 32);
    let registry = registry_with("m", PackedModel::random(&cfg, 31));
    registry.register("d", PackedModel::random(&nano_cfg("spec-cancel-d", 64, 1, 16), 32), None);
    let engine = Engine::start(
        &registry,
        EngineOptions { model: "m".into(), max_batch: 2, ..EngineOptions::default() },
    )
    .unwrap();
    let ticket = engine
        .submit(GenRequest::greedy(vec![1, 2], 4000).with_spec("d", 4))
        .unwrap();
    // Let several verify rounds land so cancellation hits a live
    // draft+target speculative state, not the prefill.
    let mut seen = 0;
    while seen < 6 {
        if let Event::Token(_) = ticket.recv().unwrap() {
            seen += 1;
        }
    }
    ticket.cancel();
    let stats = ticket.wait();
    assert_eq!(stats.finish, FinishReason::Cancelled, "cancel must end the stream");
    assert!(stats.tokens.len() >= 6 && stats.tokens.len() < 4000);
    let pool = engine.kv_pool().cloned();
    let metrics = engine.shutdown();
    assert_eq!(metrics.cancelled.load(Ordering::Relaxed), 1);
    assert!(metrics.verify_steps.load(Ordering::Relaxed) > 0, "speculation must have run");
    assert_pools_drained(pool, &metrics);
}

#[test]
fn stop_token_inside_an_accepted_draft_run_finishes_with_stop() {
    let cfg = nano_cfg("spec-stop", 64, 2, 32);
    let target = PackedModel::random(&cfg, 41);
    let mut reference = target.clone();
    // A perfect draft guarantees the stop token arrives *inside* an
    // accepted run (k=6 covers the cut position), not as a phase-1
    // sample.
    let full = reference.generate(&[3, 1], 24);
    let stop = full[4];
    let cut = full.iter().position(|&t| t == stop).unwrap();
    let registry = registry_with("m", target.clone());
    registry.register("d", target, None);
    let engine = Engine::start(
        &registry,
        EngineOptions { model: "m".into(), max_batch: 2, ..EngineOptions::default() },
    )
    .unwrap();
    let req = GenRequest::sampled(
        vec![3, 1],
        24,
        SamplingParams { stop_tokens: vec![stop], ..SamplingParams::greedy() },
    )
    .with_spec("d", 6);
    let stats = engine.submit(req).unwrap().wait();
    assert_eq!(stats.finish, FinishReason::Stop);
    assert_eq!(stats.tokens, full[..=cut].to_vec(), "stop token included, later drafts dropped");
    let pool = engine.kv_pool().cloned();
    let metrics = engine.shutdown();
    assert!(metrics.verify_steps.load(Ordering::Relaxed) > 0);
    assert_pools_drained(pool, &metrics);
}

#[test]
fn draft_kv_exhaustion_degrades_to_plain_and_stays_bit_exact() {
    let cfg = nano_cfg("spec-dry", 64, 2, 32);
    let target = PackedModel::random(&cfg, 51);
    let mut reference = target.clone();
    let registry = registry_with("m", target);
    registry.register("d", PackedModel::random(&nano_cfg("spec-dry-d", 64, 1, 16), 52), None);
    // A one-block draft pool can never cover a draft reservation, so the
    // draft cannot expand — the request must degrade to plain decode and
    // still finish correctly.
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "m".into(),
            max_batch: 2,
            draft_kv: Some(KvPoolOptions { n_blocks: 1, block_size: 4, ..Default::default() }),
            ..EngineOptions::default()
        },
    )
    .unwrap();
    let stats = engine
        .submit(GenRequest::greedy(vec![9, 8, 7], 16).with_spec("d", 4))
        .unwrap()
        .wait();
    assert_eq!(stats.finish, FinishReason::Length, "degrade must not fail the request");
    assert_eq!(stats.tokens, reference.generate(&[9, 8, 7], 16));
    let pool = engine.kv_pool().cloned();
    let metrics = engine.shutdown();
    assert!(
        metrics.spec_degraded.load(Ordering::Relaxed) >= 1,
        "the dry draft pool must be observed"
    );
    assert_eq!(metrics.verify_steps.load(Ordering::Relaxed), 0, "no verify without a draft");
    assert_pools_drained(pool, &metrics);
}

#[test]
fn draft_pool_contention_degrades_the_loser_only() {
    let cfg = nano_cfg("spec-contend", 64, 2, 32);
    let target = PackedModel::random(&cfg, 61);
    let mut reference = target.clone();
    let registry = registry_with("m", target);
    let draft_cfg = nano_cfg("spec-contend-d", 64, 1, 16);
    registry.register("d", PackedModel::random(&draft_cfg, 62), None);
    // The draft pool fits exactly one request's draft reservation:
    // 3 + 24 + 4 = 31 tokens over 16-token blocks -> 2 x 1 layer = 2.
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "m".into(),
            max_batch: 2,
            draft_kv: Some(KvPoolOptions { n_blocks: 2, block_size: 16, ..Default::default() }),
            ..EngineOptions::default()
        },
    )
    .unwrap();
    let a = engine.submit(GenRequest::greedy(vec![1, 2, 3], 24).with_spec("d", 4)).unwrap();
    let b = engine.submit(GenRequest::greedy(vec![1, 2, 3], 24).with_spec("d", 4)).unwrap();
    let want = reference.generate(&[1, 2, 3], 24);
    assert_eq!(a.wait().tokens, want);
    assert_eq!(b.wait().tokens, want, "the degraded loser still decodes correctly");
    let pool = engine.kv_pool().cloned();
    let metrics = engine.shutdown();
    assert_pools_drained(pool, &metrics);
}

#[test]
fn preempted_speculative_request_resumes_and_finishes_bit_exact() {
    let cfg = nano_cfg("spec-preempt", 64, 2, 32);
    let target = PackedModel::random(&cfg, 71);
    let mut reference = target.clone();
    let registry = registry_with("m", target.clone());
    registry.register("d", target, None);
    // Target pool fits exactly one long request: 4 + 200 tokens over
    // 8-token blocks -> 26 logical x 2 layers = 52 blocks.
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "m".into(),
            max_batch: 4,
            kv: Some(KvPoolOptions { n_blocks: 52, block_size: 8, ..Default::default() }),
            ..EngineOptions::default()
        },
    )
    .unwrap();
    let low = engine
        .submit(GenRequest::greedy(vec![1, 2, 3, 4], 200).with_spec("d", 4))
        .unwrap();
    loop {
        match low.recv().expect("stream open") {
            Event::Token(_) => break,
            _ => {}
        }
    }
    let high_req = GenRequest::greedy(vec![9, 8, 7, 6], 200).with_priority(5);
    let high = match engine.submit(high_req) {
        // The flagged preemption frees the low request's blocks; the
        // blocking retry claims them.
        Err(SubmitError::KvExhausted(req, _)) => engine.submit_blocking(req).unwrap(),
        Ok(t) => t, // only possible if low finished first; asserts below catch it
        Err(e) => panic!("unexpected submit error: {e}"),
    };
    assert_eq!(high.wait().tokens, reference.generate(&[9, 8, 7, 6], 200));
    // The preempted speculative request resumes (draft state rebuilt from
    // scratch) and continues the identical greedy stream.
    let low_stats = low.wait();
    assert_eq!(low_stats.finish, FinishReason::Length);
    assert_eq!(low_stats.tokens, reference.generate(&[1, 2, 3, 4], 200));
    let pool = engine.kv_pool().cloned();
    let metrics = engine.shutdown();
    assert_eq!(metrics.preempted.load(Ordering::Relaxed), 1, "exactly one preemption");
    assert!(
        metrics.spec_requests.load(Ordering::Relaxed) <= 1,
        "a preempt/resume cycle must not double-count the speculative request"
    );
    assert_pools_drained(pool, &metrics);
}

// ------------------------------------------- registry / artifact negatives

#[test]
fn vocab_incompatible_draft_is_rejected_at_submit_with_typed_error() {
    let registry = registry_with("m", PackedModel::random(&nano_cfg("t", 64, 2, 32), 81));
    // Same width and depth, different vocab — the one thing that matters.
    registry.register("d", PackedModel::random(&nano_cfg("d", 48, 2, 32), 82), None);
    let engine = Engine::start(
        &registry,
        EngineOptions { model: "m".into(), ..EngineOptions::default() },
    )
    .unwrap();
    match engine.submit(GenRequest::greedy(vec![1, 2], 8).with_spec("d", 4)) {
        Err(SubmitError::DraftRejected(req, e)) => {
            assert_eq!(req.n_new, 8, "request rides back in the error");
            assert_eq!(e, DraftError::VocabMismatch { draft: 48, target: 64 });
        }
        other => panic!(
            "expected DraftRejected, got {:?}",
            other.map(|_| ()).map_err(|e| e.to_string())
        ),
    }
    match engine.submit(GenRequest::greedy(vec![1, 2], 8).with_spec("missing", 4)) {
        Err(SubmitError::DraftRejected(_, DraftError::UnknownModel(name))) => {
            assert_eq!(name, "missing");
        }
        other => panic!(
            "expected UnknownModel, got {:?}",
            other.map(|_| ()).map_err(|e| e.to_string())
        ),
    }
    // The engine keeps serving plain requests after the rejections.
    assert_eq!(engine.submit(GenRequest::greedy(vec![1, 2], 4)).unwrap().wait().tokens.len(), 4);
    engine.shutdown();
}

#[test]
fn pqm_round_tripped_draft_with_wrong_vocab_is_rejected_not_panicked() {
    // The draft arrives the way production drafts do — through the `.pqm`
    // artifact codec — and its header-declared vocab disagrees with the
    // target's: submit must reject with the typed error, and the worker
    // must never see it.
    let target = PackedModel::random(&nano_cfg("t", 64, 2, 32), 91);
    let bad_draft = PackedModel::random(&nano_cfg("bad-draft", 32, 1, 16), 92);
    let bytes = pquant::artifact::save_pqm_bytes(&bad_draft, None);
    let loaded = pquant::artifact::load_pqm_bytes(&bytes).expect("valid artifact");
    let registry = registry_with("m", target);
    registry.register("d", loaded.model, None);
    let engine = Engine::start(
        &registry,
        EngineOptions { model: "m".into(), ..EngineOptions::default() },
    )
    .unwrap();
    match engine.submit(GenRequest::greedy(vec![1], 6).with_spec("d", 3)) {
        Err(SubmitError::DraftRejected(_, DraftError::VocabMismatch { draft, target })) => {
            assert_eq!((draft, target), (32, 64));
        }
        other => panic!(
            "expected VocabMismatch, got {:?}",
            other.map(|_| ()).map_err(|e| e.to_string())
        ),
    }
    engine.shutdown();
}

#[test]
fn hot_swapping_the_draft_under_load_keeps_streams_lossless() {
    let cfg = nano_cfg("spec-swap", 64, 2, 32);
    let target = PackedModel::random(&cfg, 101);
    let mut reference = target.clone();
    let registry = registry_with("m", target);
    registry.register("d", PackedModel::random(&nano_cfg("swap-d1", 64, 1, 16), 102), None);
    let engine = Engine::start(
        &registry,
        EngineOptions { model: "m".into(), max_batch: 2, ..EngineOptions::default() },
    )
    .unwrap();
    // Get a speculative request mid-stream on draft generation 1.
    let inflight = engine
        .submit(GenRequest::greedy(vec![2, 4], 60).with_spec("d", 3))
        .unwrap();
    let mut seen = 0;
    while seen < 4 {
        if let Event::Token(_) = inflight.recv().unwrap() {
            seen += 1;
        }
    }
    // Swap the draft to different weights *and* different geometry (same
    // vocab); in-flight speculation drains on its pinned lease, new
    // requests pick up generation 2.
    let report = registry.hot_swap(
        "d",
        PackedModel::random(&nano_cfg("swap-d2", 64, 2, 24), 103),
        None,
        Duration::ZERO,
    );
    assert_eq!(report.generation, 2);
    let post = engine.submit(GenRequest::greedy(vec![2, 4], 20).with_spec("d", 3)).unwrap();
    // Both streams are bit-exact with plain decode: the draft choice (and
    // the swap) can change throughput only, never output.
    assert_eq!(inflight.wait().tokens, reference.generate(&[2, 4], 60), "in-flight stream");
    assert_eq!(post.wait().tokens, reference.generate(&[2, 4], 20), "post-swap stream");
    let pool = engine.kv_pool().cloned();
    let metrics = engine.shutdown();
    assert_eq!(metrics.completed.load(Ordering::Relaxed), 2);
    assert_pools_drained(pool, &metrics);
}

// -------------------------------------------------- decoder cross-check

#[test]
fn direct_decoder_and_engine_agree_on_speculative_greedy() {
    let cfg = nano_cfg("spec-cross", 64, 2, 32);
    let target = PackedModel::random(&cfg, 111);
    let draft = PackedModel::random(&nano_cfg("spec-cross-d", 64, 1, 16), 112);
    let mut t1 = target.clone();
    let mut d1 = draft.clone();
    let mut dec = SpecDecoder::new(3);
    let direct = dec.generate(&mut t1, &mut d1, &[6, 6, 6], 15, None);

    let registry = registry_with("m", target);
    registry.register("d", draft, None);
    let engine = Engine::start(
        &registry,
        EngineOptions { model: "m".into(), ..EngineOptions::default() },
    )
    .unwrap();
    let served = engine
        .submit(GenRequest::greedy(vec![6, 6, 6], 15).with_spec("d", 3))
        .unwrap()
        .wait();
    assert_eq!(served.tokens, direct, "engine and direct decoder must agree");
    engine.shutdown();
}
