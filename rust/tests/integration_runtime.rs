//! Integration: load real artifacts, execute train/fwd through PJRT, and
//! verify numerics against the python-recorded golden trajectory.
//!
//! Requires `make artifacts`; tests are skipped (with a notice) when the
//! artifacts directory is missing so `cargo test` still passes pre-build.

use pquant::runtime::{load_artifact, Runtime, TrainState};

fn have_artifacts(name: &str) -> bool {
    let ok = pquant::runtime::artifacts_root().join(name).join("manifest.json").exists();
    if !ok {
        eprintln!("[skip] artifacts/{name} missing — run `make artifacts`");
    }
    ok
}

#[test]
fn golden_loss_trajectory_matches_python() {
    if !have_artifacts("nano-pquant") {
        return;
    }
    let art = load_artifact("nano-pquant").unwrap();
    let golden = art.golden().unwrap().expect("nano configs record golden.json");
    let rt = Runtime::cpu().unwrap();
    let step = rt.compile(&art, "train_step").unwrap();
    let mut state = TrainState::initial(&art).unwrap();
    for (i, &want) in golden.losses.iter().enumerate() {
        let got = state.step(&step, &golden.tokens, golden.lr, golden.wd).unwrap();
        let rel = (got - want).abs() / want.abs().max(1e-6);
        assert!(rel < 2e-3, "step {i}: rust loss {got} vs python {want} (rel {rel:.2e})");
    }
}

#[test]
fn forward_runs_and_is_finite() {
    if !have_artifacts("nano-pquant") {
        return;
    }
    let art = load_artifact("nano-pquant").unwrap();
    let rt = Runtime::cpu().unwrap();
    let fwd = rt.compile(&art, "fwd").unwrap();
    let state = TrainState::initial(&art).unwrap();
    let seq = art.manifest.seq_len;
    let tokens: Vec<i32> = (0..seq as i32).map(|i| i % art.manifest.config.vocab as i32).collect();
    let (logits, ffn_input) = state.forward(&fwd, &tokens).unwrap();
    assert_eq!(logits.len(), seq * art.manifest.config.vocab);
    assert_eq!(ffn_input.len(), seq * art.manifest.config.d_model);
    assert!(logits.iter().all(|x| x.is_finite()));
    assert!(ffn_input.iter().all(|x| x.is_finite()));
}

#[test]
fn checkpoint_roundtrip_preserves_state() {
    if !have_artifacts("nano-pquant") {
        return;
    }
    let art = load_artifact("nano-pquant").unwrap();
    let rt = Runtime::cpu().unwrap();
    let step = rt.compile(&art, "train_step").unwrap();
    let mut state = TrainState::initial(&art).unwrap();
    let golden = art.golden().unwrap().unwrap();
    state.step(&step, &golden.tokens, 1e-3, 0.1).unwrap();

    let path = format!("/tmp/pquant_ckpt_{}.npz", std::process::id());
    state.save_checkpoint(&art, &path).unwrap();
    let mut restored = TrainState::load_checkpoint(&art, &path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(restored.step, state.step);

    // Continuing from the restored state must match continuing in place.
    let a = state.step(&step, &golden.tokens, 1e-3, 0.1).unwrap();
    let b = restored.step(&step, &golden.tokens, 1e-3, 0.1).unwrap();
    assert!((a - b).abs() < 1e-6, "{a} vs {b}");
}

#[test]
fn param_by_name_finds_feature_scaling() {
    if !have_artifacts("nano-pquant") {
        return;
    }
    let art = load_artifact("nano-pquant").unwrap();
    let state = TrainState::initial(&art).unwrap();
    let (shape, alpha) = state.param_by_name(&art, "layers.0.alpha").unwrap();
    assert!(shape.is_empty());
    assert_eq!(alpha, vec![art.manifest.config.alpha_init]);
    let (_, beta) = state.param_by_name(&art, "layers.0.beta").unwrap();
    assert_eq!(beta, vec![art.manifest.config.beta_init]);
}
