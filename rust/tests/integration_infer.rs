//! Integration: the rust packed engine vs the AOT PJRT forward path on a
//! *trained-from-init* model — the two implementations share quantization
//! semantics, so their next-token rankings should agree on most positions.

use pquant::infer::PackedModel;
use pquant::runtime::{load_artifact, Runtime, TrainState};

fn have(name: &str) -> bool {
    let ok = pquant::runtime::artifacts_root().join(name).join("manifest.json").exists();
    if !ok {
        eprintln!("[skip] artifacts/{name} missing");
    }
    ok
}

#[test]
fn packed_engine_agrees_with_pjrt_on_topk() {
    if !have("nano-pquant") {
        return;
    }
    let art = load_artifact("nano-pquant").unwrap();
    let rt = Runtime::cpu().unwrap();
    let state = TrainState::initial(&art).unwrap();
    let fwd = rt.compile(&art, "fwd").unwrap();

    let seq = art.manifest.seq_len;
    let vocab = art.manifest.config.vocab;
    let tokens: Vec<i32> = (0..seq).map(|i| ((i * 7) % vocab) as i32).collect();
    let (logits, _) = state.forward(&fwd, &tokens).unwrap();

    let mut packed = PackedModel::from_state(&art, &state).unwrap();
    let mut caches = packed.new_caches(seq);
    let mut agree = 0usize;
    let mut checked = 0usize;
    for t in 0..seq {
        let row = packed.decode_step(tokens[t] as u32, t, &mut caches);
        // compare argmax with the PJRT logits at the same position
        let pj_row = &logits[t * vocab..(t + 1) * vocab];
        let am = |v: &[f32]| {
            v.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        };
        checked += 1;
        if am(&row) == am(pj_row) {
            agree += 1;
        }
    }
    let frac = agree as f64 / checked as f64;
    // The engines differ in activation re-quantization points (per-token γ
    // chaining); at random init logits are near-uniform so we only require
    // majority agreement.
    assert!(frac > 0.5, "argmax agreement {frac:.2} too low");
}

#[test]
fn packed_model_storage_matches_memory_model_order() {
    if !have("micro-pquant") || !have("micro-fp16") {
        return;
    }
    let pq_art = load_artifact("micro-pquant").unwrap();
    let fp_art = load_artifact("micro-fp16").unwrap();
    let pq = PackedModel::from_state(&pq_art, &TrainState::initial(&pq_art).unwrap()).unwrap();
    let fp = PackedModel::from_state(&fp_art, &TrainState::initial(&fp_art).unwrap()).unwrap();
    assert!(pq.storage_bytes() < fp.storage_bytes());
    // block weights are ~16x smaller; embeddings shared → overall ratio in (1, 16)
    let ratio = fp.storage_bytes() as f64 / pq.storage_bytes() as f64;
    assert!(ratio > 1.5 && ratio < 16.0, "ratio {ratio:.2}");
}

#[test]
fn generation_from_converted_weights_is_deterministic() {
    if !have("nano-pquant") {
        return;
    }
    let art = load_artifact("nano-pquant").unwrap();
    let state = TrainState::initial(&art).unwrap();
    let mut a = PackedModel::from_state(&art, &state).unwrap();
    let mut b = PackedModel::from_state(&art, &state).unwrap();
    assert_eq!(a.generate(&[3, 1, 4], 8), b.generate(&[3, 1, 4], 8));
}
