//! Integration tests for the paged KV-cache subsystem: bit-exactness of
//! paged vs contiguous attention (property-tested across random shapes,
//! block sizes and positions), prefix-share attach + copy-on-write
//! correctness, pool budget accounting, and the engine-level behaviors —
//! `KvExhausted` backpressure that drains as blocks free, deterministic
//! preempt-and-recompute, and prefix sharing across concurrent requests.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use pquant::config::{ModelConfig, Variant};
use pquant::infer::{KvCache, PackedBlock, PackedModel};
use pquant::kvcache::{
    BlockPool, KvError, KvPoolOptions, KvSegment, KvStorageMode, KvStore, PagedSeq, PrefixTag,
};
use pquant::serve::{
    Engine, EngineOptions, Event, FinishReason, GenRequest, ModelRegistry, SamplingParams,
    SubmitError,
};
use pquant::util::prop::check;
use pquant::util::rng::Rng;

fn nano_cfg(name: &str) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        variant: Variant::PQuant,
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 96,
        r: 16,
        n_experts: 2,
        seq_len: 32,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

fn registry_with(name: &str, model: PackedModel) -> Arc<ModelRegistry> {
    let registry = Arc::new(ModelRegistry::new());
    registry.register(name, model, None);
    registry
}

/// Submit, blocking on KvExhausted until admission (bounded by a timeout
/// so a bug fails the test instead of hanging it).
fn submit_blocking(engine: &Engine, mut req: GenRequest) -> pquant::serve::Ticket {
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        match engine.submit(req) {
            Ok(t) => return t,
            Err(SubmitError::KvExhausted(r, _)) | Err(SubmitError::QueueFull(r, _)) => {
                assert!(Instant::now() < deadline, "admission never drained");
                req = r;
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
}

// ----------------------------------------------------- paged bit-exactness

#[test]
fn prop_paged_block_attention_bit_identical_to_contiguous() {
    let variants =
        [Variant::Fp16, Variant::BitNet, Variant::BitNet158, Variant::PQuant];
    check(
        0xA11,
        20,
        |r| {
            let d = [16usize, 32, 64][r.below(3)];
            let heads = [2usize, 4][r.below(2)];
            let seq_len = 1 + r.below(20);
            let block_size = [1usize, 2, 3, 5, 8, 16][r.below(6)];
            let variant = variants[r.below(4)];
            (d, heads, seq_len, block_size, variant, r.next_u64())
        },
        |&(d, heads, seq_len, block_size, variant, seed)| {
            let mut block_a = PackedBlock::random(variant, d, heads, 2 * d, 8, 2, seed);
            let mut block_b = block_a.clone();
            let mut rope = pquant::infer::RopeTable::default();
            rope.ensure(d / heads / 2, seq_len);
            let mut cache = KvCache::new(seq_len, d);
            let pool = Arc::new(BlockPool::new(
                KvPoolOptions { n_blocks: 64, block_size, ..Default::default() },
                1,
                d,
            ));
            let adm = pool
                .admit(&[], seq_len, PrefixTag::default())
                .map_err(|e| format!("admit failed: {e}"))?;
            let mut seq = PagedSeq::new(&pool, adm);
            for pos in 0..seq_len {
                let x = Rng::new(seed ^ (pos as u64 + 1)).normal_vec(d);
                let ya = block_a
                    .try_forward(&x, pos, &mut cache, &rope)
                    .map_err(|e| format!("contig: {e}"))?;
                let mut layer = seq.layer(0);
                let yb = block_b
                    .try_forward(&x, pos, &mut layer, &rope)
                    .map_err(|e| format!("paged: {e}"))?;
                if ya != yb {
                    return Err(format!("outputs diverge at pos {pos}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shared_prefix_and_cow_are_bit_exact() {
    let cfg = nano_cfg("prop-share");
    check(
        0x5AFE,
        12,
        |r| {
            let prompt_len = 2 + r.below(18);
            let block_size = [2usize, 4, 8, 16][r.below(4)];
            let n_cont = 1 + r.below(5);
            (prompt_len, block_size, n_cont, r.next_u64())
        },
        |&(prompt_len, block_size, n_cont, seed)| {
            let mut model_ref = PackedModel::random(&cfg, 77);
            let mut model_paged = model_ref.clone();
            let pool = Arc::new(BlockPool::new(
                KvPoolOptions { n_blocks: 512, block_size, ..Default::default() },
                cfg.n_layers,
                cfg.d_model,
            ));
            let mut prompt_rng = Rng::new(seed);
            let prompt: Vec<u32> =
                (0..prompt_len).map(|_| prompt_rng.below(64) as u32).collect();
            let tag = PrefixTag(7, 1);
            let total = prompt_len + n_cont;

            // Sequence A: full prefill, register, then continue.
            let adm = pool.admit(&prompt, total, tag).map_err(|e| format!("{e}"))?;
            if adm.shared_len() != 0 {
                return Err("first admission must not find a prefix".into());
            }
            let mut seq_a = PagedSeq::new(&pool, adm);
            for (pos, &t) in prompt.iter().enumerate() {
                model_paged.decode_step_paged(t, pos, &mut seq_a).map_err(|e| format!("{e}"))?;
            }
            pool.register_prefix(&prompt, &mut seq_a);
            let cont_a: Vec<u32> = (0..n_cont).map(|i| (i as u32 * 13 + 5) % 64).collect();
            for (i, &t) in cont_a.iter().enumerate() {
                model_paged
                    .decode_step_paged(t, prompt_len + i, &mut seq_a)
                    .map_err(|e| format!("{e}"))?;
            }

            // Sequence B: same prompt attaches the shared prefix, then
            // diverges into different tokens (copy-on-write path).
            let adm = pool.admit(&prompt, total, tag).map_err(|e| format!("{e}"))?;
            let shared = adm.shared_len();
            if shared == 0 {
                return Err("second admission must attach the registered prefix".into());
            }
            if shared >= prompt_len {
                return Err(format!(
                    "shared len {shared} must leave the last prompt token to re-decode"
                ));
            }
            let mut seq_b = PagedSeq::new(&pool, adm);
            let cont_b: Vec<u32> = (0..n_cont).map(|i| (i as u32 * 7 + 3) % 64).collect();
            let fed_b: Vec<u32> =
                prompt.iter().copied().chain(cont_b.iter().copied()).collect();
            // Contiguous reference over B's full fed sequence.
            let mut caches = model_ref.new_caches(total);
            let mut want = Vec::new();
            for (pos, &t) in fed_b.iter().enumerate() {
                want.push(model_ref.decode_step(t, pos, &mut caches));
            }
            for pos in shared..fed_b.len() {
                let got = model_paged
                    .decode_step_paged(fed_b[pos], pos, &mut seq_b)
                    .map_err(|e| format!("{e}"))?;
                if got != want[pos] {
                    return Err(format!(
                        "shared/CoW logits diverge at pos {pos} (shared={shared})"
                    ));
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------- budget accounting

#[test]
fn admit_fails_recoverably_when_pool_too_small() {
    let pool = Arc::new(BlockPool::new(KvPoolOptions { n_blocks: 3, block_size: 4, ..Default::default() }, 2, 8));
    // 8 tokens -> 2 logical blocks x 2 layers = 4 > 3.
    match pool.admit(&[1, 2], 8, PrefixTag::default()) {
        Err(KvError::OutOfBlocks { needed: 4, available: 3 }) => {}
        other => panic!("expected OutOfBlocks, got {other:?}", other = other.map(|_| ())),
    }
    // Nothing leaked by the failed admission.
    assert_eq!(pool.available(), 3);
}

#[test]
fn eviction_reclaims_unused_shared_prefixes_under_pressure() {
    let pool = Arc::new(BlockPool::new(KvPoolOptions { n_blocks: 8, block_size: 4, ..Default::default() }, 1, 4));
    let prompt: Vec<u32> = (0..8).collect();
    let adm = pool.admit(&prompt, 8, PrefixTag(1, 1)).unwrap();
    let mut seq = PagedSeq::new(&pool, adm);
    let row = [0.25f32; 4];
    for _ in 0..8 {
        seq.layer(0).push(&row, &row).unwrap();
    }
    pool.register_prefix(&prompt, &mut seq);
    assert!(pool.stats().registered_prefixes >= 1);
    drop(seq);
    // The map still holds the two frozen prompt blocks...
    assert_eq!(pool.available(), 6);
    // ...until budget pressure evicts them (no live users).
    let r = pool.try_reserve(7).expect("eviction must reclaim map blocks");
    assert_eq!(pool.available(), 1);
    assert!(pool.stats().evicted_blocks >= 2);
    drop(r);
}

// --------------------------------------------------- engine: kv exhaustion

#[test]
fn kv_exhausted_blocks_admission_then_drains_as_blocks_free() {
    let model = PackedModel::random(&nano_cfg("kv-drain"), 5);
    let mut reference = model.clone();
    let registry = registry_with("m", model);
    // Pool fits exactly one request: 4 prompt + 12 new = 16 tokens over
    // 8-token blocks -> 2 logical x 2 layers = 4 blocks.
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "m".into(),
            max_batch: 4,
            kv: Some(KvPoolOptions { n_blocks: 4, block_size: 8, ..Default::default() }),
            ..EngineOptions::default()
        },
    )
    .unwrap();
    let first = engine.submit(GenRequest::greedy(vec![1, 2, 3, 4], 12)).unwrap();
    // The pool is now fully reserved: the next submission must bounce.
    let second = match engine.submit(GenRequest::greedy(vec![5, 6, 7, 8], 12)) {
        Err(SubmitError::KvExhausted(req, _)) => {
            assert_eq!(req.n_new, 12, "request rides back in the error");
            req
        }
        other => panic!("expected KvExhausted, got {:?}", other.map(|_| ()).map_err(|e| e.to_string())),
    };
    // Retrying drains: the first request finishes, frees its blocks, and
    // the second is admitted and completes correctly.
    let second = submit_blocking(&engine, second);
    assert_eq!(first.wait().tokens, reference.generate(&[1, 2, 3, 4], 12));
    assert_eq!(second.wait().tokens, reference.generate(&[5, 6, 7, 8], 12));
    let metrics = engine.shutdown();
    assert_eq!(metrics.completed.load(Ordering::Relaxed), 2);
    let kv = metrics.kv().expect("paged engine reports pool stats");
    assert_eq!(kv.n_blocks, 4);
    assert_eq!(kv.in_use, 0, "all blocks returned after the drain");
}

#[test]
fn oversized_request_fails_fast_instead_of_retrying_forever() {
    let registry = registry_with("m", PackedModel::random(&nano_cfg("too-large"), 7));
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "m".into(),
            kv: Some(KvPoolOptions { n_blocks: 4, block_size: 8, ..Default::default() }),
            ..EngineOptions::default()
        },
    )
    .unwrap();
    // Worst case 1004 tokens -> 126 logical x 2 layers, far beyond 4
    // blocks: no drain can ever admit this, so it must not be KvExhausted
    // (which means "retry"), and it must not flag any preemption.
    match engine.submit(GenRequest::greedy(vec![1, 2, 3, 4], 1000)) {
        Err(SubmitError::KvTooLarge(req)) => assert_eq!(req.n_new, 1000),
        other => panic!(
            "expected KvTooLarge, got {:?}",
            other.map(|_| ()).map_err(|e| e.to_string())
        ),
    }
    // The pool is untouched and normally-sized requests still serve.
    let stats = engine.submit(GenRequest::greedy(vec![1, 2], 4)).unwrap().wait();
    assert_eq!(stats.tokens.len(), 4);
    engine.shutdown();
}

// ---------------------------------------------- engine: preempt + recompute

#[test]
fn preemption_frees_blocks_and_recompute_is_deterministic() {
    let model = PackedModel::random(&nano_cfg("preempt"), 9);
    let mut reference = model.clone();
    let registry = registry_with("m", model);
    // Pool fits exactly one long request: 4 + 400 tokens over 8-token
    // blocks -> 51 logical x 2 layers = 102 blocks.
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "m".into(),
            max_batch: 4,
            kv: Some(KvPoolOptions { n_blocks: 102, block_size: 8, ..Default::default() }),
            ..EngineOptions::default()
        },
    )
    .unwrap();
    let low = engine.submit(GenRequest::greedy(vec![1, 2, 3, 4], 400)).unwrap();
    // Let it decode for real before the high-priority request races it.
    loop {
        match low.recv().expect("stream open") {
            Event::Token(_) => break,
            _ => {}
        }
    }
    let high_req = GenRequest::greedy(vec![9, 8, 7, 6], 400).with_priority(5);
    let high = match engine.submit(high_req) {
        Err(SubmitError::KvExhausted(req, _)) => submit_blocking(&engine, req),
        Ok(t) => t, // only possible if low finished first — the asserts below catch it
        Err(e) => panic!("unexpected submit error: {e}"),
    };
    let high_stats = high.wait();
    assert_eq!(high_stats.finish, FinishReason::Length);
    assert_eq!(high_stats.tokens, reference.generate(&[9, 8, 7, 6], 400));
    // The preempted request resumes after the blocks free and its
    // recompute continues the identical greedy stream.
    let low_stats = low.wait();
    assert_eq!(low_stats.finish, FinishReason::Length);
    assert_eq!(low_stats.tokens, reference.generate(&[1, 2, 3, 4], 400));
    let metrics = engine.shutdown();
    assert_eq!(
        metrics.preempted.load(Ordering::Relaxed),
        1,
        "exactly one preemption must have occurred"
    );
    assert_eq!(metrics.completed.load(Ordering::Relaxed), 2);
}

// ------------------------------------------------- engine: prefix sharing

#[test]
fn concurrent_same_prompt_requests_share_prefix_blocks_and_agree() {
    let model = PackedModel::random(&nano_cfg("share"), 21);
    let mut reference = model.clone();
    let registry = registry_with("m", model);
    let engine = Engine::start(
        &registry,
        EngineOptions { model: "m".into(), max_batch: 4, ..EngineOptions::default() },
    )
    .unwrap();
    let prompt: Vec<u32> = (0..20).map(|i| (i * 3 + 1) % 64).collect();
    let want = reference.generate(&prompt, 6);
    // Warm-up registers the prompt's prefix blocks at prefill completion.
    assert_eq!(engine.submit(GenRequest::greedy(prompt.clone(), 6)).unwrap().wait().tokens, want);
    // A concurrent burst of identical prompts shares them.
    let tickets: Vec<_> = (0..4)
        .map(|_| engine.submit(GenRequest::greedy(prompt.clone(), 6)).unwrap())
        .collect();
    for t in tickets {
        assert_eq!(t.wait().tokens, want, "shared-prefix decode must stay bit-exact");
    }
    let metrics = engine.shutdown();
    let kv = metrics.kv().unwrap();
    assert!(
        kv.shared_attached > 0,
        "burst must attach shared blocks (hit rate {})",
        kv.shared_hit_rate
    );
    assert!(kv.registered_prefixes >= 1);
    assert!(kv.cow_copies >= 1, "divergence into generation must copy-on-write");
    assert_eq!(metrics.completed.load(Ordering::Relaxed), 5);
}

#[test]
fn stop_token_finish_returns_unused_tail_blocks() {
    let model = PackedModel::random(&nano_cfg("tail"), 13);
    let mut reference = model.clone();
    let full = reference.generate(&[3, 1], 12);
    let stop = full[2];
    let registry = registry_with("m", model);
    let engine = Engine::start(
        &registry,
        EngineOptions { model: "m".into(), max_batch: 2, ..EngineOptions::default() },
    )
    .unwrap();
    // Budget 40 new tokens but stop after ~3: the reserved tail was never
    // materialized and must be returned (and counted) at completion.
    let req = GenRequest::sampled(
        vec![3, 1],
        40,
        SamplingParams { stop_tokens: vec![stop], ..SamplingParams::greedy() },
    );
    let stats = engine.submit(req).unwrap().wait();
    assert_eq!(stats.finish, FinishReason::Stop);
    let metrics = engine.shutdown();
    let kv = metrics.kv().unwrap();
    assert!(
        kv.unused_tail_returned > 0,
        "early stop must return reserved-but-unused tail blocks"
    );
    // The share map may retain the registered prompt snapshot (one block
    // per layer); everything the request itself held must be back.
    assert!(
        kv.in_use <= 2,
        "only the registered prompt snapshot may stay resident, saw {}",
        kv.in_use
    );
}

// ------------------------------------------------ storage modes: int8 tier

fn argmax_ix(v: &[f32]) -> usize {
    let mut bi = 0;
    let mut best = f32::NEG_INFINITY;
    for (i, &x) in v.iter().enumerate() {
        if x > best {
            best = x;
            bi = i;
        }
    }
    bi
}

/// Top-1 index and its margin over the runner-up.
fn top2_margin(v: &[f32]) -> (usize, f32) {
    let mut bi = 0;
    let mut best = f32::NEG_INFINITY;
    let mut second = f32::NEG_INFINITY;
    for (i, &x) in v.iter().enumerate() {
        if x > best {
            second = best;
            best = x;
            bi = i;
        } else if x > second {
            second = x;
        }
    }
    (bi, best - second)
}

/// Quantized-vs-f32 greedy decode divergence is bounded: teacher-force the
/// f32 greedy stream through both storage modes and require (a) the logit
/// error stays a small fraction of the logit scale, and (b) wherever the
/// f32 argmax margin exceeds twice the observed sup-norm error — the exact
/// condition under which quantization could never flip an argmax — both
/// modes pick the same token.
#[test]
fn prop_int8_kv_greedy_decode_divergence_is_bounded() {
    let cfg = nano_cfg("int8-div");
    check(
        0x18b,
        8,
        |r| (2 + r.below(8), r.next_u64()),
        |&(prompt_len, seed)| {
            let mut model_f = PackedModel::random(&cfg, 31);
            let mut model_q = model_f.clone();
            let n_new = 12;
            let total = prompt_len + n_new;
            let mk_pool = |mode| {
                Arc::new(BlockPool::new(
                    KvPoolOptions { n_blocks: 64, block_size: 4, mode },
                    cfg.n_layers,
                    cfg.d_model,
                ))
            };
            let pool_f = mk_pool(KvStorageMode::F32);
            let pool_q = mk_pool(KvStorageMode::Int8);
            let adm = pool_f.admit(&[], total, PrefixTag::default()).map_err(|e| format!("{e}"))?;
            let mut seq_f = PagedSeq::new(&pool_f, adm);
            let adm = pool_q.admit(&[], total, PrefixTag::default()).map_err(|e| format!("{e}"))?;
            let mut seq_q = PagedSeq::new(&pool_q, adm);
            let mut rng = Rng::new(seed);
            let mut fed: Vec<u32> = (0..prompt_len).map(|_| rng.below(64) as u32).collect();
            for pos in 0..total - 1 {
                let lf = model_f
                    .decode_step_paged(fed[pos], pos, &mut seq_f)
                    .map_err(|e| format!("f32: {e}"))?;
                let lq = model_q
                    .decode_step_paged(fed[pos], pos, &mut seq_q)
                    .map_err(|e| format!("int8: {e}"))?;
                if pos + 1 >= prompt_len {
                    let max_err =
                        lf.iter().zip(&lq).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
                    let scale = lf.iter().fold(0f32, |m, v| m.max(v.abs()));
                    let tol = 0.15 * scale + 0.02;
                    if max_err > tol {
                        return Err(format!(
                            "pos {pos}: logit error {max_err} exceeds tolerance {tol} \
                             (15% of scale {scale} + cushion)"
                        ));
                    }
                    let (top, margin) = top2_margin(&lf);
                    if margin > 2.0 * max_err && argmax_ix(&lq) != top {
                        return Err(format!(
                            "pos {pos}: argmax flipped despite margin {margin} > 2x error {max_err}"
                        ));
                    }
                    if fed.len() < total {
                        fed.push(top as u32);
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn engine_serves_to_completion_on_an_int8_pool() {
    let model = PackedModel::random(&nano_cfg("int8-serve"), 17);
    let registry = registry_with("m", model);
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "m".into(),
            max_batch: 4,
            kv: Some(KvPoolOptions { n_blocks: 64, block_size: 4, mode: KvStorageMode::Int8 }),
            ..EngineOptions::default()
        },
    )
    .unwrap();
    let prompt: Vec<u32> = (0..10).map(|i| (i * 3 + 2) % 64).collect();
    // Same prompt twice: the second run exercises prefix attach + CoW on
    // quantized blocks.
    let a = engine.submit(GenRequest::greedy(prompt.clone(), 8)).unwrap().wait();
    let b = engine.submit(GenRequest::greedy(prompt.clone(), 8)).unwrap().wait();
    assert_eq!(a.finish, FinishReason::Length);
    assert_eq!(a.tokens.len(), 8);
    assert_eq!(a.tokens, b.tokens, "same prompt, same pool: identical greedy stream");
    let metrics = engine.shutdown();
    let kv = metrics.kv().unwrap();
    assert_eq!(kv.mode, KvStorageMode::Int8);
    assert!(kv.shared_attached > 0, "second request must attach the quantized prefix");
}

// --------------------------------------------- eviction order + spill tier

/// One seeded admission/registration trace against a tight pool, with a
/// per-step counter snapshot and a final residency probe.
fn lru_trace(seed: u64) -> (Vec<(usize, usize, usize)>, Vec<bool>) {
    let pool = Arc::new(BlockPool::new(
        KvPoolOptions { n_blocks: 8, block_size: 4, ..Default::default() },
        1,
        4,
    ));
    let tag = PrefixTag(1, 1);
    let prompts: Vec<Vec<u32>> =
        (0..6).map(|i| (0..8).map(|t| (i * 16 + t) as u32).collect()).collect();
    let mut rng = Rng::new(seed);
    let mut log = Vec::new();
    for _ in 0..40 {
        let i = rng.below(prompts.len());
        if let Ok(adm) = pool.admit(&prompts[i], 9, tag) {
            let mut seq = PagedSeq::new(&pool, adm);
            let row = [i as f32 * 0.1 + 0.5; 4];
            for _ in seq.len()..8 {
                seq.layer(0).push(&row, &row).unwrap();
            }
            pool.register_prefix(&prompts[i], &mut seq);
        }
        let s = pool.stats();
        log.push((s.evicted_blocks, s.registered_prefixes, pool.available()));
    }
    let resident = prompts
        .iter()
        .map(|p| match pool.admit(p, 9, tag) {
            Ok(adm) => adm.shared_len() > 0,
            Err(_) => false,
        })
        .collect();
    (log, resident)
}

#[test]
fn lru_eviction_is_deterministic_for_identical_traces() {
    // The shed order uses a logical clock, not wall time: replaying the
    // same admission trace must evict the same blocks at the same steps
    // and leave the same prefixes resident.
    let a = lru_trace(0xC0FFEE);
    let b = lru_trace(0xC0FFEE);
    assert_eq!(a.0, b.0, "per-step eviction counters must match");
    assert_eq!(a.1, b.1, "final residency must match");
    assert!(
        a.0.last().unwrap().0 > 0,
        "trace must actually evict under pressure for the test to mean anything"
    );
    // A different seed produces a different trace (sanity: the probe is
    // not vacuously constant).
    let c = lru_trace(0xBEEF);
    assert!(a.0 != c.0 || a.1 != c.1, "distinct traces should diverge");
}

/// Collect the raw stored bits of one layer's resident rows, whatever the
/// storage mode.
fn resident_bits(seq: &mut PagedSeq, layer: usize) -> Vec<u8> {
    let mut out = Vec::new();
    seq.layer(layer).for_each_seg(&mut |seg| match seg {
        KvSegment::F32 { k, v } => {
            for &x in k.iter().chain(v.iter()) {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        KvSegment::Int8 { k, v, k_scale, v_scale } => {
            for &c in k.iter().chain(v.iter()) {
                out.push(c as u8);
            }
            for &g in k_scale.iter().chain(v_scale.iter()) {
                out.extend_from_slice(&g.to_le_bytes());
            }
        }
    });
    out
}

#[test]
fn spilled_prefix_faults_back_bit_identical_in_both_modes() {
    for mode in [KvStorageMode::F32, KvStorageMode::Int8] {
        let dir = std::env::temp_dir()
            .join(format!("pquant-spill-it-{}-{mode}", std::process::id()));
        let pool = Arc::new(BlockPool::new(
            KvPoolOptions { n_blocks: 8, block_size: 4, mode },
            1,
            4,
        ));
        pool.enable_spill(&dir).unwrap();
        let tag = PrefixTag(3, 3);
        let prompt: Vec<u32> = (0..8).collect();
        {
            let adm = pool.admit(&prompt, 8, tag).unwrap();
            let mut seq = PagedSeq::new(&pool, adm);
            for pos in 0..8 {
                let k: Vec<f32> = (0..4).map(|j| (pos * 7 + j) as f32 * 0.13 - 1.0).collect();
                let v: Vec<f32> = k.iter().map(|x| x + 0.5).collect();
                seq.layer(0).push(&k, &v).unwrap();
            }
            pool.register_prefix(&prompt, &mut seq);
        }
        // Probe the resident entry's bits before spilling.
        let before = {
            let adm = pool.admit(&prompt, 9, tag).unwrap();
            assert!(adm.shared_len() > 0, "{mode}: prefix must be resident");
            let mut seq = PagedSeq::new(&pool, adm);
            resident_bits(&mut seq, 0)
        };
        assert!(!before.is_empty());
        pool.spill_unused();
        let spilled = pool.stats();
        assert!(spilled.spilled_entries > 0, "{mode}: entry must move to the cold tier");
        assert!(spilled.spill_writes > 0);
        // Re-admission faults it back from disk...
        let after = {
            let adm = pool.admit(&prompt, 9, tag).unwrap();
            assert!(adm.shared_len() > 0, "{mode}: fault-back must restore the prefix");
            let mut seq = PagedSeq::new(&pool, adm);
            resident_bits(&mut seq, 0)
        };
        // ...bit-identical to what was spilled.
        assert_eq!(before, after, "{mode}: fault-back must be bit-identical");
        let s = pool.stats();
        assert!(s.spill_faults >= 1, "{mode}: fault counter must record the restore");
        // F32 registers two boundary entries (lens 4 and 8) and only the
        // probed one faults back; the count must strictly decrease.
        assert!(
            s.spilled_entries < spilled.spilled_entries,
            "{mode}: faulted entry must leave the cold tier"
        );
        assert_eq!(s.spill_fault_fails, 0, "{mode}: no fault failures expected");
        std::fs::remove_dir_all(&dir).ok();
    }
}

// ------------------------------------------- engine: legacy contiguous mode

#[test]
fn engine_without_pool_still_serves_and_reports_no_kv_stats() {
    let model = PackedModel::random(&nano_cfg("legacy"), 3);
    let mut reference = model.clone();
    let registry = registry_with("m", model);
    let engine = Engine::start(
        &registry,
        EngineOptions { model: "m".into(), kv: None, ..EngineOptions::default() },
    )
    .unwrap();
    let stats = engine.submit(GenRequest::greedy(vec![7, 9], 5)).unwrap().wait();
    assert_eq!(stats.tokens, reference.generate(&[7, 9], 5));
    let metrics = engine.shutdown();
    assert!(metrics.kv().is_none(), "no pool, no pool stats");
}
