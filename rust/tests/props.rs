//! Cross-module property tests (the offline proptest substitute — see
//! util::prop): coordinator, quant, gemm and tokenizer invariants.

use pquant::coordinator::TwoPhaseSchedule;
use pquant::gemm;
use pquant::quant;
use pquant::tokenizer::Bpe;
use pquant::util::prop::check;
use pquant::util::rng::Rng;

#[test]
fn schedule_lr_always_positive_and_bounded() {
    check(1, 100, |r: &mut Rng| {
        let total = 10 + r.below(5000) as u64;
        let peak = r.range_f32(1e-5, 1e-1);
        (total, peak)
    }, |&(total, peak)| {
        let s = TwoPhaseSchedule::paper(total, peak);
        for step in 1..=total {
            let lr = s.lr(step);
            if !(lr > 0.0 && lr <= peak * 1.0001) {
                return Err(format!("lr {lr} out of (0, {peak}] at step {step}/{total}"));
            }
        }
        Ok(())
    });
}

#[test]
fn schedule_wd_is_two_valued() {
    check(2, 50, |r: &mut Rng| 10 + r.below(2000) as u64, |&total| {
        let s = TwoPhaseSchedule::paper(total, 1e-3);
        for step in 1..=total {
            let wd = s.wd(step);
            if wd != 0.1 && wd != 0.0 {
                return Err(format!("wd {wd} not in {{0.1, 0}}"));
            }
        }
        Ok(())
    });
}

#[test]
fn binarize_dequant_preserves_sign_of_centered() {
    check(3, 60, |r: &mut Rng| {
        let n = 1 + r.below(500);
        r.normal_vec(n)
    }, |w| {
        let b = quant::binarize(w);
        let deq = quant::dequant_binary(&b);
        for (orig, dq) in w.iter().zip(&deq) {
            let centered = orig - b.mu;
            if centered >= 0.0 && *dq < 0.0 || centered < 0.0 && *dq > 0.0 {
                return Err(format!("sign flip: {centered} vs {dq}"));
            }
        }
        Ok(())
    });
}

#[test]
fn ternarize_error_never_worse_than_half_scale_per_element() {
    check(4, 60, |r: &mut Rng| {
        let n = 1 + r.below(300);
        r.normal_vec(n)
    }, |w| {
        let t = quant::ternarize(w);
        for (orig, &q) in w.iter().zip(&t.vals) {
            let deq = q as f32 * t.scale;
            // |w| <= 1.5*scale ⇒ error <= 0.5*scale; beyond that the clip
            // error grows with |w| — check the piecewise bound.
            let bound = if orig.abs() <= 1.5 * t.scale {
                0.5 * t.scale + 1e-5
            } else {
                orig.abs() - t.scale + 1e-5
            };
            if (orig - deq).abs() > bound {
                return Err(format!("|{orig} - {deq}| > {bound}"));
            }
        }
        Ok(())
    });
}

#[test]
fn lut_gemv_equals_dense_signs_for_all_shapes() {
    check(5, 40, |r: &mut Rng| {
        let k = 1 + r.below(130);
        let n = 1 + r.below(30);
        let signs: Vec<bool> = (0..k * n).map(|_| r.below(2) == 1).collect();
        let x: Vec<i8> = (0..k).map(|_| (r.below(255) as i32 - 127) as i8).collect();
        (k, n, signs, x)
    }, |(k, n, signs, x)| {
        let packed = quant::pack_signs(signs, *k, *n);
        let luts = gemm::build_luts(x, *k);
        let got = gemm::lut_gemv(&luts, &packed);
        for j in 0..*n {
            let want: i32 = (0..*k)
                .map(|i| if signs[i * n + j] { x[i] as i32 } else { -(x[i] as i32) })
                .sum();
            if got[j] != want {
                return Err(format!("col {j}: {} != {want}", got[j]));
            }
        }
        Ok(())
    });
}

#[test]
fn bpe_roundtrips_arbitrary_ascii() {
    let corpus = pquant::data::Corpus::new(1).generate(60_000);
    let bpe = Bpe::train(&corpus[..40_000], 400);
    check(6, 40, |r: &mut Rng| {
        let len = 1 + r.below(80);
        (0..len)
            .map(|_| (32 + r.below(95)) as u8 as char)
            .collect::<String>()
    }, |text| {
        let ids = bpe.encode(text);
        let decoded = bpe.decode(&ids);
        if decoded == *text {
            Ok(())
        } else {
            Err(format!("{text:?} → {decoded:?}"))
        }
    });
}

#[test]
fn quantize_i8_rows_bounds_and_scale() {
    check(7, 50, |r: &mut Rng| {
        let rows = 1 + r.below(8);
        let cols = 1 + r.below(200);
        (rows, cols, r.normal_vec(rows * cols))
    }, |(rows, cols, x)| {
        let (q, gammas) = quant::quantize_i8_rows(x, *rows, *cols);
        if gammas.iter().any(|g| !g.is_finite() || *g <= 0.0) {
            return Err("non-finite gamma".into());
        }
        if q.iter().any(|&v| v < -127 || v > 127) {
            return Err("q8 out of range".into());
        }
        Ok(())
    });
}

#[test]
fn footprint_traffic_never_exceeds_storage() {
    let configs = pquant::config::paper_configs();
    check(8, 40, |r: &mut Rng| {
        let base = configs[r.below(configs.len())].clone();
        let n = [1, 2, 4, 8][r.below(4)];
        pquant::config::paper_pquant_n(&base, n)
    }, |cfg| {
        let f = pquant::memory::footprint(cfg);
        if f.traffic() > f.storage() {
            return Err(format!("traffic {} > storage {}", f.traffic(), f.storage()));
        }
        Ok(())
    });
}

#[test]
fn packed_generation_tokens_always_in_vocab() {
    check(9, 8, |r: &mut Rng| {
        let variant = [
            pquant::config::Variant::Fp16,
            pquant::config::Variant::BitNet,
            pquant::config::Variant::BitNet158,
            pquant::config::Variant::PQuant,
        ][r.below(4)];
        (variant, r.next_u64())
    }, |&(variant, seed)| {
        let cfg = pquant::config::ModelConfig {
            name: "prop".into(),
            variant,
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 96,
            r: if variant == pquant::config::Variant::PQuant { 16 } else { 0 },
            n_experts: if variant == pquant::config::Variant::PQuant { 2 } else { 1 },
            seq_len: 16,
            alpha_init: 2.0,
            beta_init: 0.2,
        };
        let mut m = pquant::infer::PackedModel::random(&cfg, seed);
        let out = m.generate(&[1, 2, 3], 4);
        if out.iter().all(|&t| (t as usize) < 64) {
            Ok(())
        } else {
            Err(format!("tokens out of vocab: {out:?}"))
        }
    });
}
