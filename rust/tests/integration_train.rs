//! Integration: full coordinator loop over real artifacts — schedule,
//! stability snapshots, rollback injection, eval, checkpointing.

use pquant::coordinator::{TrainOptions, Trainer};
use pquant::data::Dataset;
use pquant::runtime::{load_artifact, Runtime};

fn have(name: &str) -> bool {
    let ok = pquant::runtime::artifacts_root().join(name).join("manifest.json").exists();
    if !ok {
        eprintln!("[skip] artifacts/{name} missing");
    }
    ok
}

fn tiny_dataset(vocab: usize) -> Dataset {
    Dataset::synthetic(0xBEEF, 400_000, vocab).0
}

#[test]
fn nano_training_reduces_loss() {
    if !have("nano-pquant") {
        return;
    }
    let art = load_artifact("nano-pquant").unwrap();
    let rt = Runtime::cpu().unwrap();
    let ds = tiny_dataset(art.manifest.config.vocab);
    let mut trainer = Trainer::new(&rt, &art, &ds).unwrap();
    let report = trainer
        .run(&TrainOptions { steps: 40, log_every: 0, eval_every: 0, ..Default::default() })
        .unwrap();
    let first = report.losses[0];
    assert!(
        report.tail_loss < first * 0.92,
        "loss {first} → {} did not decrease enough",
        report.tail_loss
    );
    assert_eq!(report.losses.len(), 40);
    assert!(report.feature_scaling.len() == art.manifest.config.n_layers);
}

#[test]
fn injected_spike_triggers_rollback_and_recovers() {
    if !have("nano-bitnet") {
        return;
    }
    let art = load_artifact("nano-bitnet").unwrap();
    let rt = Runtime::cpu().unwrap();
    let ds = tiny_dataset(art.manifest.config.vocab);
    let mut trainer = Trainer::new(&rt, &art, &ds).unwrap();
    let report = trainer
        .run(&TrainOptions {
            steps: 36,
            log_every: 0,
            snapshot_every: 6,
            inject_spike_at: Some(24),
            ..Default::default()
        })
        .unwrap();
    assert!(report.rollbacks >= 1, "spike must trigger a rollback");
    assert_eq!(report.losses.len(), 36, "run must complete after recovery");
    assert!(report.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn feature_scaling_override_is_applied() {
    if !have("nano-pquant") {
        return;
    }
    let art = load_artifact("nano-pquant").unwrap();
    let rt = Runtime::cpu().unwrap();
    let ds = tiny_dataset(art.manifest.config.vocab);
    let mut trainer = Trainer::new(&rt, &art, &ds).unwrap();
    let report = trainer
        .run(&TrainOptions {
            steps: 2,
            log_every: 0,
            feature_scaling_override: Some((1.25, 0.75)),
            ..Default::default()
        })
        .unwrap();
    // after 2 steps the values have moved slightly, but must be near the override
    for (a, b) in report.feature_scaling {
        assert!((a - 1.25).abs() < 0.05, "alpha {a}");
        assert!((b - 0.75).abs() < 0.05, "beta {b}");
    }
}

#[test]
fn single_phase_schedule_differs_from_two_phase() {
    if !have("nano-fp16") {
        return;
    }
    let art = load_artifact("nano-fp16").unwrap();
    let rt = Runtime::cpu().unwrap();
    let ds = tiny_dataset(art.manifest.config.vocab);
    let run = |single| {
        let mut t = Trainer::new(&rt, &art, &ds).unwrap();
        t.run(&TrainOptions {
            steps: 20,
            log_every: 0,
            single_phase: single,
            ..Default::default()
        })
        .unwrap()
        .losses
    };
    let two = run(false);
    let one = run(true);
    assert_ne!(two, one, "schedules must produce different trajectories");
}
