//! Paper-style table/figure rendering: markdown tables to stdout plus JSON
//! under `results/` for every experiment harness.

use std::fmt::Write as _;

use crate::util::json::Json;

/// A rendered table: header + rows of cells.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Render as aligned markdown.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n### {}\n", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(s, " {c:w$} |");
            }
            s
        };
        let _ = writeln!(out, "{}", line(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Write an experiment's JSON payload under results/<id>.json and its
/// rendered tables under results/<id>.md.
pub fn save(id: &str, json: &Json, tables: &[&Table]) {
    std::fs::create_dir_all("results").ok();
    let jpath = format!("results/{id}.json");
    std::fs::write(&jpath, json.to_string_pretty()).ok();
    let md: String = tables.iter().map(|t| t.render()).collect();
    let mpath = format!("results/{id}.md");
    std::fs::write(&mpath, &md).ok();
    println!("[report] wrote {jpath} and {mpath}");
}

/// Simple ASCII line chart for loss curves (Fig 4 / 5b / 10 rendering).
pub fn ascii_chart(series: &[(&str, &[f32])], width: usize, height: usize) -> String {
    let all: Vec<f32> = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .filter(|y| y.is_finite())
        .collect();
    if all.is_empty() {
        return String::from("(no data)\n");
    }
    let lo = all.iter().copied().fold(f32::INFINITY, f32::min);
    let hi = all.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let span = (hi - lo).max(1e-9);
    let marks = [b'*', b'+', b'o', b'x', b'#', b'@'];
    let mut grid = vec![vec![b' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for (i, &y) in ys.iter().enumerate() {
            if !y.is_finite() {
                continue;
            }
            let xpix = i * (width - 1) / ys.len().max(2).saturating_sub(1).max(1);
            let ypix = ((hi - y) / span * (height - 1) as f32).round() as usize;
            grid[ypix.min(height - 1)][xpix.min(width - 1)] = mark;
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "  {hi:.3} ┐");
    for row in grid {
        let _ = writeln!(out, "        │{}", String::from_utf8_lossy(&row));
    }
    let _ = writeln!(out, "  {lo:.3} ┘");
    for (si, (name, _)) in series.iter().enumerate() {
        let _ = writeln!(out, "        {} = {}", marks[si % marks.len()] as char, name);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("### Demo"));
        assert!(s.contains("| long-name | 2.5"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn chart_renders_series() {
        let ys1: Vec<f32> = (0..50).map(|i| 5.0 - i as f32 * 0.05).collect();
        let ys2: Vec<f32> = (0..50).map(|i| 4.0 - i as f32 * 0.03).collect();
        let s = ascii_chart(&[("a", &ys1), ("b", &ys2)], 40, 10);
        assert!(s.contains('*') && s.contains('+'));
    }
}
