//! Offline bit-packing (paper Appendix A): 1-bit weights are packed 8 per
//! byte ("UINT8 format with 8 parameters per byte, 1/16 the storage of
//! FP16"); ternary weights are packed 4 per byte (2 bits each).
//!
//! The packed layout is *column-major by group-of-bits along the input
//! dim*: for a [k, n] weight matrix the LUT GEMV consumes, bits of one
//! output column are contiguous so a GEMV walks memory linearly.

/// Packed ±1 weights of a [k, n] matrix, column-major.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedBits {
    pub k: usize,
    pub n: usize,
    /// ceil(k/8) bytes per column, n columns. Bit b of byte y in column j
    /// is row index y*8 + b; 1 = +1, 0 = -1. Rows beyond k are zero-padded
    /// (pad bits encode -1 but are never read: the LUT path masks them).
    pub bytes: Vec<u8>,
    pub bytes_per_col: usize,
}

/// Pack sign bits (row-major [k, n] bools, true = +1) column-major.
pub fn pack_signs(signs: &[bool], k: usize, n: usize) -> PackedBits {
    assert_eq!(signs.len(), k * n);
    let bytes_per_col = k.div_ceil(8);
    let mut bytes = vec![0u8; bytes_per_col * n];
    for j in 0..n {
        let col = &mut bytes[j * bytes_per_col..(j + 1) * bytes_per_col];
        for i in 0..k {
            if signs[i * n + j] {
                col[i / 8] |= 1 << (i % 8);
            }
        }
    }
    PackedBits { k, n, bytes, bytes_per_col }
}

/// Unpack back to row-major bools (test/debug path).
pub fn unpack_signs(p: &PackedBits) -> Vec<bool> {
    let mut signs = vec![false; p.k * p.n];
    for j in 0..p.n {
        let col = &p.bytes[j * p.bytes_per_col..(j + 1) * p.bytes_per_col];
        for i in 0..p.k {
            signs[i * p.n + j] = (col[i / 8] >> (i % 8)) & 1 == 1;
        }
    }
    signs
}

/// Packed ternary {-1, 0, +1} weights, 4 per byte, column-major.
/// Encoding per 2-bit field: 0b00 = 0, 0b01 = +1, 0b10 = -1.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedTernary {
    pub k: usize,
    pub n: usize,
    pub bytes: Vec<u8>,
    pub bytes_per_col: usize,
}

pub fn pack_ternary(vals: &[i8], k: usize, n: usize) -> PackedTernary {
    assert_eq!(vals.len(), k * n);
    let bytes_per_col = k.div_ceil(4);
    let mut bytes = vec![0u8; bytes_per_col * n];
    for j in 0..n {
        let col = &mut bytes[j * bytes_per_col..(j + 1) * bytes_per_col];
        for i in 0..k {
            let code: u8 = match vals[i * n + j] {
                0 => 0b00,
                1 => 0b01,
                -1 => 0b10,
                v => panic!("ternary value out of range: {v}"),
            };
            col[i / 4] |= code << ((i % 4) * 2);
        }
    }
    PackedTernary { k, n, bytes, bytes_per_col }
}

pub fn unpack_ternary(p: &PackedTernary) -> Vec<i8> {
    let mut vals = vec![0i8; p.k * p.n];
    for j in 0..p.n {
        let col = &p.bytes[j * p.bytes_per_col..(j + 1) * p.bytes_per_col];
        for i in 0..p.k {
            let code = (col[i / 4] >> ((i % 4) * 2)) & 0b11;
            vals[i * p.n + j] = match code {
                0b00 => 0,
                0b01 => 1,
                0b10 => -1,
                _ => unreachable!("invalid ternary code"),
            };
        }
    }
    vals
}

/// Storage bytes for the packed representation (the Fig-6 traffic model).
impl PackedBits {
    pub fn storage_bytes(&self) -> usize {
        self.bytes.len()
    }
}

impl PackedTernary {
    pub fn storage_bytes(&self) -> usize {
        self.bytes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn signs_roundtrip_exact() {
        prop::check(11, 50, |r: &mut Rng| {
            let k = 1 + r.below(70);
            let n = 1 + r.below(20);
            let signs: Vec<bool> = (0..k * n).map(|_| r.below(2) == 1).collect();
            (k, n, signs)
        }, |(k, n, signs)| {
            let p = pack_signs(signs, *k, *n);
            if unpack_signs(&p) == *signs { Ok(()) } else { Err("roundtrip mismatch".into()) }
        });
    }

    #[test]
    fn ternary_roundtrip_exact() {
        prop::check(12, 50, |r: &mut Rng| {
            let k = 1 + r.below(70);
            let n = 1 + r.below(20);
            let vals: Vec<i8> = (0..k * n).map(|_| r.below(3) as i8 - 1).collect();
            (k, n, vals)
        }, |(k, n, vals)| {
            let p = pack_ternary(vals, *k, *n);
            if unpack_ternary(&p) == *vals { Ok(()) } else { Err("roundtrip mismatch".into()) }
        });
    }

    #[test]
    fn storage_is_one_sixteenth_of_fp16() {
        // Appendix A: packed 1-bit = 1/16 the bytes of fp16 (k multiple of 8).
        let k = 4096;
        let n = 64;
        let signs = vec![true; k * n];
        let p = pack_signs(&signs, k, n);
        assert_eq!(p.storage_bytes() * 16, k * n * 2);
    }

    #[test]
    fn ternary_storage_is_2bits() {
        let p = pack_ternary(&vec![1i8; 128 * 4], 128, 4);
        assert_eq!(p.storage_bytes(), 128 / 4 * 4);
    }
}
