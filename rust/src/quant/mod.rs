//! Quantizers — the rust mirror of `python/compile/kernels/quantize.py`.
//!
//! The training path quantizes inside the AOT HLO; this module implements
//! the *same math* for the offline weight-conversion step (checkpoint →
//! packed inference weights) and for the ablation studies (channel-wise /
//! group-wise, Fig 7 right).  Numerical agreement with the python oracles
//! is enforced by integration tests against golden vectors.

pub mod pack;

pub use pack::{pack_signs, unpack_signs, pack_ternary, unpack_ternary, PackedBits, PackedTernary};

/// Epsilon matching python `quantize.EPS`.
pub const EPS: f32 = 1e-6;
/// Symmetric INT8 bound matching python `quantize.Q8_BOUND`.
pub const Q8_BOUND: f32 = 127.0;

/// Result of 1-bit sign/absmean quantization (eq. 3-6).
#[derive(Debug, Clone)]
pub struct Binarized {
    /// Sign bits; true = +1, false = -1 (sign(0) → +1, like the oracle).
    pub signs: Vec<bool>,
    /// Per-tensor dequantization scale λ = mean|W − μ|.
    pub lambda: f32,
    /// Mean μ removed before binarization.
    pub mu: f32,
}

/// 1-bit sign/absmean with mean-centering; mirrors `binarize_weight`.
pub fn binarize(w: &[f32]) -> Binarized {
    let n = w.len().max(1) as f32;
    let mu = w.iter().sum::<f32>() / n;
    let lambda = w.iter().map(|x| (x - mu).abs()).sum::<f32>() / n + EPS;
    let signs = w.iter().map(|x| x - mu >= 0.0).collect();
    Binarized { signs, lambda, mu }
}

/// Dequantize 1-bit back to f32 (λ·sign; μ is *not* re-added — matches the
/// python oracle and the paper's eq. 10).
pub fn dequant_binary(b: &Binarized) -> Vec<f32> {
    b.signs.iter().map(|&s| if s { b.lambda } else { -b.lambda }).collect()
}

/// Result of ternary absmean quantization (BitNet1.58).
#[derive(Debug, Clone)]
pub struct Ternarized {
    /// Values in {-1, 0, +1}.
    pub vals: Vec<i8>,
    /// Per-tensor scale = mean|W|.
    pub scale: f32,
}

/// Ternary absmean; mirrors `ternarize_weight`.
pub fn ternarize(w: &[f32]) -> Ternarized {
    let n = w.len().max(1) as f32;
    let scale = w.iter().map(|x| x.abs()).sum::<f32>() / n + EPS;
    let vals = w
        .iter()
        .map(|x| (x / scale).round().clamp(-1.0, 1.0) as i8)
        .collect();
    Ternarized { vals, scale }
}

/// Result of INT8 absmax quantization.
#[derive(Debug, Clone)]
pub struct Quantized8 {
    pub vals: Vec<i8>,
    /// γ = 127 / max|x|; dequantize with x = q/γ.
    pub gamma: f32,
}

/// Per-tensor INT8 absmax; mirrors `absmax_quantize_per_tensor`.
pub fn quantize_i8(x: &[f32]) -> Quantized8 {
    let absmax = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let gamma = Q8_BOUND / (absmax + EPS);
    let vals = x
        .iter()
        .map(|v| (v * gamma).round().clamp(-Q8_BOUND, Q8_BOUND) as i8)
        .collect();
    Quantized8 { vals, gamma }
}

/// Quantize one activation row into a caller-owned buffer and return its
/// γ — the single allocation-free primitive behind [`quantize_i8_rows`]
/// and the batched activation path, so every caller performs bit-identical
/// arithmetic.
pub fn quantize_i8_row_into(row: &[f32], out: &mut [i8]) -> f32 {
    debug_assert_eq!(row.len(), out.len());
    let absmax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let gamma = Q8_BOUND / (absmax + EPS);
    for (dst, v) in out.iter_mut().zip(row) {
        *dst = (v * gamma).round().clamp(-Q8_BOUND, Q8_BOUND) as i8;
    }
    gamma
}

/// Dequantize one INT8 row quantized by [`quantize_i8_row_into`] into a
/// caller-owned buffer: `x = q / γ`. The KV-cache's quantized storage
/// mode uses exactly this expression (spill round-trips and in-place
/// attention dequant must agree bit-for-bit), so it lives beside the
/// quantizer rather than being re-derived per call site.
pub fn dequant_i8_row_into(q: &[i8], gamma: f32, out: &mut [f32]) {
    debug_assert_eq!(q.len(), out.len());
    for (dst, &v) in out.iter_mut().zip(q) {
        *dst = v as f32 / gamma;
    }
}

/// Per-row (token) INT8 absmax over a [rows, cols] row-major buffer;
/// mirrors `absmax_quantize(axis=-1)`. Returns per-row γ.
pub fn quantize_i8_rows(x: &[f32], rows: usize, cols: usize) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(x.len(), rows * cols);
    let mut vals = vec![0i8; x.len()];
    let mut gammas = vec![0.0f32; rows];
    for r in 0..rows {
        gammas[r] = quantize_i8_row_into(
            &x[r * cols..(r + 1) * cols],
            &mut vals[r * cols..(r + 1) * cols],
        );
    }
    (vals, gammas)
}

/// Channel-wise (per output column) 1-bit quantization of a [k, n]
/// row-major matrix (ablation, Fig 7 right). Returns per-column (λ, μ).
pub fn binarize_channelwise(w: &[f32], k: usize, n: usize) -> (Vec<bool>, Vec<f32>, Vec<f32>) {
    assert_eq!(w.len(), k * n);
    let mut mus = vec![0.0f32; n];
    let mut lambdas = vec![0.0f32; n];
    for j in 0..n {
        let mut sum = 0.0f32;
        for i in 0..k {
            sum += w[i * n + j];
        }
        let mu = sum / k as f32;
        let mut asum = 0.0f32;
        for i in 0..k {
            asum += (w[i * n + j] - mu).abs();
        }
        mus[j] = mu;
        lambdas[j] = asum / k as f32 + EPS;
    }
    let mut signs = vec![false; k * n];
    for i in 0..k {
        for j in 0..n {
            signs[i * n + j] = w[i * n + j] - mus[j] >= 0.0;
        }
    }
    (signs, lambdas, mus)
}

/// Group-wise 1-bit quantization along the input dim, groups of `group`
/// (ablation, Fig 7 right: group = 64). Returns per-(group, col) λ.
pub fn binarize_groupwise(
    w: &[f32],
    k: usize,
    n: usize,
    group: usize,
) -> (Vec<bool>, Vec<f32>) {
    assert_eq!(w.len(), k * n);
    assert_eq!(k % group, 0, "group must divide k");
    let n_groups = k / group;
    let mut lambdas = vec![0.0f32; n_groups * n];
    let mut signs = vec![false; k * n];
    for g in 0..n_groups {
        for j in 0..n {
            let mut sum = 0.0f32;
            for i in 0..group {
                sum += w[(g * group + i) * n + j];
            }
            let mu = sum / group as f32;
            let mut asum = 0.0f32;
            for i in 0..group {
                asum += (w[(g * group + i) * n + j] - mu).abs();
            }
            lambdas[g * n + j] = asum / group as f32 + EPS;
            for i in 0..group {
                let idx = (g * group + i) * n + j;
                signs[idx] = w[idx] - mu >= 0.0;
            }
        }
    }
    (signs, lambdas)
}

/// Mean squared reconstruction error of a quantizer output vs the original.
pub fn mse(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>() / a.len().max(1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        Rng::new(seed).normal_vec(n)
    }

    #[test]
    fn binarize_basic() {
        let w = vec![1.0, -1.0, 2.0, -2.0];
        let b = binarize(&w);
        assert_eq!(b.mu, 0.0);
        assert!((b.lambda - 1.5 - EPS).abs() < 1e-6);
        assert_eq!(b.signs, vec![true, false, true, false]);
    }

    #[test]
    fn binarize_centered() {
        // All-positive weights: centering must produce both signs.
        let w = vec![1.0, 2.0, 3.0, 4.0];
        let b = binarize(&w);
        assert!(b.signs.iter().any(|&s| s) && b.signs.iter().any(|&s| !s));
    }

    #[test]
    fn ternarize_zeros_small() {
        let w = vec![0.01, -0.01, 5.0, -5.0];
        let t = ternarize(&w);
        assert_eq!(t.vals, vec![0, 0, 1, -1]);
    }

    #[test]
    fn quantize_i8_bounds_and_roundtrip() {
        let x = randn(1000, 1);
        let q = quantize_i8(&x);
        assert!(q.vals.iter().all(|&v| (-127..=127).contains(&(v as i32))));
        // max-abs element maps to ±127
        assert_eq!(q.vals.iter().map(|v| v.abs()).max().unwrap(), 127);
        // dequantized error bounded by half a step
        let step = 1.0 / q.gamma;
        for (orig, q8) in x.iter().zip(&q.vals) {
            assert!((orig - *q8 as f32 / q.gamma).abs() <= 0.5 * step + 1e-6);
        }
    }

    #[test]
    fn per_row_gamma_differs() {
        let mut x = vec![0.0f32; 2 * 4];
        x[..4].copy_from_slice(&[1.0, -1.0, 0.5, 0.0]);
        x[4..].copy_from_slice(&[100.0, -50.0, 25.0, 0.0]);
        let (_, gammas) = quantize_i8_rows(&x, 2, 4);
        assert!(gammas[0] > gammas[1] * 50.0);
    }

    #[test]
    fn dequant_binary_error_below_fp_range() {
        let w = randn(4096, 2);
        let b = binarize(&w);
        let deq = dequant_binary(&b);
        // 1-bit MSE for a standard normal is 1 - 2/π ≈ 0.363
        let e = mse(&w, &deq);
        assert!(e > 0.2 && e < 0.55, "mse = {e}");
    }

    #[test]
    fn groupwise_beats_pertensor_on_structured() {
        // Columns with very different magnitudes: group scales fit better.
        let k = 128;
        let n = 8;
        let mut rng = Rng::new(3);
        let mut w = vec![0.0f32; k * n];
        for i in 0..k {
            for j in 0..n {
                let scale = if i < 64 { 0.1 } else { 10.0 };
                w[i * n + j] = rng.normal() * scale;
            }
        }
        let (signs_g, lam_g) = binarize_groupwise(&w, k, n, 64);
        let mut deq_g = vec![0.0f32; k * n];
        for i in 0..k {
            for j in 0..n {
                let lam = lam_g[(i / 64) * n + j];
                deq_g[i * n + j] = if signs_g[i * n + j] { lam } else { -lam };
            }
        }
        let b = binarize(&w);
        let deq_t = dequant_binary(&b);
        assert!(mse(&w, &deq_g) < mse(&w, &deq_t));
    }

    #[test]
    fn channelwise_scales_follow_columns() {
        let k = 64;
        let w: Vec<f32> = (0..k * 2)
            .map(|idx| {
                let col = idx % 2;
                let sign = if (idx / 2) % 2 == 0 { 1.0 } else { -1.0 };
                sign * if col == 0 { 10.0 } else { 0.1 }
            })
            .collect();
        let (_, lambdas, _) = binarize_channelwise(&w, k, 2);
        assert!(lambdas[0] > lambdas[1] * 50.0);
    }
}
