//! Analytic memory-footprint model (paper §4.5 Fig 6, Table 3, Table 6,
//! Appendix D.1).
//!
//! Fig 6 plots "memory footprint of model weights transferred during a
//! single forward pass": with top-1 routing only one 8-bit expert branch
//! moves per token regardless of N, so pQuant's *traffic* is constant in N
//! while its *storage* grows (the Appendix D.1 trade-off).
//!
//! Storage encoding per variant (Appendix A):
//!   fp16       — 2 bytes/weight
//!   bitnet     — 1 bit/weight packed + one f16 scale per matrix
//!   bitnet158  — 2 bits/weight packed + one f16 scale per matrix
//!   pquant     — 1-bit branch packed; 8-bit branch 1 byte/weight; scalar
//!                α/β/λ/γ/μ fused (§4.5: "merged during inference")
//! Embeddings, LM head and norms stay fp16 in every variant (Table 3
//! "memory footprint include the storage of Embeddings and LayerNorm").
//!
//! KV-cache term: once weights are 1-bit, the KV cache dominates serving
//! memory, so [`Footprint`] carries an explicit `kv_bytes` term sized by
//! [`kv_seq_bytes`] (one sequence) or [`kv_pool_bytes`] (a whole
//! [`BlockPool`](crate::kvcache::BlockPool) budget: `n_blocks` fixed-byte
//! block slabs of K and V rows, per layer). Both are storage-mode aware:
//! f32 rows cost `4·d_model` bytes, int8 rows `d_model + 4` (codes plus a
//! per-row absmax scale), so quantizing the cache shrinks the term ~4×.
//! `storage()` includes it; `traffic()` keeps the paper's Fig-6 semantics
//! (weight bytes moved per forward pass) and does not.

use crate::config::{ModelConfig, Variant};
use crate::kvcache::{KvPoolOptions, KvStorageMode};

/// Byte counts for one model; `traffic` = bytes moved per forward pass
/// (activated weights), `storage` = resident bytes (all weights).
#[derive(Debug, Clone, PartialEq)]
pub struct Footprint {
    pub embed_bytes: usize,
    pub norm_bytes: usize,
    pub attn_bytes: usize,
    pub ffn_1bit_bytes: usize,
    /// One expert branch (the activated one).
    pub ffn_8bit_active_bytes: usize,
    /// All N expert branches.
    pub ffn_8bit_total_bytes: usize,
    pub router_bytes: usize,
    pub scale_bytes: usize,
    /// Resident KV-cache bytes (0 from [`footprint`]; attach a serving
    /// budget with [`Footprint::with_kv`]).
    pub kv_bytes: usize,
}

impl Footprint {
    /// Bytes transferred per forward pass (Fig 6 — weights only; the KV
    /// term is resident state, not per-pass weight traffic).
    pub fn traffic(&self) -> usize {
        self.embed_bytes
            + self.norm_bytes
            + self.attn_bytes
            + self.ffn_1bit_bytes
            + self.ffn_8bit_active_bytes
            + self.router_bytes
            + self.scale_bytes
    }

    /// Resident storage (Table 3 "Memory", Appendix D.1) plus the KV term.
    pub fn storage(&self) -> usize {
        self.embed_bytes
            + self.norm_bytes
            + self.attn_bytes
            + self.ffn_1bit_bytes
            + self.ffn_8bit_total_bytes
            + self.router_bytes
            + self.scale_bytes
            + self.kv_bytes
    }

    /// Attach a KV-cache byte count (see [`kv_seq_bytes`] /
    /// [`kv_pool_bytes`]).
    pub fn with_kv(mut self, kv_bytes: usize) -> Footprint {
        self.kv_bytes = kv_bytes;
        self
    }
}

const FP16: usize = 2;

/// Resident KV bytes for one sequence of `tokens` positions: K and V rows
/// per layer, priced by the pool's storage mode
/// ([`KvStorageMode::row_bytes`]).
pub fn kv_seq_bytes(cfg: &ModelConfig, tokens: usize, mode: KvStorageMode) -> usize {
    2 * tokens * cfg.n_layers * mode.row_bytes(cfg.d_model)
}

/// Worst-case resident bytes of a whole paged KV pool budget
/// (blocks are per-layer, so `n_blocks` already counts layers). Matches
/// [`KvPoolStats::capacity_bytes`](crate::kvcache::KvPoolStats) exactly.
pub fn kv_pool_bytes(cfg: &ModelConfig, opts: &KvPoolOptions) -> usize {
    opts.n_blocks * opts.block_bytes(cfg.d_model)
}

/// Compute the footprint model for a config.
pub fn footprint(cfg: &ModelConfig) -> Footprint {
    let d = cfg.d_model;
    let l = cfg.n_layers;
    // Embeddings + untied head + all norms stay fp16.
    let embed_bytes = 2 * cfg.vocab * d * FP16;
    let norm_bytes = (2 * l * d + d) * FP16;

    let attn_weights = 4 * d * d * l;
    let (attn_bytes, ffn_1bit_bytes, ffn_8bit_active, ffn_8bit_total, router_bytes, scales) =
        match cfg.variant {
            Variant::Fp16 => {
                let ffn = 2 * d * cfg.d_ff * l;
                (attn_weights * FP16, ffn * FP16, 0, 0, 0, 0)
            }
            Variant::BitNet => {
                let ffn = 2 * d * cfg.d_ff * l;
                // 1 bit per weight + 1 f16 scale per matrix (4 attn + 2 ffn per layer)
                (attn_weights / 8, ffn / 8, 0, 0, 0, 6 * l * FP16)
            }
            Variant::BitNet158 => {
                let ffn = 2 * d * cfg.d_ff * l;
                (attn_weights / 4, ffn / 4, 0, 0, 0, 6 * l * FP16)
            }
            Variant::PQuant => {
                let ffn1 = 2 * d * cfg.d_ff_1bit() * l;
                let expert = 2 * d * cfg.r * l; // one branch, 1 byte/weight INT8
                let router = d * cfg.n_experts * l * FP16;
                // per-layer fused scalars: λ(×6 mats), γ, α, β → folded; keep
                // a conservative 8 f16 scalars per layer
                (attn_weights / 8, ffn1 / 8, expert, expert * cfg.n_experts, router, 8 * l * FP16)
            }
        };

    Footprint {
        embed_bytes,
        norm_bytes,
        attn_bytes,
        ffn_1bit_bytes,
        ffn_8bit_active_bytes: ffn_8bit_active,
        ffn_8bit_total_bytes: ffn_8bit_total,
        router_bytes,
        scale_bytes: scales,
        kv_bytes: 0,
    }
}

/// GiB helper for reports.
pub fn gib(bytes: usize) -> f64 {
    bytes as f64 / (1024.0 * 1024.0 * 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_configs;

    fn by_name(name: &str) -> ModelConfig {
        paper_configs().into_iter().find(|c| c.name == name).unwrap()
    }

    #[test]
    fn fig6_ordering_pquant_below_bitnet158_below_fp16() {
        for size in ["300M", "700M", "1.3B"] {
            let fp = footprint(&by_name(&format!("paper-{size}-fp16"))).traffic();
            let b158 = footprint(&by_name(&format!("paper-{size}-bitnet158"))).traffic();
            let pq = footprint(&by_name(&format!("paper-{size}-pquant"))).traffic();
            assert!(pq < b158, "{size}: pquant {pq} !< bitnet1.58 {b158}");
            assert!(b158 < fp, "{size}: bitnet1.58 {b158} !< fp16 {fp}");
        }
    }

    #[test]
    fn paper_ratios_roughly_hold() {
        // §4.5: "compared to LLaMA-2, pQuant reduces memory usage by 92%,
        // and requires 31% less memory than BitNet1.58" (block weights;
        // embeddings dilute the ratio at small scale, so compare 1.3B).
        let fp = footprint(&by_name("paper-1.3B-fp16")).traffic() as f64;
        let b158 = footprint(&by_name("paper-1.3B-bitnet158")).traffic() as f64;
        let pq = footprint(&by_name("paper-1.3B-pquant")).traffic() as f64;
        let vs_fp = 1.0 - pq / fp;
        let vs_b158 = 1.0 - pq / b158;
        assert!(vs_fp > 0.75, "reduction vs fp16 = {vs_fp:.2}, paper ~0.92");
        assert!(vs_b158 > 0.15 && vs_b158 < 0.55,
            "reduction vs bitnet1.58 = {vs_b158:.2}, paper ~0.31");
    }

    #[test]
    fn traffic_constant_in_n_storage_grows() {
        // §4.5: "pQuant maintains a consistent memory footprint during
        // decoding regardless of the value of N".
        let base = by_name("paper-1.3B-pquant");
        let f1 = footprint(&crate::config::paper_pquant_n(&base, 1));
        let f8 = footprint(&crate::config::paper_pquant_n(&base, 8));
        // traffic: only the router grows (negligible but nonzero)
        let t1 = f1.traffic() as f64;
        let t8 = f8.traffic() as f64;
        assert!((t8 - t1) / t1 < 0.01, "traffic must be ~constant in N");
        assert!(f8.storage() > f1.storage(), "storage must grow with N");
    }

    #[test]
    fn table6_total_params_growth_shape() {
        // Table 6: 1.3B base → 1.4B (N=2) → 1.5B (N=4) → 1.7B (N=8).
        let base = by_name("paper-1.3B-pquant");
        let p1 = crate::config::paper_pquant_n(&base, 1).param_count() as f64;
        let p2 = crate::config::paper_pquant_n(&base, 2).param_count() as f64;
        let p4 = crate::config::paper_pquant_n(&base, 4).param_count() as f64;
        let p8 = crate::config::paper_pquant_n(&base, 8).param_count() as f64;
        assert!((p2 / p1 - 1.4 / 1.3).abs() < 0.06, "N=2 ratio {:.3}", p2 / p1);
        assert!((p4 / p1 - 1.5 / 1.3).abs() < 0.08, "N=4 ratio {:.3}", p4 / p1);
        assert!((p8 / p1 - 1.7 / 1.3).abs() < 0.12, "N=8 ratio {:.3}", p8 / p1);
    }

    #[test]
    fn packed_1bit_is_16x_smaller_than_fp16_blocks() {
        let fp = footprint(&by_name("paper-1.3B-fp16"));
        let bn = footprint(&by_name("paper-1.3B-bitnet"));
        assert_eq!(fp.attn_bytes, bn.attn_bytes * 16);
    }

    #[test]
    fn kv_term_adds_to_storage_not_traffic() {
        let cfg = by_name("paper-1.3B-pquant");
        let base = footprint(&cfg);
        let kv = kv_seq_bytes(&cfg, 2048, KvStorageMode::F32);
        assert_eq!(kv, 2 * 2048 * cfg.d_model * cfg.n_layers * 4);
        let with = footprint(&cfg).with_kv(kv);
        assert_eq!(with.storage(), base.storage() + kv);
        assert_eq!(with.traffic(), base.traffic(), "Fig-6 traffic is weights only");
    }

    #[test]
    fn kv_dominates_pquant_weights_at_serving_depth() {
        // The regime motivating the paged pool: with 1-bit blocks, a
        // few concurrent long sequences out-weigh the packed weights.
        let cfg = by_name("paper-1.3B-pquant");
        let weights = footprint(&cfg);
        let block_weights = weights.storage() - weights.embed_bytes;
        assert!(kv_seq_bytes(&cfg, 4096, KvStorageMode::F32) * 8 > block_weights);
    }

    #[test]
    fn int8_kv_term_is_near_4x_smaller() {
        let cfg = by_name("paper-1.3B-pquant");
        let f = kv_seq_bytes(&cfg, 2048, KvStorageMode::F32) as f64;
        let i = kv_seq_bytes(&cfg, 2048, KvStorageMode::Int8) as f64;
        let ratio = f / i;
        assert!(ratio > 3.9 && ratio <= 4.0, "f32/int8 ratio {ratio:.3}");
    }

    #[test]
    fn pool_bytes_scale_with_budget() {
        let cfg = by_name("paper-300M-pquant");
        let small =
            crate::kvcache::KvPoolOptions { n_blocks: 64, block_size: 16, ..Default::default() };
        let big =
            crate::kvcache::KvPoolOptions { n_blocks: 128, block_size: 16, ..Default::default() };
        assert_eq!(kv_pool_bytes(&cfg, &big), 2 * kv_pool_bytes(&cfg, &small));
    }

    #[test]
    fn pool_bytes_match_pool_stats_capacity_in_both_modes() {
        // The analytic model and the live pool must agree byte-for-byte,
        // whatever the storage mode — this is the accounting contract the
        // serving metrics rely on.
        let cfg = by_name("paper-300M-pquant");
        for mode in [KvStorageMode::F32, KvStorageMode::Int8] {
            let opts = crate::kvcache::KvPoolOptions { n_blocks: 32, block_size: 8, mode };
            let pool = crate::kvcache::BlockPool::new(opts, cfg.n_layers, cfg.d_model);
            let stats = pool.stats();
            assert_eq!(
                kv_pool_bytes(&cfg, &opts),
                stats.capacity_bytes,
                "{mode}: analytic model disagrees with pool capacity"
            );
        }
    }
}
