//! Data pipeline: synthetic corpus generation, tokenized shard storage,
//! shuffled batch iteration (the paper "directly mixed and shuffled the
//! training datasets", Appendix C).

pub mod corpus;

pub use corpus::Corpus;

use anyhow::Result;

use crate::tokenizer::Bpe;
use crate::util::rng::Rng;

/// Tokenized training data with deterministic shuffled batch iteration.
pub struct Dataset {
    /// Flat token stream (train split).
    pub train: Vec<u32>,
    /// Held-out token stream for perplexity (the WikiText-2 analog).
    pub valid: Vec<u32>,
    pub vocab: usize,
}

impl Dataset {
    /// Build a dataset: generate corpus text, train a BPE on a prefix,
    /// tokenize, split 98/2 train/valid.
    pub fn synthetic(seed: u64, target_bytes: usize, vocab_size: usize) -> (Dataset, Bpe) {
        let text = Corpus::new(seed).generate(target_bytes);
        let bpe_sample_len = text.len().min(256 * 1024);
        let bpe = Bpe::train(&text[..bpe_sample_len], vocab_size);
        let ids = bpe.encode(&text);
        let split = ids.len() * 98 / 100;
        let ds = Dataset {
            train: ids[..split].to_vec(),
            valid: ids[split..].to_vec(),
            vocab: bpe.vocab_size(),
        };
        (ds, bpe)
    }

    /// Number of distinct (batch, seq+1) windows available per epoch.
    pub fn windows_per_epoch(&self, seq_len: usize) -> usize {
        self.train.len() / (seq_len + 1)
    }

    /// Deterministic shuffled batch iterator over (seq_len+1)-token windows.
    pub fn batches(&self, batch: usize, seq_len: usize, seed: u64) -> BatchIter<'_> {
        let window = seq_len + 1;
        let n_windows = self.train.len() / window;
        assert!(n_windows >= batch, "dataset too small for batch size");
        let mut order: Vec<usize> = (0..n_windows).collect();
        Rng::new(seed).shuffle(&mut order);
        BatchIter { data: &self.train, order, window, batch, cursor: 0 }
    }
}

/// Infinite batch iterator: reshuffles (deterministically) on epoch wrap.
pub struct BatchIter<'a> {
    data: &'a [u32],
    order: Vec<usize>,
    window: usize,
    batch: usize,
    cursor: usize,
}

impl<'a> BatchIter<'a> {
    /// Next [batch, seq_len+1] token block as i32 (the PJRT operand dtype).
    pub fn next_batch(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch * self.window);
        for _ in 0..self.batch {
            if self.cursor >= self.order.len() {
                // epoch boundary: reshuffle deterministically from position
                let mut rng = Rng::new(self.order[0] as u64 ^ 0xD1CE);
                rng.shuffle(&mut self.order);
                self.cursor = 0;
            }
            let w = self.order[self.cursor];
            self.cursor += 1;
            let start = w * self.window;
            out.extend(self.data[start..start + self.window].iter().map(|&t| t as i32));
        }
        out
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.batch, self.window)
    }
}

/// Default corpus-cache parameters shared by every CLI entry point, so
/// `eval --model` scores the same cached dataset as `eval --config`.
pub const DEFAULT_CACHE_DIR: &str = "results/cache/data";
pub const DEFAULT_CORPUS_SEED: u64 = 0xC0FFEE;
pub const DEFAULT_CORPUS_BYTES: usize = 4 * 1024 * 1024;

/// Load or build the default cached dataset at a given vocab size.
pub fn default_cached_dataset(vocab_size: usize) -> Result<(Dataset, Bpe)> {
    cached_dataset(DEFAULT_CACHE_DIR, DEFAULT_CORPUS_SEED, DEFAULT_CORPUS_BYTES, vocab_size)
}

/// Load or build a cached dataset + tokenizer under `dir`.
pub fn cached_dataset(
    dir: &str,
    seed: u64,
    target_bytes: usize,
    vocab_size: usize,
) -> Result<(Dataset, Bpe)> {
    std::fs::create_dir_all(dir)?;
    let bpe_path = format!("{dir}/bpe_{seed}_{vocab_size}.json");
    let toks_path = format!("{dir}/tokens_{seed}_{target_bytes}_{vocab_size}.bin");
    if std::path::Path::new(&bpe_path).exists() && std::path::Path::new(&toks_path).exists() {
        let bpe = Bpe::load(&bpe_path)?;
        let bytes = std::fs::read(&toks_path)?;
        let ids: Vec<u32> = bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let split = ids.len() * 98 / 100;
        return Ok((
            Dataset {
                train: ids[..split].to_vec(),
                valid: ids[split..].to_vec(),
                vocab: bpe.vocab_size(),
            },
            bpe,
        ));
    }
    let (ds, bpe) = Dataset::synthetic(seed, target_bytes, vocab_size);
    bpe.save(&bpe_path)?;
    let mut bytes = Vec::with_capacity((ds.train.len() + ds.valid.len()) * 4);
    for &t in ds.train.iter().chain(&ds.valid) {
        bytes.extend_from_slice(&t.to_le_bytes());
    }
    std::fs::write(&toks_path, bytes)?;
    Ok((ds, bpe))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_dataset_shapes() {
        let (ds, bpe) = Dataset::synthetic(1, 60_000, 512);
        assert_eq!(ds.vocab, 512);
        assert!(ds.train.len() > 10_000);
        assert!(ds.valid.len() > 100);
        assert!(ds.train.iter().all(|&t| (t as usize) < bpe.vocab_size()));
    }

    #[test]
    fn batches_have_right_shape_and_range() {
        let (ds, _) = Dataset::synthetic(2, 60_000, 512);
        let mut it = ds.batches(4, 32, 9);
        for _ in 0..5 {
            let b = it.next_batch();
            assert_eq!(b.len(), 4 * 33);
            assert!(b.iter().all(|&t| t >= 0 && (t as usize) < ds.vocab));
        }
    }

    #[test]
    fn batches_deterministic() {
        let (ds, _) = Dataset::synthetic(3, 60_000, 512);
        let a: Vec<i32> = ds.batches(2, 16, 7).next_batch();
        let b: Vec<i32> = ds.batches(2, 16, 7).next_batch();
        assert_eq!(a, b);
        let c: Vec<i32> = ds.batches(2, 16, 8).next_batch();
        assert_ne!(a, c, "different seeds should shuffle differently");
    }

    #[test]
    fn epoch_wrap_reshuffles() {
        let (ds, _) = Dataset::synthetic(4, 30_000, 512);
        let n = ds.windows_per_epoch(32);
        let mut it = ds.batches(1, 32, 5);
        for _ in 0..n * 2 + 3 {
            let b = it.next_batch();
            assert_eq!(b.len(), 33);
        }
    }

    #[test]
    fn cache_roundtrip() {
        let dir = format!("/tmp/pquant_test_cache_{}", std::process::id());
        let (a, _) = cached_dataset(&dir, 11, 30_000, 512).unwrap();
        let (b, _) = cached_dataset(&dir, 11, 30_000, 512).unwrap();
        assert_eq!(a.train, b.train);
        std::fs::remove_dir_all(&dir).ok();
    }
}
