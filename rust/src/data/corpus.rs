//! Synthetic grammar corpus — the C4/Wikipedia/ArXiv substitute
//! (DESIGN.md §Substitutions).
//!
//! A seeded PCFG produces English-like paragraphs with both local n-gram
//! structure and the specific regularities the zero-shot task suite
//! (eval::tasks) probes:
//!
//!   * SVO sentences with topic-coherent nouns/verbs/adjectives
//!   * category membership facts      → ARC-E / BoolQ analogs
//!   * property (opposite) facts      → OpenbookQA analog
//!   * tool/affordance facts          → PIQA analog
//!   * ordered sequences              → HellaSwag analog
//!   * subject-verb number agreement  → Winogrande analog
//!   * two-hop category+property      → ARC-C analog
//!
//! Everything is deterministic in the seed, so training runs and the paper
//! harnesses are reproducible bit-for-bit.

use crate::util::rng::Rng;

/// A noun with its category, typical property, and affordance tool.
pub struct Noun {
    pub word: &'static str,
    pub plural: &'static str,
    pub category: &'static str,
    pub property: &'static str,
}

pub const CATEGORIES: [&str; 4] = ["animal", "tool", "food", "place"];

pub const NOUNS: &[Noun] = &[
    Noun { word: "fox", plural: "foxes", category: "animal", property: "fast" },
    Noun { word: "bear", plural: "bears", category: "animal", property: "strong" },
    Noun { word: "owl", plural: "owls", category: "animal", property: "quiet" },
    Noun { word: "wolf", plural: "wolves", category: "animal", property: "fast" },
    Noun { word: "horse", plural: "horses", category: "animal", property: "strong" },
    Noun { word: "mouse", plural: "mice", category: "animal", property: "small" },
    Noun { word: "hammer", plural: "hammers", category: "tool", property: "heavy" },
    Noun { word: "knife", plural: "knives", category: "tool", property: "sharp" },
    Noun { word: "saw", plural: "saws", category: "tool", property: "sharp" },
    Noun { word: "drill", plural: "drills", category: "tool", property: "loud" },
    Noun { word: "wrench", plural: "wrenches", category: "tool", property: "heavy" },
    Noun { word: "bread", plural: "breads", category: "food", property: "soft" },
    Noun { word: "apple", plural: "apples", category: "food", property: "sweet" },
    Noun { word: "cheese", plural: "cheeses", category: "food", property: "soft" },
    Noun { word: "soup", plural: "soups", category: "food", property: "warm" },
    Noun { word: "rice", plural: "rices", category: "food", property: "plain" },
    Noun { word: "river", plural: "rivers", category: "place", property: "wide" },
    Noun { word: "forest", plural: "forests", category: "place", property: "dark" },
    Noun { word: "market", plural: "markets", category: "place", property: "busy" },
    Noun { word: "harbor", plural: "harbors", category: "place", property: "calm" },
];

/// Antonym pairs — the "opposite of" facts (OpenbookQA analog).
pub const OPPOSITES: &[(&str, &str)] = &[
    ("hot", "cold"),
    ("big", "small"),
    ("fast", "slow"),
    ("light", "dark"),
    ("wet", "dry"),
    ("hard", "soft"),
    ("loud", "quiet"),
    ("full", "empty"),
];

/// Affordances: action → tool (PIQA analog).
pub const AFFORDANCES: &[(&str, &str)] = &[
    ("cut", "knife"),
    ("pound", "hammer"),
    ("bore", "drill"),
    ("turn", "wrench"),
    ("split", "saw"),
];

/// Ordered sequences (HellaSwag analog: continuation).
pub const SEQUENCES: &[&[&str]] = &[
    &["one", "two", "three", "four", "five", "six", "seven", "eight"],
    &["monday", "tuesday", "wednesday", "thursday", "friday", "saturday", "sunday"],
    &["spring", "summer", "autumn", "winter"],
    &["dawn", "morning", "noon", "evening", "night"],
    &["first", "second", "third", "fourth", "fifth"],
];

pub const VERBS_S: &[&str] = &["sees", "follows", "finds", "likes", "fears", "meets"];
pub const VERBS_P: &[&str] = &["see", "follow", "find", "like", "fear", "meet"];
pub const ADJECTIVES: &[&str] = &[
    "red", "old", "young", "tall", "small", "big", "gray", "wild", "calm", "bright",
];

/// Corpus generator over the fixed grammar.
pub struct Corpus {
    rng: Rng,
}

impl Corpus {
    pub fn new(seed: u64) -> Corpus {
        Corpus { rng: Rng::new(seed) }
    }

    fn noun(&mut self) -> &'static Noun {
        &NOUNS[self.rng.below(NOUNS.len())]
    }

    /// One sentence; the mix of patterns is weighted so facts appear often
    /// enough to be learned by a few-million-parameter model.
    pub fn sentence(&mut self) -> String {
        match self.rng.weighted(&[3.0, 2.0, 1.5, 1.5, 1.5, 1.5, 1.0]) {
            // SVO with optional adjectives
            0 => {
                let a = self.noun();
                let b = self.noun();
                let adj = ADJECTIVES[self.rng.below(ADJECTIVES.len())];
                let v = VERBS_S[self.rng.below(VERBS_S.len())];
                format!("the {adj} {} {v} the {} .", a.word, b.word)
            }
            // category membership fact
            1 => {
                let n = self.noun();
                let art = article(n.category);
                format!("{} {} is {art} {} .", article_cap(n.word), n.word, n.category)
            }
            // property fact
            2 => {
                let n = self.noun();
                format!("the {} is {} .", n.word, n.property)
            }
            // opposites fact
            3 => {
                let (a, b) = OPPOSITES[self.rng.below(OPPOSITES.len())];
                if self.rng.below(2) == 0 {
                    format!("the opposite of {a} is {b} .")
                } else {
                    format!("the opposite of {b} is {a} .")
                }
            }
            // affordance fact
            4 => {
                let (action, tool) = AFFORDANCES[self.rng.below(AFFORDANCES.len())];
                let food = loop {
                    let n = self.noun();
                    if n.category == "food" {
                        break n;
                    }
                };
                format!("you {action} the {} with a {tool} .", food.word)
            }
            // ordered sequence fragment
            5 => {
                let seq = SEQUENCES[self.rng.below(SEQUENCES.len())];
                let start = self.rng.below(seq.len().saturating_sub(2).max(1));
                let len = (2 + self.rng.below(3)).min(seq.len() - start);
                let mut s = seq[start..start + len].join(" ");
                s.push_str(" .");
                s
            }
            // number agreement (plural vs singular + are/is)
            _ => {
                let n = self.noun();
                let adj = ADJECTIVES[self.rng.below(ADJECTIVES.len())];
                if self.rng.below(2) == 0 {
                    format!("the {} are {adj} .", n.plural)
                } else {
                    format!("the {} is {adj} .", n.word)
                }
            }
        }
    }

    /// A paragraph of `n` sentences separated by spaces.
    pub fn paragraph(&mut self, n: usize) -> String {
        (0..n).map(|_| self.sentence()).collect::<Vec<_>>().join(" ")
    }

    /// Generate ~`target_bytes` of corpus text.
    pub fn generate(&mut self, target_bytes: usize) -> String {
        let mut out = String::with_capacity(target_bytes + 128);
        while out.len() < target_bytes {
            let n = 6 + self.rng.below(6);
            out.push_str(&self.paragraph(n));
            out.push('\n');
        }
        out
    }
}

fn article(word: &str) -> &'static str {
    match word.as_bytes().first() {
        Some(b'a') | Some(b'e') | Some(b'i') | Some(b'o') | Some(b'u') => "an",
        _ => "a",
    }
}

fn article_cap(word: &str) -> &'static str {
    article(word)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = Corpus::new(7).generate(10_000);
        let b = Corpus::new(7).generate(10_000);
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Corpus::new(1).generate(1000), Corpus::new(2).generate(1000));
    }

    #[test]
    fn contains_all_fact_patterns() {
        let text = Corpus::new(3).generate(200_000);
        assert!(text.contains(" is a "), "category facts missing");
        assert!(text.contains("the opposite of "), "opposite facts missing");
        assert!(text.contains(" with a "), "affordance facts missing");
        assert!(text.contains("monday tuesday") || text.contains("one two"),
            "sequences missing");
        assert!(text.contains(" are "), "plural agreement missing");
    }

    #[test]
    fn reaches_target_size() {
        let text = Corpus::new(5).generate(50_000);
        assert!(text.len() >= 50_000);
        assert!(text.len() < 60_000);
    }

    #[test]
    fn grammar_tables_consistent() {
        for n in NOUNS {
            assert!(CATEGORIES.contains(&n.category), "{} has unknown category", n.word);
        }
        for (_, tool) in AFFORDANCES {
            assert!(NOUNS.iter().any(|n| n.word == *tool && n.category == "tool"),
                "affordance tool {tool} not a tool noun");
        }
    }
}
