//! Byte-level codecs for the `.pqm` sections: a bounds-checked cursor pair
//! plus encoders/decoders for [`ModelConfig`], [`QLinear`] and
//! [`PackedBlock`].
//!
//! Everything is little-endian and self-describing enough to be validated
//! without trusting the payload: reads go through [`ByteReader::take`]
//! (which fails on truncation instead of panicking) and element counts are
//! checked-multiplied before any allocation, so a corrupted or adversarial
//! section errors out instead of OOM-ing or slicing out of bounds.

use anyhow::{bail, Result};

use crate::config::{ModelConfig, Variant};
use crate::infer::block::{DecoupledFfn, Ffn, PackedBlock};
use crate::infer::QLinear;
use crate::quant::{PackedBits, PackedTernary};

// ---------------------------------------------------------------- writer

/// Append-only little-endian byte sink.
pub(crate) struct ByteWriter {
    pub buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter { buf: Vec::new() }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    /// Raw f32 slice, no length prefix (count comes from context).
    pub fn put_f32_raw(&mut self, xs: &[f32]) {
        self.buf.reserve(xs.len() * 4);
        for x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// u32 length prefix + raw f32 data.
    pub fn put_f32s(&mut self, xs: &[f32]) {
        self.put_u32(xs.len() as u32);
        self.put_f32_raw(xs);
    }

    pub fn put_i8_raw(&mut self, xs: &[i8]) {
        self.buf.reserve(xs.len());
        for &x in xs {
            self.buf.push(x as u8);
        }
    }
}

// ---------------------------------------------------------------- reader

/// Bounds-checked little-endian cursor over one section payload.
pub(crate) struct ByteReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(bytes: &'a [u8]) -> ByteReader<'a> {
        ByteReader { bytes, pos: 0 }
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.bytes.len() - self.pos {
            bail!(
                "truncated section: wanted {n} bytes at offset {}, {} available",
                self.pos,
                self.bytes.len() - self.pos
            );
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f32_raw(&mut self, count: usize) -> Result<Vec<f32>> {
        let raw = self.take(checked_bytes(count, 4)?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// u32 length prefix + raw f32 data (pair of [`ByteWriter::put_f32s`]).
    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let count = self.u32()? as usize;
        self.f32_raw(count)
    }

    pub fn i8_raw(&mut self, count: usize) -> Result<Vec<i8>> {
        Ok(self.take(count)?.iter().map(|&b| b as i8).collect())
    }

    /// Error if the payload has trailing bytes (format drift guard).
    pub fn finish(&self) -> Result<()> {
        if self.pos != self.bytes.len() {
            bail!(
                "section has {} trailing bytes past offset {}",
                self.bytes.len() - self.pos,
                self.pos
            );
        }
        Ok(())
    }
}

/// `count * size` with overflow/absurdity guards — runs *before* any
/// allocation so corrupt headers cannot trigger huge reserves.
fn checked_bytes(count: usize, size: usize) -> Result<usize> {
    match count.checked_mul(size) {
        Some(n) => Ok(n),
        None => bail!("element count {count} overflows"),
    }
}

// ---------------------------------------------------------------- config

fn variant_code(v: Variant) -> u8 {
    match v {
        Variant::Fp16 => 0,
        Variant::BitNet => 1,
        Variant::BitNet158 => 2,
        Variant::PQuant => 3,
    }
}

fn variant_from_code(c: u8) -> Result<Variant> {
    Ok(match c {
        0 => Variant::Fp16,
        1 => Variant::BitNet,
        2 => Variant::BitNet158,
        3 => Variant::PQuant,
        _ => bail!("unknown variant code {c}"),
    })
}

pub(crate) fn encode_config(cfg: &ModelConfig) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u32(cfg.name.len() as u32);
    w.put_bytes(cfg.name.as_bytes());
    w.put_u8(variant_code(cfg.variant));
    for dim in [
        cfg.vocab,
        cfg.d_model,
        cfg.n_layers,
        cfg.n_heads,
        cfg.d_ff,
        cfg.r,
        cfg.n_experts,
        cfg.seq_len,
    ] {
        w.put_u32(dim as u32);
    }
    w.put_f32(cfg.alpha_init);
    w.put_f32(cfg.beta_init);
    w.buf
}

pub(crate) fn decode_config(payload: &[u8]) -> Result<ModelConfig> {
    let mut r = ByteReader::new(payload);
    let name_len = r.u32()? as usize;
    let name = String::from_utf8(r.take(name_len)?.to_vec())?;
    let variant = variant_from_code(r.u8()?)?;
    let mut dims = [0usize; 8];
    for d in dims.iter_mut() {
        *d = r.u32()? as usize;
    }
    let cfg = ModelConfig {
        name,
        variant,
        vocab: dims[0],
        d_model: dims[1],
        n_layers: dims[2],
        n_heads: dims[3],
        d_ff: dims[4],
        r: dims[5],
        n_experts: dims[6],
        seq_len: dims[7],
        alpha_init: r.f32()?,
        beta_init: r.f32()?,
    };
    r.finish()?;
    if cfg.d_model == 0 || cfg.vocab == 0 || cfg.n_layers == 0 || cfg.n_heads == 0 {
        bail!("config section has zero-sized geometry");
    }
    Ok(cfg)
}

// ---------------------------------------------------------------- linears

const QL_F32: u8 = 0;
const QL_ONE_BIT: u8 = 1;
const QL_TERNARY: u8 = 2;
const QL_INT8: u8 = 3;

pub(crate) fn encode_qlinear(w: &mut ByteWriter, q: &QLinear) {
    match q {
        QLinear::F32 { w: data, k, n } => {
            w.put_u8(QL_F32);
            w.put_u32(*k as u32);
            w.put_u32(*n as u32);
            w.put_f32_raw(data);
        }
        QLinear::OneBit { w: p, lambda } => {
            w.put_u8(QL_ONE_BIT);
            w.put_u32(p.k as u32);
            w.put_u32(p.n as u32);
            w.put_f32(*lambda);
            w.put_bytes(&p.bytes);
        }
        QLinear::Ternary { w: p, scale } => {
            w.put_u8(QL_TERNARY);
            w.put_u32(p.k as u32);
            w.put_u32(p.n as u32);
            w.put_f32(*scale);
            w.put_bytes(&p.bytes);
        }
        QLinear::Int8 { w: data, gamma_w, k, n } => {
            w.put_u8(QL_INT8);
            w.put_u32(*k as u32);
            w.put_u32(*n as u32);
            w.put_f32(*gamma_w);
            w.put_i8_raw(data);
        }
    }
}

pub(crate) fn decode_qlinear(r: &mut ByteReader) -> Result<QLinear> {
    let tag = r.u8()?;
    let k = r.u32()? as usize;
    let n = r.u32()? as usize;
    if k == 0 || n == 0 {
        bail!("linear with zero dimension ({k}x{n})");
    }
    Ok(match tag {
        QL_F32 => QLinear::F32 { w: r.f32_raw(checked_bytes(k, n)?)?, k, n },
        QL_ONE_BIT => {
            let lambda = r.f32()?;
            let bytes_per_col = k.div_ceil(8);
            let bytes = r.take(checked_bytes(bytes_per_col, n)?)?.to_vec();
            QLinear::OneBit { w: PackedBits { k, n, bytes, bytes_per_col }, lambda }
        }
        QL_TERNARY => {
            let scale = r.f32()?;
            let bytes_per_col = k.div_ceil(4);
            let bytes = r.take(checked_bytes(bytes_per_col, n)?)?.to_vec();
            QLinear::Ternary { w: PackedTernary { k, n, bytes, bytes_per_col }, scale }
        }
        QL_INT8 => {
            let gamma_w = r.f32()?;
            QLinear::Int8 { w: r.i8_raw(checked_bytes(k, n)?)?, gamma_w, k, n }
        }
        t => bail!("unknown linear tag {t}"),
    })
}

// ---------------------------------------------------------------- blocks

const FFN_DENSE: u8 = 0;
const FFN_DECOUPLED: u8 = 1;

pub(crate) fn encode_block(b: &PackedBlock) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u32(b.n_heads as u32);
    w.put_f32s(&b.attn_norm);
    w.put_f32s(&b.ffn_norm);
    for q in [&b.wq, &b.wk, &b.wv, &b.wo] {
        encode_qlinear(&mut w, q);
    }
    match &b.ffn {
        Ffn::Dense { up, down } => {
            w.put_u8(FFN_DENSE);
            encode_qlinear(&mut w, up);
            encode_qlinear(&mut w, down);
        }
        Ffn::Decoupled(dec) => {
            w.put_u8(FFN_DECOUPLED);
            encode_qlinear(&mut w, &dec.up_1bit);
            encode_qlinear(&mut w, &dec.down_1bit);
            w.put_u32(dec.experts.len() as u32);
            for (up, down) in &dec.experts {
                encode_qlinear(&mut w, up);
                encode_qlinear(&mut w, down);
            }
            w.put_f32s(&dec.router);
            w.put_f32(dec.alpha);
            w.put_f32(dec.beta);
        }
    }
    w.buf
}

pub(crate) fn decode_block(payload: &[u8], cfg: &ModelConfig) -> Result<PackedBlock> {
    let d = cfg.d_model;
    let mut r = ByteReader::new(payload);
    let n_heads = r.u32()? as usize;
    if n_heads != cfg.n_heads {
        bail!("block has {n_heads} heads, config says {}", cfg.n_heads);
    }
    let attn_norm = r.f32s()?;
    let ffn_norm = r.f32s()?;
    if attn_norm.len() != d || ffn_norm.len() != d {
        bail!(
            "block norms have {}/{} gains, config d_model is {d}",
            attn_norm.len(),
            ffn_norm.len()
        );
    }
    let mut proj = Vec::with_capacity(4);
    for name in ["wq", "wk", "wv", "wo"] {
        let q = decode_qlinear(&mut r)?;
        if q.shape() != (d, d) {
            bail!("{name} has shape {:?}, want ({d}, {d})", q.shape());
        }
        proj.push(q);
    }
    let mut proj = proj.into_iter();
    let (wq, wk, wv, wo) = (
        proj.next().unwrap(),
        proj.next().unwrap(),
        proj.next().unwrap(),
        proj.next().unwrap(),
    );
    let ffn = match r.u8()? {
        FFN_DENSE => {
            let up = decode_qlinear(&mut r)?;
            let down = decode_qlinear(&mut r)?;
            if up.shape() != (d, cfg.d_ff) || down.shape() != (cfg.d_ff, d) {
                bail!(
                    "dense FFN shapes {:?}/{:?} do not match d_ff {}",
                    up.shape(),
                    down.shape(),
                    cfg.d_ff
                );
            }
            Ffn::Dense { up, down }
        }
        FFN_DECOUPLED => {
            let up_1bit = decode_qlinear(&mut r)?;
            let down_1bit = decode_qlinear(&mut r)?;
            let n1 = cfg.d_ff_1bit();
            if up_1bit.shape() != (d, n1) || down_1bit.shape() != (n1, d) {
                bail!(
                    "1-bit branch shapes {:?}/{:?} do not match d_ff_1bit {n1}",
                    up_1bit.shape(),
                    down_1bit.shape()
                );
            }
            let n_experts = r.u32()? as usize;
            if n_experts == 0 || n_experts != cfg.n_experts.max(1) {
                bail!("block has {n_experts} experts, config says {}", cfg.n_experts);
            }
            let mut experts = Vec::with_capacity(n_experts);
            for e in 0..n_experts {
                let up = decode_qlinear(&mut r)?;
                let down = decode_qlinear(&mut r)?;
                if up.shape() != (d, cfg.r) || down.shape() != (cfg.r, d) {
                    bail!("expert {e} shapes {:?}/{:?} do not match r {}", up.shape(), down.shape(), cfg.r);
                }
                experts.push((up, down));
            }
            let router = r.f32s()?;
            if router.len() != d * n_experts {
                bail!("router has {} weights, want {}", router.len(), d * n_experts);
            }
            Ffn::Decoupled(DecoupledFfn {
                up_1bit,
                down_1bit,
                experts,
                router,
                alpha: r.f32()?,
                beta: r.f32()?,
            })
        }
        t => bail!("unknown FFN tag {t}"),
    };
    r.finish()?;
    Ok(PackedBlock {
        attn_norm,
        ffn_norm,
        wq,
        wk,
        wv,
        wo,
        ffn,
        n_heads,
        timing: Default::default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn reader_rejects_truncation() {
        let mut r = ByteReader::new(&[1, 2, 3]);
        assert!(r.take(2).is_ok());
        let err = r.take(2).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn reader_rejects_trailing_bytes() {
        let mut r = ByteReader::new(&[0; 8]);
        r.take(4).unwrap();
        assert!(r.finish().is_err());
        r.take(4).unwrap();
        assert!(r.finish().is_ok());
    }

    #[test]
    fn config_roundtrip() {
        let cfg = ModelConfig {
            name: "roundtrip".into(),
            variant: Variant::PQuant,
            vocab: 512,
            d_model: 64,
            n_layers: 3,
            n_heads: 4,
            d_ff: 176,
            r: 16,
            n_experts: 2,
            seq_len: 32,
            alpha_init: 2.0,
            beta_init: 0.2,
        };
        assert_eq!(decode_config(&encode_config(&cfg)).unwrap(), cfg);
    }

    #[test]
    fn qlinear_roundtrip_all_kinds() {
        let mut rng = Rng::new(9);
        let wf = rng.normal_vec(24 * 10);
        for q in [
            QLinear::f32(&wf, 24, 10),
            QLinear::one_bit(&wf, 24, 10),
            QLinear::ternary(&wf, 24, 10),
            QLinear::int8(&wf, 24, 10),
        ] {
            let mut w = ByteWriter::new();
            encode_qlinear(&mut w, &q);
            let mut r = ByteReader::new(&w.buf);
            let back = decode_qlinear(&mut r).unwrap();
            r.finish().unwrap();
            assert!(back == q, "mismatch after roundtrip");
        }
    }

    #[test]
    fn qlinear_rejects_bad_tag() {
        let mut w = ByteWriter::new();
        w.put_u8(9);
        w.put_u32(4);
        w.put_u32(4);
        assert!(decode_qlinear(&mut ByteReader::new(&w.buf)).is_err());
    }
}
