//! Versioned binary `.pqm` packed-model artifacts (paper Appendix A: the
//! offline quantize-and-pack step made shippable).
//!
//! A `.pqm` file serializes one complete [`PackedModel`] — config,
//! embeddings, per-block packed 1-bit/ternary planes, INT8 expert weights,
//! scales, router tensors — plus an optional BPE tokenizer, so `serve` and
//! `eval` can restart from disk without a live `TrainState` or any JSON
//! per-tensor parsing.
//!
//! Layout (all little-endian):
//!
//! ```text
//! [ 8] magic 0x89 "PQM" \r \n 0x1A \n    (PNG-style: catches text-mode mangling)
//! [ 4] format version (u32)
//! [ 4] section count (u32)
//! [24] × N section table entries: kind u16, index u16, offset u64, len u64, crc32 u32
//! [..] section payloads, concatenated in table order
//! ```
//!
//! Loads are a single sequential read: parse the 16-byte header, walk the
//! table, CRC-check every payload, then decode.  A truncated file, foreign
//! magic, future version, or corrupted payload is rejected with a precise
//! error instead of producing garbage weights.

pub(crate) mod codec;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::infer::{PackedBlock, PackedModel};
use crate::tokenizer::Bpe;
use crate::util::json::Json;

/// File magic: `0x89 "PQM" \r \n 0x1A \n`.
pub const MAGIC: [u8; 8] = [0x89, b'P', b'Q', b'M', 0x0D, 0x0A, 0x1A, 0x0A];
/// Current (and only) format version this build writes and reads.
pub const FORMAT_VERSION: u32 = 1;

const HEADER_BYTES: usize = 16;
const TABLE_ENTRY_BYTES: usize = 24;
/// Sanity cap on the section count (a model has 4 + n_layers + 1 sections).
const MAX_SECTIONS: usize = 65_536;

/// Section kinds. `index` disambiguates repeated kinds (block layer id).
pub mod kind {
    pub const CONFIG: u16 = 1;
    pub const EMBED: u16 = 2;
    pub const LM_HEAD: u16 = 3;
    pub const FINAL_NORM: u16 = 4;
    pub const BLOCK: u16 = 5;
    pub const TOKENIZER: u16 = 6;
    /// KV spill-file metadata (geometry + prefix identity), one per file.
    pub const KV_META: u16 = 7;
    /// One spilled KV block; `index` is the flattened (layer, block) id.
    pub const KV_BLOCK: u16 = 8;
}

/// Human name of a section kind (inspect output).
pub fn kind_name(kind: u16) -> &'static str {
    match kind {
        kind::CONFIG => "config",
        kind::EMBED => "embed",
        kind::LM_HEAD => "lm_head",
        kind::FINAL_NORM => "final_norm",
        kind::BLOCK => "block",
        kind::TOKENIZER => "tokenizer",
        kind::KV_META => "kv_meta",
        kind::KV_BLOCK => "kv_block",
        _ => "unknown",
    }
}

/// One parsed section-table entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Section {
    pub kind: u16,
    pub index: u16,
    pub offset: u64,
    pub len: u64,
    pub crc: u32,
}

// ---------------------------------------------------------------- crc32

const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = build_crc_table();

/// CRC-32 (IEEE 802.3, the zlib polynomial) over `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------- save

/// A model loaded from a `.pqm` artifact.
pub struct PqmModel {
    pub model: PackedModel,
    pub tokenizer: Option<Bpe>,
}

/// Assemble a `.pqm` section container from `(kind, index, payload)`
/// triples: magic + version header, CRC'd section table, concatenated
/// payloads. The model artifact and the KV spill tier both serialize
/// through this one writer, so every on-disk byte the repo produces gets
/// the same corruption/truncation detection.
pub fn save_container(payloads: &[(u16, u16, Vec<u8>)]) -> Vec<u8> {
    let table_end = HEADER_BYTES + TABLE_ENTRY_BYTES * payloads.len();
    let body: usize = payloads.iter().map(|(_, _, p)| p.len()).sum();
    let mut out = Vec::with_capacity(table_end + body);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&(payloads.len() as u32).to_le_bytes());
    let mut offset = table_end as u64;
    for (sec_kind, index, payload) in payloads {
        out.extend_from_slice(&sec_kind.to_le_bytes());
        out.extend_from_slice(&index.to_le_bytes());
        out.extend_from_slice(&offset.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&crc32(payload).to_le_bytes());
        offset += payload.len() as u64;
    }
    for (_, _, payload) in payloads {
        out.extend_from_slice(payload);
    }
    out
}

/// Parse a `.pqm` section container and CRC-verify every payload.
/// The read-side twin of [`save_container`].
pub fn read_container(bytes: &[u8]) -> Result<Vec<Section>> {
    let sections = parse_table(bytes)?;
    verify_crcs(bytes, &sections)?;
    Ok(sections)
}

/// The payload bytes of one parsed section.
pub fn section_payload<'a>(bytes: &'a [u8], s: &Section) -> &'a [u8] {
    payload(bytes, s)
}

/// Serialize a packed model (and optional tokenizer) to `.pqm` bytes.
pub fn save_pqm_bytes(model: &PackedModel, tokenizer: Option<&Bpe>) -> Vec<u8> {
    let mut payloads: Vec<(u16, u16, Vec<u8>)> = Vec::with_capacity(5 + model.blocks.len());
    payloads.push((kind::CONFIG, 0, codec::encode_config(&model.cfg)));
    payloads.push((kind::EMBED, 0, f32_payload(&model.embed)));
    payloads.push((kind::LM_HEAD, 0, f32_payload(&model.lm_head)));
    payloads.push((kind::FINAL_NORM, 0, f32_payload(&model.final_norm)));
    for (l, block) in model.blocks.iter().enumerate() {
        payloads.push((kind::BLOCK, l as u16, codec::encode_block(block)));
    }
    if let Some(bpe) = tokenizer {
        payloads.push((kind::TOKENIZER, 0, bpe.to_json().to_string().into_bytes()));
    }
    save_container(&payloads)
}

/// Write a `.pqm` artifact to disk; returns the file size in bytes.
pub fn save_pqm(
    model: &PackedModel,
    tokenizer: Option<&Bpe>,
    path: impl AsRef<Path>,
) -> Result<u64> {
    let path = path.as_ref();
    let bytes = save_pqm_bytes(model, tokenizer);
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {parent:?}"))?;
        }
    }
    std::fs::write(path, &bytes).with_context(|| format!("writing {path:?}"))?;
    Ok(bytes.len() as u64)
}

fn f32_payload(xs: &[f32]) -> Vec<u8> {
    let mut w = codec::ByteWriter::new();
    w.put_f32_raw(xs);
    w.buf
}

// ---------------------------------------------------------------- load

/// Parse + bounds-check the header and section table (no payload reads).
fn parse_table(bytes: &[u8]) -> Result<Vec<Section>> {
    if bytes.len() < HEADER_BYTES {
        bail!("truncated .pqm: {} bytes, header needs {HEADER_BYTES}", bytes.len());
    }
    if bytes[..8] != MAGIC {
        bail!("not a .pqm artifact (bad magic)");
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != FORMAT_VERSION {
        bail!("unsupported .pqm format version {version} (this build reads {FORMAT_VERSION})");
    }
    let count = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    if count == 0 || count > MAX_SECTIONS {
        bail!("implausible section count {count}");
    }
    let table_end = HEADER_BYTES + count * TABLE_ENTRY_BYTES;
    if bytes.len() < table_end {
        bail!(
            "truncated .pqm: section table needs {table_end} bytes, file has {}",
            bytes.len()
        );
    }
    let mut sections = Vec::with_capacity(count);
    for i in 0..count {
        let e = &bytes[HEADER_BYTES + i * TABLE_ENTRY_BYTES..];
        let s = Section {
            kind: u16::from_le_bytes(e[0..2].try_into().unwrap()),
            index: u16::from_le_bytes(e[2..4].try_into().unwrap()),
            offset: u64::from_le_bytes(e[4..12].try_into().unwrap()),
            len: u64::from_le_bytes(e[12..20].try_into().unwrap()),
            crc: u32::from_le_bytes(e[20..24].try_into().unwrap()),
        };
        let end = s.offset.checked_add(s.len).unwrap_or(u64::MAX);
        if s.offset < table_end as u64 || end > bytes.len() as u64 {
            bail!(
                "truncated .pqm: section {} [{}+{}] exceeds file size {}",
                kind_name(s.kind),
                s.offset,
                s.len,
                bytes.len()
            );
        }
        sections.push(s);
    }
    Ok(sections)
}

fn payload<'a>(bytes: &'a [u8], s: &Section) -> &'a [u8] {
    &bytes[s.offset as usize..(s.offset + s.len) as usize]
}

/// CRC-verify every section against its table entry.
fn verify_crcs(bytes: &[u8], sections: &[Section]) -> Result<()> {
    for s in sections {
        let got = crc32(payload(bytes, s));
        if got != s.crc {
            bail!(
                "section {}[{}] CRC mismatch: file says {:#010x}, payload hashes to {got:#010x} — artifact is corrupted",
                kind_name(s.kind),
                s.index,
                s.crc
            );
        }
    }
    Ok(())
}

/// Find exactly one section of `k` in the table.
fn find_one(sections: &[Section], k: u16) -> Result<&Section> {
    let mut found = None;
    for s in sections {
        if s.kind == k {
            if found.is_some() {
                bail!("duplicate {} section", kind_name(k));
            }
            found = Some(s);
        }
    }
    found.ok_or_else(|| anyhow::anyhow!("missing {} section", kind_name(k)))
}

/// Decode a raw-f32 section of `k`, checking the element count.
fn f32_section(bytes: &[u8], sections: &[Section], k: u16, want: usize) -> Result<Vec<f32>> {
    let s = find_one(sections, k)?;
    let mut r = codec::ByteReader::new(payload(bytes, s));
    let xs = r.f32_raw((s.len / 4) as usize)?;
    r.finish()?;
    if xs.len() != want {
        bail!("{} has {} elements, config wants {want}", kind_name(k), xs.len());
    }
    Ok(xs)
}

/// Deserialize a `.pqm` artifact from bytes, verifying every section CRC.
pub fn load_pqm_bytes(bytes: &[u8]) -> Result<PqmModel> {
    let sections = parse_table(bytes)?;
    verify_crcs(bytes, &sections)?;

    let cfg = codec::decode_config(payload(bytes, find_one(&sections, kind::CONFIG)?))?;
    let d = cfg.d_model;

    let embed = f32_section(bytes, &sections, kind::EMBED, cfg.vocab * d)?;
    let lm_head = f32_section(bytes, &sections, kind::LM_HEAD, d * cfg.vocab)?;
    let final_norm = f32_section(bytes, &sections, kind::FINAL_NORM, d)?;

    let mut blocks: Vec<Option<PackedBlock>> = (0..cfg.n_layers).map(|_| None).collect();
    for s in &sections {
        if s.kind != kind::BLOCK {
            continue;
        }
        let l = s.index as usize;
        if l >= cfg.n_layers {
            bail!("block section index {l} out of range (n_layers {})", cfg.n_layers);
        }
        if blocks[l].is_some() {
            bail!("duplicate block section for layer {l}");
        }
        blocks[l] = Some(
            codec::decode_block(payload(bytes, s), &cfg)
                .with_context(|| format!("decoding block {l}"))?,
        );
    }
    let blocks: Vec<PackedBlock> = blocks
        .into_iter()
        .enumerate()
        .map(|(l, b)| b.ok_or_else(|| anyhow::anyhow!("missing block section for layer {l}")))
        .collect::<Result<_>>()?;

    let tokenizer = match sections.iter().find(|s| s.kind == kind::TOKENIZER) {
        Some(s) => {
            let text = std::str::from_utf8(payload(bytes, s))
                .context("tokenizer section is not UTF-8")?;
            Some(Bpe::from_json(&Json::parse(text)?).context("parsing tokenizer section")?)
        }
        None => None,
    };

    Ok(PqmModel {
        model: PackedModel {
            cfg,
            embed,
            lm_head,
            final_norm,
            blocks,
            rope: Default::default(),
        },
        tokenizer,
    })
}

/// Load a `.pqm` artifact from disk (one sequential read + CRC checks).
pub fn load_pqm(path: impl AsRef<Path>) -> Result<PqmModel> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    load_pqm_bytes(&bytes).with_context(|| format!("loading .pqm artifact {path:?}"))
}

// ---------------------------------------------------------------- inspect

/// Cheap header-level view of an artifact: config + section table, with
/// only the config payload CRC-verified (tensor payloads are not decoded).
#[derive(Debug, Clone)]
pub struct PqmInfo {
    pub version: u32,
    pub file_bytes: u64,
    pub config: ModelConfig,
    pub has_tokenizer: bool,
    pub sections: Vec<Section>,
}

pub fn inspect_pqm_bytes(bytes: &[u8]) -> Result<PqmInfo> {
    let sections = parse_table(bytes)?;
    let cfg_section = sections
        .iter()
        .find(|s| s.kind == kind::CONFIG)
        .ok_or_else(|| anyhow::anyhow!("missing config section"))?;
    verify_crcs(bytes, std::slice::from_ref(cfg_section))?;
    let config = codec::decode_config(payload(bytes, cfg_section))?;
    Ok(PqmInfo {
        // Report the version the *file* declares, not our compiled-in
        // constant — they only coincide while exactly one version exists.
        version: u32::from_le_bytes(bytes[8..12].try_into().unwrap()),
        file_bytes: bytes.len() as u64,
        config,
        has_tokenizer: sections.iter().any(|s| s.kind == kind::TOKENIZER),
        sections,
    })
}

pub fn inspect_pqm(path: impl AsRef<Path>) -> Result<PqmInfo> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    inspect_pqm_bytes(&bytes).with_context(|| format!("inspecting .pqm artifact {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Variant;

    fn nano(variant: Variant) -> PackedModel {
        PackedModel::random(
            &ModelConfig {
                name: format!("pqm-{}", variant.name()),
                variant,
                vocab: 64,
                d_model: 32,
                n_layers: 2,
                n_heads: 2,
                d_ff: 96,
                r: if variant == Variant::PQuant { 16 } else { 0 },
                n_experts: if variant == Variant::PQuant { 2 } else { 1 },
                seq_len: 16,
                alpha_init: 2.0,
                beta_init: 0.2,
            },
            7,
        )
    }

    #[test]
    fn crc32_known_vector() {
        // The canonical IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_preserves_generation() {
        for v in [Variant::Fp16, Variant::BitNet, Variant::BitNet158, Variant::PQuant] {
            let mut m = nano(v);
            let bytes = save_pqm_bytes(&m, None);
            let mut loaded = load_pqm_bytes(&bytes).unwrap().model;
            assert_eq!(loaded.cfg, m.cfg, "{v:?}");
            assert_eq!(loaded.generate(&[1, 2, 3], 6), m.generate(&[1, 2, 3], 6), "{v:?}");
        }
    }

    #[test]
    fn tokenizer_section_roundtrips() {
        let m = nano(Variant::BitNet);
        let bpe = Bpe::train("the quick brown fox the quick brown fox jumps ", 280);
        let bytes = save_pqm_bytes(&m, Some(&bpe));
        let loaded = load_pqm_bytes(&bytes).unwrap();
        let tok = loaded.tokenizer.expect("tokenizer section present");
        assert_eq!(tok.encode("the quick fox"), bpe.encode("the quick fox"));
        assert!(load_pqm_bytes(&save_pqm_bytes(&m, None)).unwrap().tokenizer.is_none());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = save_pqm_bytes(&nano(Variant::BitNet), None);
        bytes[0] ^= 0xFF;
        let err = load_pqm_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn rejects_future_version() {
        let mut bytes = save_pqm_bytes(&nano(Variant::BitNet), None);
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        let err = load_pqm_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn rejects_corruption_with_crc_error() {
        let mut bytes = save_pqm_bytes(&nano(Variant::PQuant), None);
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        let err = load_pqm_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
    }

    #[test]
    fn rejects_truncation() {
        let bytes = save_pqm_bytes(&nano(Variant::Fp16), None);
        for cut in [4, HEADER_BYTES + 3, bytes.len() - 9] {
            let err = load_pqm_bytes(&bytes[..cut]).unwrap_err().to_string();
            assert!(err.contains("truncated"), "cut {cut}: {err}");
        }
    }

    #[test]
    fn inspect_reads_config_without_decoding_tensors() {
        let m = nano(Variant::PQuant);
        let bytes = save_pqm_bytes(&m, None);
        let info = inspect_pqm_bytes(&bytes).unwrap();
        assert_eq!(info.config, m.cfg);
        assert_eq!(info.file_bytes, bytes.len() as u64);
        assert!(!info.has_tokenizer);
        // 4 fixed sections + 2 blocks
        assert_eq!(info.sections.len(), 6);
        // Corrupting a block payload does not break inspect (config-only CRC) …
        let mut corrupt = bytes.clone();
        let last = corrupt.len() - 1;
        corrupt[last] ^= 1;
        assert!(inspect_pqm_bytes(&corrupt).is_ok());
        // … but a full load rejects it.
        assert!(load_pqm_bytes(&corrupt).is_err());
    }
}
