//! # pQuant — decoupled linear QAT-from-scratch for extremely low-bit LMs
//!
//! Rust L3 coordinator of the three-layer reproduction (see `DESIGN.md`):
//! JAX/Pallas author the model at build time (`make artifacts`), this crate
//! owns everything at runtime — training orchestration, quantized inference,
//! serving, evaluation, and every paper experiment.
//!
//! Module map:
//! * [`artifact`] — versioned binary `.pqm` packed-model artifacts
//!   (section table + CRC32), the export/load half of the deployment story
//! * [`config`] — model/variant configurations mirroring `python/compile/configs.py`
//! * [`tensor`] — dense matrix type + the linear algebra the sensitivity
//!   analysis needs (Cholesky inverse)
//! * [`quant`] — sign/absmean 1-bit, ternary, INT8 absmax, group/channel
//!   quantizers + bit-packing (8 weights/byte)
//! * [`gemm`] — the Figure-8 engines: f32 GEMM, INT8 GEMM, T-MAC-style LUT
//!   W1A8 GEMV, packed ternary GEMV, plus their weight-stationary batched
//!   twins ([`gemm::batched`]: each packed weight column read once per
//!   batch step, bit-identical to the GEMV paths); inner loops run behind
//!   runtime CPU-feature dispatch ([`gemm::simd`]: AVX2/NEON with the
//!   scalar loops as the always-on bit-exactness oracle, `PQUANT_SIMD`
//!   override — see `docs/performance.md`)
//! * [`infer`] — pure-rust packed-weight transformer inference engine:
//!   single-token decode, and the fused batched path
//!   ([`infer::PackedModel::decode_step_batch`] over [`infer::SeqStep`]s
//!   with a per-worker allocation-free [`infer::Scratch`]; precomputed
//!   RoPE tables, opt-in per-component timing)
//! * [`kvcache`] — paged KV-cache subsystem: fixed block budget
//!   ([`kvcache::BlockPool`]), per-sequence page tables with copy-on-write
//!   ([`kvcache::PagedSeq`]), prompt-prefix sharing, and recoverable
//!   [`kvcache::KvError`]s in place of overflow panics; attention decodes
//!   paged and contiguous caches bit-identically via [`kvcache::KvStore`]
//! * [`runtime`] — PJRT client wrapper: load HLO-text artifacts, thread
//!   training state through the AOT train step
//! * [`coordinator`] — two-phase schedule, training loop, checkpoints,
//!   stability monitor
//! * [`serve`] — the persistent [`serve::Engine`] session API (streaming
//!   tickets, per-request sampling, cancellation, bounded-queue
//!   backpressure, chunked prefill, KV-budgeted admission with priority
//!   preemption over a [`kvcache::BlockPool`]) over the multi-model
//!   [`serve::ModelRegistry`] (lease-counted replicas, warm hot-swap);
//!   workers advance the whole active set with one fused
//!   weight-stationary batch step per round (decode rows + prefill-chunk
//!   rows + speculative verify runs), bit-exact with unbatched decoding;
//!   [`serve::spec`] adds end-to-end speculative decoding — a
//!   registry-leased draft proposes K tokens, the target verifies all
//!   K+1 positions as rows of the same fused step, rejected suffixes
//!   roll their KV pages back, and greedy output stays bit-identical to
//!   [`infer::PackedModel::generate`]; [`serve::http`] opens the network
//!   front door — a dependency-free HTTP/1.1 + SSE server (`POST
//!   /v1/generate` streams ticket events, disconnect cancels, queue/KV
//!   backpressure maps to 429/503 with typed retry hints) — and
//!   [`serve::loadgen`] replays seeded bursty traces against it (or the
//!   in-process engine) and reports per-tier SLO attainment
//! * [`obs`] — zero-dependency observability core: lock-free log-bucketed
//!   histograms + a counter/gauge [`obs::Registry`] (no locks on the
//!   record path), opt-in per-request [`obs::trace`] span recording
//!   exported as Chrome trace-event JSON (Perfetto-loadable), and
//!   Prometheus text exposition ([`obs::prom`]) behind `GET /v1/metrics`
//!   content negotiation
//! * [`tokenizer`] — byte-level BPE
//! * [`data`] — synthetic grammar corpus + batch iterator
//! * [`sensitivity`] — OBS/SPQR sensitivity maps, democratization metrics
//! * [`eval`] — perplexity + synthetic zero-shot task suite
//! * [`memory`] — analytic memory-footprint model (Fig 6 / Tables 3, 6)
//! * [`report`] — paper-style table renderers
//! * [`experiments`] — one harness per paper table/figure
//! * [`util`] — offline substrates: JSON, RNG, bench + property harnesses,
//!   scoped thread pool

pub mod artifact;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod gemm;
pub mod infer;
pub mod kvcache;
pub mod memory;
pub mod obs;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sensitivity;
pub mod serve;
pub mod tensor;
pub mod tokenizer;
pub mod util;
