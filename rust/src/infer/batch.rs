//! Batched decode substrate: shared per-row quantized activations, the
//! per-worker [`Scratch`] arena, and the [`SeqStep`] descriptor that lets
//! one fused forward pass advance a mixed set of sequences.
//!
//! The design is weight-stationary end to end: a batch step quantizes all
//! B rows once ([`QuantActsBatch`]), builds all per-row LUTs once, then
//! every linear reads each packed weight column a single time for the
//! whole batch ([`crate::gemm::batched`]). All intermediate buffers live
//! in the [`Scratch`], so the steady-state decode loop performs **zero
//! heap allocations** in the linear layers once capacities are warm
//! (verified by `tests/alloc_free.rs`).
//!
//! Rows are sequences' next tokens (decode) *or* prompt-chunk tokens
//! (prefill): a chunk of M prompt tokens is M rows of the same
//! [`SeqStep`], turning chunked prefill into an M-row GEMM instead of M
//! GEMVs. Attention stays per-sequence — each row has its own cache and
//! position — and within a step rows are attended in position order, so
//! batched output is bit-identical to the one-token-at-a-time path.

use crate::config::ModelConfig;
use crate::gemm::{self, lut::Luts, TernaryLuts};
use crate::kvcache::{KvError, KvStore, PagedLayer, PagedSeq};
use crate::quant;
use crate::util::align::AlignedVec;

use super::block::KvCache;

/// Per-batch quantized activations: B rows quantized once, per-row lookup
/// tables built once, shared by every linear reading the same input batch
/// (the batched form of [`QuantActs`](super::QuantActs)). Reusable: a
/// fresh [`QuantActsBatch::quantize_rows`] invalidates the tables without
/// releasing their storage.
#[derive(Default)]
pub struct QuantActsBatch {
    b: usize,
    k: usize,
    x_q: Vec<i8>,
    gammas: Vec<f32>,
    luts: Vec<Luts>,
    tluts: Vec<TernaryLuts>,
    luts_built: bool,
    tluts_built: bool,
    lut_builds: usize,
    grew: bool,
}

impl QuantActsBatch {
    pub fn new() -> QuantActsBatch {
        QuantActsBatch::default()
    }

    /// Quantize `b` rows of width `k` (row-major `xs`), invalidating any
    /// previously built tables. Per-row arithmetic is identical to
    /// [`QuantActs::quantize`](super::QuantActs::quantize).
    pub fn quantize_rows(&mut self, xs: &[f32], b: usize, k: usize) {
        assert!(xs.len() >= b * k);
        self.b = b;
        self.k = k;
        grow(&mut self.x_q, b * k, &mut self.grew);
        grow(&mut self.gammas, b, &mut self.grew);
        for r in 0..b {
            self.gammas[r] = quant::quantize_i8_row_into(
                &xs[r * k..(r + 1) * k],
                &mut self.x_q[r * k..(r + 1) * k],
            );
        }
        self.luts_built = false;
        self.tluts_built = false;
    }

    /// Rows in the current batch.
    pub fn rows(&self) -> usize {
        self.b
    }

    /// Row width of the current batch.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Per-row quantization scales γ.
    pub fn gammas(&self) -> &[f32] {
        &self.gammas[..self.b]
    }

    /// Quantized rows, row-major [b, k].
    pub fn x_q(&self) -> &[i8] {
        &self.x_q[..self.b * self.k]
    }

    /// One row's quantized activations.
    pub fn x_q_row(&self, r: usize) -> &[i8] {
        &self.x_q[r * self.k..(r + 1) * self.k]
    }

    /// Per-row group-of-4 LUTs for the 1-bit engines, built once per
    /// quantization (lazily, like the single-token path).
    pub fn luts(&mut self) -> &[Luts] {
        if !self.luts_built {
            if self.luts.len() < self.b {
                self.grew = true;
                self.luts.resize_with(self.b, || Luts { tables: Vec::new(), n_groups: 0 });
            }
            for r in 0..self.b {
                gemm::build_luts_into(
                    &self.x_q[r * self.k..(r + 1) * self.k],
                    self.k,
                    &mut self.luts[r],
                );
            }
            self.luts_built = true;
            self.lut_builds += 1;
        }
        &self.luts[..self.b]
    }

    /// Per-row byte-indexed tables for the ternary engine.
    pub fn ternary_luts(&mut self) -> &[TernaryLuts] {
        if !self.tluts_built {
            if self.tluts.len() < self.b {
                self.grew = true;
                self.tluts
                    .resize_with(self.b, || TernaryLuts { tables: Vec::new(), n_groups: 0 });
            }
            for r in 0..self.b {
                gemm::build_ternary_luts_into(
                    &self.x_q[r * self.k..(r + 1) * self.k],
                    self.k,
                    &mut self.tluts[r],
                );
            }
            self.tluts_built = true;
            self.lut_builds += 1;
        }
        &self.tluts[..self.b]
    }

    /// Table builds paid since construction (shared-read invariant probe:
    /// one per quantization per engine family, however many linears read
    /// the batch).
    pub fn lut_build_count(&self) -> usize {
        self.lut_builds
    }

    /// Pre-size the quantization buffers for up to `b` rows of width `k`
    /// (expert sub-batch sizes vary step to step, so steady-state
    /// allocation-freedom needs the worst case reserved up front).
    pub(crate) fn reserve(&mut self, b: usize, k: usize) {
        grow(&mut self.x_q, b * k, &mut self.grew);
        grow(&mut self.gammas, b, &mut self.grew);
    }

    fn take_grew(&mut self) -> bool {
        std::mem::replace(&mut self.grew, false)
    }
}

/// Integer/float accumulator scratch for the batched kernels' [n, b]
/// outputs, reused across every linear of a batch step. Backed by
/// [`AlignedVec`] so the planes start on a 32-byte vector boundary for
/// the SIMD kernels (layout only — the kernels use unaligned loads and
/// are bit-identical either way).
#[derive(Default)]
pub struct AccScratch {
    yi: AlignedVec<i32>,
    yf: AlignedVec<f32>,
    grew: bool,
}

impl AccScratch {
    pub fn i32_acc(&mut self, len: usize) -> &mut [i32] {
        self.grew |= self.yi.grow(len);
        self.yi.slice_mut(len)
    }

    pub fn f32_acc(&mut self, len: usize) -> &mut [f32] {
        self.grew |= self.yf.grow(len);
        self.yf.slice_mut(len)
    }
}

/// Grow-only resize that records whether a reallocation happened.
pub(crate) fn grow<T: Clone + Default>(v: &mut Vec<T>, len: usize, grew: &mut bool) {
    if v.len() < len {
        if len > v.capacity() {
            *grew = true;
        }
        v.resize(len, T::default());
    }
}

/// Grow-only resize in power-of-two jumps, for buffers whose need creeps
/// up by one each token (attention scores): from a warm state the next
/// reallocation is a doubling, not every step.
pub(crate) fn grow_pow2(v: &mut Vec<f32>, need: usize, grew: &mut bool) {
    if v.len() < need {
        let cap = need.next_power_of_two();
        if cap > v.capacity() {
            *grew = true;
        }
        v.resize(cap, 0.0);
    }
}

/// One sequence's KV state inside a batch step: the contiguous fast path
/// or a paged sequence — mixes freely within one batch.
pub enum BatchKv<'a> {
    Contig(&'a mut [KvCache]),
    Paged(&'a mut PagedSeq),
}

impl BatchKv<'_> {
    /// Tokens already cached for this sequence.
    pub fn len(&self) -> usize {
        match self {
            BatchKv::Contig(c) => c.first().map_or(0, |k| k.len),
            BatchKv::Paged(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// One layer's cache view for attention.
    pub(crate) fn layer(&mut self, l: usize) -> KvLayerRef<'_> {
        match self {
            BatchKv::Contig(c) => KvLayerRef::Contig(&mut c[l]),
            BatchKv::Paged(s) => KvLayerRef::Paged(s.layer(l)),
        }
    }
}

/// Layer-level cache handle unifying the two layouts behind [`KvStore`],
/// so the batched attention walks either bit-identically.
pub(crate) enum KvLayerRef<'a> {
    Contig(&'a mut KvCache),
    Paged(PagedLayer<'a>),
}

impl KvStore for KvLayerRef<'_> {
    fn len(&self) -> usize {
        match self {
            KvLayerRef::Contig(c) => c.len,
            KvLayerRef::Paged(p) => p.len(),
        }
    }

    fn push(&mut self, k: &[f32], v: &[f32]) -> Result<(), KvError> {
        match self {
            KvLayerRef::Contig(c) => c.push(k, v),
            KvLayerRef::Paged(p) => p.push(k, v),
        }
    }

    fn for_each_seg<'a>(&'a self, f: &mut dyn FnMut(crate::kvcache::KvSegment<'a>)) {
        match self {
            KvLayerRef::Contig(c) => c.for_each_seg(f),
            KvLayerRef::Paged(p) => p.for_each_seg(f),
        }
    }
}

/// One sequence's contribution to a fused batch step: its next tokens
/// (one for decode, a prompt chunk for prefill, a speculative verify run),
/// the position of the first, and its KV state. `err` is set by the step
/// if this sequence's cache failed — the other sequences in the batch are
/// unaffected.
pub struct SeqStep<'a> {
    pub tokens: &'a [u32],
    pub pos: usize,
    pub kv: BatchKv<'a>,
    /// Compute logits for the last row (decode rows and prompt-completing
    /// prefill chunks want them; interior prefill chunks skip the lm_head).
    pub want_logits: bool,
    /// Compute logits for *every* row — the speculative verify path, where
    /// each of the K+1 run rows is checked against the draft's proposal.
    pub all_logits: bool,
    pub err: Option<KvError>,
}

impl<'a> SeqStep<'a> {
    pub fn new(tokens: &'a [u32], pos: usize, kv: BatchKv<'a>, want_logits: bool) -> SeqStep<'a> {
        SeqStep { tokens, pos, kv, want_logits, all_logits: false, err: None }
    }

    /// A step whose every row wants logits (speculative verification).
    pub fn with_all_logits(tokens: &'a [u32], pos: usize, kv: BatchKv<'a>) -> SeqStep<'a> {
        SeqStep { tokens, pos, kv, want_logits: true, all_logits: true, err: None }
    }

    /// Logits rows this step asks the lm_head for.
    pub(crate) fn wanted_rows(&self) -> usize {
        if self.err.is_some() || self.tokens.is_empty() {
            0
        } else if self.all_logits {
            self.tokens.len()
        } else if self.want_logits {
            1
        } else {
            0
        }
    }
}

/// Per-worker scratch arena for the fused batch step: every intermediate
/// the forward pass needs, grown on demand and reused forever after.
/// Holding one per serving worker makes the steady-state decode loop
/// allocation-free in the linear layers.
#[derive(Default)]
pub struct Scratch {
    /// Residual rows [b, d]; taken/returned by `decode_step_batch`.
    pub(crate) xs: Vec<f32>,
    /// Normed rows [b, d].
    pub(crate) xn: Vec<f32>,
    pub(crate) q: Vec<f32>,
    pub(crate) kr: Vec<f32>,
    pub(crate) v: Vec<f32>,
    pub(crate) ctx: Vec<f32>,
    pub(crate) o: Vec<f32>,
    /// FFN hidden rows [b, d_ff-ish].
    pub(crate) h1: Vec<f32>,
    pub(crate) y1: Vec<f32>,
    /// Router logits [b, n_experts].
    pub(crate) router: Vec<f32>,
    pub(crate) gates: Vec<f32>,
    pub(crate) eidx: Vec<usize>,
    /// Per-expert row-index groups (reused; capacity b each).
    pub(crate) groups: Vec<Vec<usize>>,
    /// Gathered expert inputs [g, d] (i8) and hidden/output rows.
    pub(crate) xq_g: Vec<i8>,
    pub(crate) hg: Vec<f32>,
    pub(crate) yg: Vec<f32>,
    /// Per-sequence attention score buffers (pow2 growth), so sequences'
    /// attention can run on separate threads within one batch step.
    pub(crate) scores_pool: Vec<Vec<f32>>,
    /// Gathered final-norm rows for the batched lm_head.
    pub(crate) head_rows: Vec<f32>,
    /// Logits rows [wanted, vocab], packed in step order; per-step slot
    /// table below. A `want_logits` step owns one row (its last), an
    /// `all_logits` step owns one per token row.
    pub(crate) logits: Vec<f32>,
    /// First logits slot of each step, and how many it owns.
    pub(crate) step_logit0: Vec<usize>,
    pub(crate) step_logit_n: Vec<usize>,
    pub(crate) acts: QuantActsBatch,
    pub(crate) acts_ctx: QuantActsBatch,
    pub(crate) acts_h: QuantActsBatch,
    pub(crate) acts_e: QuantActsBatch,
    pub(crate) acc: AccScratch,
    pub(crate) vocab: usize,
    pub(crate) grew: bool,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Size every buffer for a batch of `b` rows over `n_steps` sequences
    /// of this model geometry. Grow-only; steady state is a no-op.
    pub(crate) fn ensure(&mut self, cfg: &ModelConfig, b: usize, n_steps: usize) {
        let d = cfg.d_model;
        let h_max = cfg.d_ff.max(d);
        let n_exp = cfg.n_experts.max(1);
        let g = &mut self.grew;
        grow(&mut self.xs, b * d, g);
        grow(&mut self.xn, b * d, g);
        grow(&mut self.q, b * d, g);
        grow(&mut self.kr, b * d, g);
        grow(&mut self.v, b * d, g);
        grow(&mut self.ctx, b * d, g);
        grow(&mut self.o, b * d, g);
        grow(&mut self.h1, b * h_max, g);
        grow(&mut self.y1, b * d, g);
        grow(&mut self.router, b * n_exp, g);
        grow(&mut self.gates, b, g);
        grow(&mut self.eidx, b, g);
        if self.groups.len() < n_exp {
            *g = true;
            self.groups.resize_with(n_exp, Vec::new);
        }
        for grp in &mut self.groups {
            if grp.capacity() < b {
                *g = true;
                grp.reserve(b - grp.capacity());
            }
        }
        grow(&mut self.xq_g, b * d, g);
        grow(&mut self.hg, b * cfg.r.max(1), g);
        grow(&mut self.yg, b * d, g);
        self.acts_e.reserve(b, cfg.r.max(1));
        if self.scores_pool.len() < n_steps {
            *g = true;
            self.scores_pool.resize_with(n_steps, Vec::new);
        }
        // Worst case every row of every step wants logits (speculative
        // verify runs), so the head buffers are sized by rows, not steps.
        grow(&mut self.head_rows, b * d, g);
        grow(&mut self.logits, b * cfg.vocab, g);
        grow(&mut self.step_logit0, n_steps, g);
        grow(&mut self.step_logit_n, n_steps, g);
        self.vocab = cfg.vocab;
    }

    /// Logits row of step `si` from the last batch step — the *last*
    /// wanted row (the only one for decode/prefill steps; the final run
    /// row for an `all_logits` verify step). Valid only for steps that
    /// wanted logits and did not error.
    pub fn logits_row(&self, si: usize) -> &[f32] {
        let n = self.step_logit_n[si];
        debug_assert!(n > 0, "step {si} computed no logits");
        let slot = self.step_logit0[si] + n - 1;
        &self.logits[slot * self.vocab..(slot + 1) * self.vocab]
    }

    /// Logits of row `j` of step `si` (speculative verification reads all
    /// K+1 rows of its run).
    pub fn logits_row_at(&self, si: usize, j: usize) -> &[f32] {
        debug_assert!(j < self.step_logit_n[si], "row {j} of step {si} has no logits");
        let slot = self.step_logit0[si] + j;
        &self.logits[slot * self.vocab..(slot + 1) * self.vocab]
    }

    /// Did any buffer reallocate since the last call? Steady-state decode
    /// must answer `false` — the allocation-free invariant probe.
    pub fn take_grew(&mut self) -> bool {
        let children = self.acts.take_grew()
            | self.acts_ctx.take_grew()
            | self.acts_h.take_grew()
            | self.acts_e.take_grew()
            | std::mem::replace(&mut self.acc.grew, false);
        std::mem::replace(&mut self.grew, false) | children
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::{QuantActs, QLinear};
    use crate::util::rng::Rng;

    #[test]
    fn batch_quantization_matches_single_rows_bitexactly() {
        let mut rng = Rng::new(11);
        let (b, k) = (5, 96);
        let xs = rng.normal_vec(b * k);
        let mut batch = QuantActsBatch::new();
        batch.quantize_rows(&xs, b, k);
        for r in 0..b {
            let single = QuantActs::quantize(&xs[r * k..(r + 1) * k]);
            assert_eq!(batch.x_q_row(r), &single.x_q[..], "row {r} x_q");
            assert_eq!(batch.gammas()[r], single.gamma, "row {r} gamma");
        }
    }

    #[test]
    fn batch_luts_built_once_and_shared_across_linears() {
        let mut rng = Rng::new(12);
        let (b, k, n) = (3, 64, 32);
        let up1 = QLinear::one_bit(&rng.normal_vec(k * n), k, n);
        let up8 = QLinear::int8(&rng.normal_vec(k * 16), k, 16);
        let xs = rng.normal_vec(b * k);
        let mut acts = QuantActsBatch::new();
        acts.quantize_rows(&xs, b, k);
        let mut acc = AccScratch::default();
        let mut y = vec![0.0f32; b * n];
        up1.forward_batch_into(&xs, &mut acts, &mut y, &mut acc);
        let tables_ptr = acts.luts()[0].tables.as_ptr();
        let mut y8 = vec![0.0f32; b * 16];
        up8.forward_batch_into(&xs, &mut acts, &mut y8, &mut acc);
        assert_eq!(acts.lut_build_count(), 1, "INT8 branch must reuse the quantization");
        assert_eq!(acts.luts()[0].tables.as_ptr(), tables_ptr, "tables rebuilt");
    }

    #[test]
    fn forward_batch_matches_single_forward_bitexactly() {
        let mut rng = Rng::new(13);
        let (b, k, n) = (4, 80, 24);
        for lin in [
            QLinear::one_bit(&rng.normal_vec(k * n), k, n),
            QLinear::ternary(&rng.normal_vec(k * n), k, n),
            QLinear::int8(&rng.normal_vec(k * n), k, n),
            QLinear::f32(&rng.normal_vec(k * n), k, n),
        ] {
            let xs = rng.normal_vec(b * k);
            let mut acts = QuantActsBatch::new();
            acts.quantize_rows(&xs, b, k);
            let mut acc = AccScratch::default();
            let mut y = vec![0.0f32; b * n];
            lin.forward_batch_into(&xs, &mut acts, &mut y, &mut acc);
            for r in 0..b {
                let row = &xs[r * k..(r + 1) * k];
                let mut single = QuantActs::quantize(row);
                let want = lin.forward(row, &mut single);
                assert_eq!(&y[r * n..(r + 1) * n], &want[..], "row {r}");
            }
        }
    }

    #[test]
    fn scratch_grow_is_tracked_and_settles() {
        let cfg = ModelConfig {
            name: "t".into(),
            variant: crate::config::Variant::PQuant,
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 48,
            r: 8,
            n_experts: 2,
            seq_len: 8,
            alpha_init: 2.0,
            beta_init: 0.2,
        };
        let mut s = Scratch::new();
        s.ensure(&cfg, 4, 4);
        assert!(s.take_grew(), "first ensure must grow");
        s.ensure(&cfg, 4, 4);
        assert!(!s.take_grew(), "steady-state ensure must not grow");
        s.ensure(&cfg, 2, 2);
        assert!(!s.take_grew(), "smaller batch must reuse capacity");
    }
}
