//! Pure-rust packed-weight inference engine (paper Appendix A).
//!
//! This is the deployment path: weights converted offline from a training
//! checkpoint into packed 1-bit / INT8 / ternary form, activations
//! quantized per token, and every linear executed by the multiply-free
//! engines in [`crate::gemm`].  Python and PJRT are *not* involved — this
//! engine backs the serving benches (Fig 8, §4.5 throughput) and the
//! edge-serving example.
//!
//! Numerics deliberately mirror `python/compile/model.py` (same RMSNorm,
//! RoPE, per-token absmax activation quantization, per-tensor weight
//! scales), so logits agree with the AOT fwd path up to activation
//! re-quantization order.

pub mod batch;
pub mod block;
pub mod model;

pub use batch::{BatchKv, QuantActsBatch, Scratch, SeqStep};
pub use block::{BlockTiming, KvCache, PackedBlock, RopeTable, TimingMode};
pub use model::PackedModel;

use crate::gemm::{self, lut::Luts, TernaryLuts};
use crate::quant::{self, PackedBits, PackedTernary};

use batch::AccScratch;

/// Per-token quantized activations, shared across every linear that reads
/// the same input vector (Appendix A: the fused-read optimization — build
/// the LUTs once, use them for Q/K/V and both FFN branches — including the
/// decoupled FFN's INT8 expert up-projection, which reads `x_q` instead of
/// re-quantizing its input).
pub struct QuantActs {
    pub x_q: Vec<i8>,
    pub gamma: f32,
    luts: Option<Luts>,
    tluts: Option<TernaryLuts>,
    lut_builds: usize,
}

impl QuantActs {
    pub fn quantize(x: &[f32]) -> QuantActs {
        let (x_q, gammas) = quant::quantize_i8_rows(x, 1, x.len());
        QuantActs { x_q, gamma: gammas[0], luts: None, tluts: None, lut_builds: 0 }
    }

    /// Lazily build the group-of-4 LUTs for the 1-bit path.
    pub fn luts(&mut self, k: usize) -> &Luts {
        if self.luts.is_none() {
            self.luts = Some(gemm::build_luts(&self.x_q, k));
            self.lut_builds += 1;
        }
        self.luts.as_ref().unwrap()
    }

    /// Lazily build the byte-indexed tables for the ternary path.
    pub fn ternary_luts(&mut self, k: usize) -> &TernaryLuts {
        if self.tluts.is_none() {
            self.tluts = Some(gemm::build_ternary_luts(&self.x_q, k));
            self.lut_builds += 1;
        }
        self.tluts.as_ref().unwrap()
    }

    /// How many table builds this activation set has paid for — the
    /// shared-read invariant probe: every linear fed the same input must
    /// reuse one build (asserted by tests, not just documented).
    pub fn lut_build_count(&self) -> usize {
        self.lut_builds
    }
}

/// A quantized (or full-precision) linear layer, [k, n], y = x·W.
/// `Clone` supports serving replicas; `PartialEq` is bit-exact on the
/// packed planes and scales (artifact round-trip tests).
#[derive(Clone, PartialEq)]
pub enum QLinear {
    /// f32 row-major weights (FP16-baseline engine).
    F32 { w: Vec<f32>, k: usize, n: usize },
    /// Packed ±1 with per-tensor λ (sign/absmean).
    OneBit { w: PackedBits, lambda: f32 },
    /// Packed ternary with per-tensor scale (BitNet1.58).
    Ternary { w: PackedTernary, scale: f32 },
    /// INT8 row-major weights with per-tensor γ_w.
    Int8 { w: Vec<i8>, gamma_w: f32, k: usize, n: usize },
}

impl QLinear {
    /// Build from latent f32 weights (row-major [k, n]).
    pub fn one_bit(wf: &[f32], k: usize, n: usize) -> QLinear {
        let b = quant::binarize(wf);
        QLinear::OneBit { w: quant::pack_signs(&b.signs, k, n), lambda: b.lambda }
    }

    pub fn ternary(wf: &[f32], k: usize, n: usize) -> QLinear {
        let t = quant::ternarize(wf);
        QLinear::Ternary { w: quant::pack_ternary(&t.vals, k, n), scale: t.scale }
    }

    pub fn int8(wf: &[f32], k: usize, n: usize) -> QLinear {
        assert_eq!(wf.len(), k * n);
        let q = quant::quantize_i8(wf);
        QLinear::Int8 { w: q.vals, gamma_w: q.gamma, k, n }
    }

    pub fn f32(wf: &[f32], k: usize, n: usize) -> QLinear {
        assert_eq!(wf.len(), k * n);
        QLinear::F32 { w: wf.to_vec(), k, n }
    }

    pub fn shape(&self) -> (usize, usize) {
        match self {
            QLinear::F32 { k, n, .. } => (*k, *n),
            QLinear::OneBit { w, .. } => (w.k, w.n),
            QLinear::Ternary { w, .. } => (w.k, w.n),
            QLinear::Int8 { k, n, .. } => (*k, *n),
        }
    }

    /// Weight bytes resident for this linear (memory accounting).
    pub fn storage_bytes(&self) -> usize {
        match self {
            QLinear::F32 { w, .. } => w.len() * 2, // counted as fp16
            QLinear::OneBit { w, .. } => w.storage_bytes(),
            QLinear::Ternary { w, .. } => w.storage_bytes(),
            QLinear::Int8 { w, .. } => w.len(),
        }
    }

    /// Raw INT8 parts `(w, gamma_w, k, n)` — the batched expert path
    /// gathers sub-batches per routed expert and needs the planes directly.
    pub fn int8_parts(&self) -> Option<(&[i8], f32, usize, usize)> {
        match self {
            QLinear::Int8 { w, gamma_w, k, n } => Some((w, *gamma_w, *k, *n)),
            _ => None,
        }
    }

    /// Batched y = X·W over B rows sharing one [`QuantActsBatch`]: each
    /// packed weight column is read once for the whole batch (weight-
    /// stationary), then dequantized into row-major `y` ([b, n]) with the
    /// per-row scale. Bit-identical to B calls of [`QLinear::forward`];
    /// allocation-free once `acc`'s capacity is warm.
    pub fn forward_batch_into(
        &self,
        xs: &[f32],
        acts: &mut QuantActsBatch,
        y: &mut [f32],
        acc: &mut AccScratch,
    ) {
        let (k, n) = self.shape();
        let b = acts.rows();
        debug_assert_eq!(xs.len(), b * k);
        debug_assert_eq!(y.len(), b * n);
        match self {
            QLinear::F32 { w, .. } => {
                let yf = acc.f32_acc(n * b);
                gemm::f32_gemm_batch_into(xs, w, b, k, n, yf);
                for r in 0..b {
                    let row = &mut y[r * n..(r + 1) * n];
                    for (j, out) in row.iter_mut().enumerate() {
                        *out = yf[j * b + r];
                    }
                }
            }
            QLinear::OneBit { w, lambda } => {
                debug_assert_eq!(acts.k(), w.k);
                let yi = acc.i32_acc(n * b);
                gemm::lut_gemm_into(acts.luts(), w, yi);
                for r in 0..b {
                    let scale = lambda / acts.gammas()[r];
                    let row = &mut y[r * n..(r + 1) * n];
                    for (j, out) in row.iter_mut().enumerate() {
                        *out = yi[j * b + r] as f32 * scale;
                    }
                }
            }
            QLinear::Ternary { w, scale } => {
                debug_assert_eq!(acts.k(), w.k);
                let yi = acc.i32_acc(n * b);
                gemm::ternary_gemm_into(acts.ternary_luts(), w, yi);
                for r in 0..b {
                    let s = scale / acts.gammas()[r];
                    let row = &mut y[r * n..(r + 1) * n];
                    for (j, out) in row.iter_mut().enumerate() {
                        *out = yi[j * b + r] as f32 * s;
                    }
                }
            }
            QLinear::Int8 { w, gamma_w, .. } => {
                debug_assert_eq!(acts.k(), k);
                let yi = acc.i32_acc(n * b);
                gemm::i8_gemm_batch_into(acts.x_q(), w, b, k, n, yi);
                for r in 0..b {
                    let s = 1.0 / (gamma_w * acts.gammas()[r]);
                    let row = &mut y[r * n..(r + 1) * n];
                    for (j, out) in row.iter_mut().enumerate() {
                        *out = yi[j * b + r] as f32 * s;
                    }
                }
            }
        }
    }

    /// y = x·W for one token, reusing the shared quantized activations.
    pub fn forward(&self, x: &[f32], acts: &mut QuantActs) -> Vec<f32> {
        match self {
            QLinear::F32 { w, k, n } => gemm::f32_gemv(x, w, *k, *n),
            QLinear::OneBit { w, lambda } => {
                let scale = lambda / acts.gamma;
                let luts = acts.luts(w.k);
                gemm::lut_gemv(luts, w)
                    .into_iter()
                    .map(|v| v as f32 * scale)
                    .collect()
            }
            QLinear::Ternary { w, scale } => {
                let s = scale / acts.gamma;
                let luts = acts.ternary_luts(w.k);
                let mut y = vec![0i32; w.n];
                gemm::ternary_gemv_into(luts, w, &mut y);
                y.into_iter().map(|v| v as f32 * s).collect()
            }
            QLinear::Int8 { w, gamma_w, k, n } => {
                let s = 1.0 / (gamma_w * acts.gamma);
                gemm::i8_gemv(&acts.x_q[..*k], w, *k, *n)
                    .into_iter()
                    .map(|v| v as f32 * s)
                    .collect()
            }
        }
    }
}

/// RMSNorm ε (same as the L1 kernel).
const RMS_EPS: f32 = 1e-5;

/// RMSNorm one vector into a caller-owned buffer (the allocation-free
/// batched decode path); same arithmetic as [`rmsnorm_vec`].
pub fn rmsnorm_into(x: &[f32], gain: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + RMS_EPS).sqrt();
    for ((o, v), g) in out.iter_mut().zip(x).zip(gain) {
        *o = v * r * g;
    }
}

/// RMSNorm over one vector (same ε as the L1 kernel).
pub fn rmsnorm_vec(x: &[f32], gain: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    rmsnorm_into(x, gain, &mut out);
    out
}

/// SiLU activation.
pub fn silu(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = *v / (1.0 + (-*v).exp());
    }
}

/// In-place softmax.
pub fn softmax(x: &mut [f32]) {
    let max = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in x.iter_mut() {
        *v /= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn one_bit_linear_tracks_float() {
        let mut rng = Rng::new(1);
        let (k, n) = (128, 64);
        let wf = rng.normal_vec(k * n);
        let lin = QLinear::one_bit(&wf, k, n);
        let x = rng.normal_vec(k);
        let mut acts = QuantActs::quantize(&x);
        let y = lin.forward(&x, &mut acts);
        // ground truth against the dequantized weights
        let b = quant::binarize(&wf);
        let deq = quant::dequant_binary(&b);
        let want = gemm::f32_gemv(&x, &deq, k, n);
        let scale = want.iter().fold(0.0f32, |m, v| m.max(v.abs())) + 1e-6;
        for (g, w) in y.iter().zip(&want) {
            assert!((g - w).abs() / scale < 0.03, "{g} vs {w}");
        }
    }

    #[test]
    fn int8_linear_tracks_float() {
        let mut rng = Rng::new(2);
        let (k, n) = (96, 32);
        let wf = rng.normal_vec(k * n);
        let lin = QLinear::int8(&wf, k, n);
        let x = rng.normal_vec(k);
        let mut acts = QuantActs::quantize(&x);
        let y = lin.forward(&x, &mut acts);
        let want = gemm::f32_gemv(&x, &wf, k, n);
        let scale = want.iter().fold(0.0f32, |m, v| m.max(v.abs())) + 1e-6;
        for (g, w) in y.iter().zip(&want) {
            assert!((g - w).abs() / scale < 0.03, "{g} vs {w}");
        }
    }

    #[test]
    fn ternary_linear_tracks_dequant() {
        let mut rng = Rng::new(3);
        let (k, n) = (64, 16);
        let wf = rng.normal_vec(k * n);
        let lin = QLinear::ternary(&wf, k, n);
        let t = quant::ternarize(&wf);
        let deq: Vec<f32> = t.vals.iter().map(|&v| v as f32 * t.scale).collect();
        let x = rng.normal_vec(k);
        let mut acts = QuantActs::quantize(&x);
        let y = lin.forward(&x, &mut acts);
        let want = gemm::f32_gemv(&x, &deq, k, n);
        let scale = want.iter().fold(0.0f32, |m, v| m.max(v.abs())) + 1e-6;
        for (g, w) in y.iter().zip(&want) {
            assert!((g - w).abs() / scale < 0.03, "{g} vs {w}");
        }
    }

    #[test]
    fn luts_are_shared() {
        let mut rng = Rng::new(4);
        let x = rng.normal_vec(64);
        let mut acts = QuantActs::quantize(&x);
        let a = acts.luts(64) as *const _;
        let b = acts.luts(64) as *const _;
        assert_eq!(a, b, "LUTs must be built once");
    }

    #[test]
    fn one_bit_and_int8_linears_share_one_quantization() {
        // The decoupled FFN feeds the same normed input through the 1-bit
        // up-projection and the INT8 expert up-projection; both must read
        // the one shared QuantActs (one LUT build, one x_q buffer) rather
        // than re-quantizing.
        let mut rng = Rng::new(6);
        let (k, n1, r) = (64, 48, 16);
        let up1 = QLinear::one_bit(&rng.normal_vec(k * n1), k, n1);
        let up8 = QLinear::int8(&rng.normal_vec(k * r), k, r);
        let x = rng.normal_vec(k);
        let mut acts = QuantActs::quantize(&x);
        let xq_ptr = acts.x_q.as_ptr();
        let y1 = up1.forward(&x, &mut acts);
        let luts_ptr = acts.luts(k).tables.as_ptr();
        let y8 = up8.forward(&x, &mut acts);
        assert_eq!(acts.lut_build_count(), 1, "one LUT build for both branches");
        assert_eq!(acts.x_q.as_ptr(), xq_ptr, "x_q must not be reallocated");
        assert_eq!(acts.luts(k).tables.as_ptr(), luts_ptr, "tables must be reused");
        // And sharing must not change the numbers vs fresh activations.
        let mut fresh = QuantActs::quantize(&x);
        assert_eq!(y1, up1.forward(&x, &mut fresh));
        let mut fresh = QuantActs::quantize(&x);
        assert_eq!(y8, up8.forward(&x, &mut fresh));
    }

    #[test]
    fn storage_ordering() {
        let mut rng = Rng::new(5);
        let wf = rng.normal_vec(256 * 256);
        let f = QLinear::f32(&wf, 256, 256).storage_bytes();
        let t = QLinear::ternary(&wf, 256, 256).storage_bytes();
        let o = QLinear::one_bit(&wf, 256, 256).storage_bytes();
        let i = QLinear::int8(&wf, 256, 256).storage_bytes();
        assert!(o < t && t < i && i < f);
        assert_eq!(f, o * 16);
    }

    #[test]
    fn softmax_and_silu_sane() {
        let mut x = vec![0.0, 1.0, 2.0];
        softmax(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        let mut y = vec![-1.0, 0.0, 1.0];
        silu(&mut y);
        assert!((y[1]).abs() < 1e-7 && y[2] > 0.7 && y[0] < 0.0);
    }
}
