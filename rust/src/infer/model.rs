//! Full packed model: embedding, N blocks, head, and the decode loop —
//! plus conversion from a trained PJRT checkpoint (TrainState) into the
//! packed deployment form.

use anyhow::{bail, Result};

use crate::config::{ModelConfig, Variant};
use crate::kvcache::{KvError, PagedSeq};
use crate::runtime::{Artifact, TrainState};

use super::batch::{Scratch, SeqStep};
use super::block::{DecoupledFfn, Ffn, KvCache, PackedBlock, RopeTable, TimingMode};
use super::{rmsnorm_into, rmsnorm_vec, QLinear, QuantActs};

/// A deployable packed model. `Clone` yields an independent replica
/// (weights are immutable at serve time; only per-block timing and the
/// grown-on-demand RoPE table diverge).
#[derive(Clone)]
pub struct PackedModel {
    pub cfg: ModelConfig,
    /// Token embedding [vocab, d], full precision.
    pub embed: Vec<f32>,
    /// LM head [d, vocab], full precision.
    pub lm_head: Vec<f32>,
    pub final_norm: Vec<f32>,
    pub blocks: Vec<PackedBlock>,
    /// Precomputed RoPE sin/cos rows shared by every block (grown on
    /// demand; the hot loop never calls `powf`/`sin_cos`).
    pub rope: RopeTable,
}

impl PackedModel {
    /// Convert a training state into packed inference weights — the
    /// offline quantize-and-pack step of Appendix A.
    pub fn from_state(art: &Artifact, state: &TrainState) -> Result<PackedModel> {
        let cfg = art.manifest.config.clone();
        let d = cfg.d_model;
        let get = |name: &str| state.param_by_name(art, name);

        let (_, embed) = get("tok_embed")?;
        let (_, lm_head) = get("lm_head")?;
        let (_, final_norm) = get("final_norm")?;

        let mk = |wf: &[f32], k: usize, n: usize| -> QLinear {
            match cfg.variant {
                Variant::Fp16 => QLinear::f32(wf, k, n),
                Variant::BitNet | Variant::PQuant => QLinear::one_bit(wf, k, n),
                Variant::BitNet158 => QLinear::ternary(wf, k, n),
            }
        };

        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let p = |field: &str| get(&format!("layers.{l}.{field}"));
            let (_, attn_norm) = p("attn_norm")?;
            let (_, ffn_norm) = p("ffn_norm")?;
            let (_, wq) = p("wq")?;
            let (_, wk) = p("wk")?;
            let (_, wv) = p("wv")?;
            let (_, wo) = p("wo")?;

            let ffn = if cfg.variant == Variant::PQuant {
                let n1 = cfg.d_ff_1bit();
                let (_, up1) = p("ffn_up_1bit")?;
                let (_, dn1) = p("ffn_down_1bit")?;
                let (s_up8, up8) = p("ffn_up_8bit")?;
                let (_, dn8) = p("ffn_down_8bit")?;
                let (_, router) = p("router")?;
                let (_, alpha) = p("alpha")?;
                let (_, beta) = p("beta")?;
                if s_up8 != vec![cfg.n_experts, d, cfg.r] {
                    bail!("unexpected expert stack shape {s_up8:?}");
                }
                let experts = (0..cfg.n_experts)
                    .map(|e| {
                        let up = &up8[e * d * cfg.r..(e + 1) * d * cfg.r];
                        let dn = &dn8[e * cfg.r * d..(e + 1) * cfg.r * d];
                        (QLinear::int8(up, d, cfg.r), QLinear::int8(dn, cfg.r, d))
                    })
                    .collect();
                Ffn::Decoupled(DecoupledFfn {
                    up_1bit: QLinear::one_bit(&up1, d, n1),
                    down_1bit: QLinear::one_bit(&dn1, n1, d),
                    experts,
                    router,
                    alpha: alpha[0],
                    beta: beta[0],
                })
            } else {
                let (_, up) = p("ffn_up")?;
                let (_, dn) = p("ffn_down")?;
                Ffn::Dense { up: mk(&up, d, cfg.d_ff), down: mk(&dn, cfg.d_ff, d) }
            };

            blocks.push(PackedBlock {
                attn_norm,
                ffn_norm,
                wq: mk(&wq, d, d),
                wk: mk(&wk, d, d),
                wv: mk(&wv, d, d),
                wo: mk(&wo, d, d),
                ffn,
                n_heads: cfg.n_heads,
                timing: Default::default(),
            });
        }

        Ok(PackedModel { cfg, embed, lm_head, final_norm, blocks, rope: RopeTable::default() })
    }

    /// Random model of a given config (bench workloads).
    pub fn random(cfg: &ModelConfig, seed: u64) -> PackedModel {
        let mut rng = crate::util::rng::Rng::new(seed);
        let d = cfg.d_model;
        let blocks = (0..cfg.n_layers)
            .map(|l| {
                PackedBlock::random(
                    cfg.variant,
                    d,
                    cfg.n_heads,
                    cfg.d_ff,
                    cfg.r,
                    cfg.n_experts.max(1),
                    seed ^ (l as u64 + 1),
                )
            })
            .collect();
        PackedModel {
            cfg: cfg.clone(),
            embed: rng.normal_vec(cfg.vocab * d),
            lm_head: rng.normal_vec(d * cfg.vocab),
            final_norm: vec![1.0; d],
            blocks,
            rope: RopeTable::default(),
        }
    }

    /// Half head-dim of this geometry (the RoPE table's row width).
    fn rope_half(&self) -> usize {
        self.cfg.d_model / self.cfg.n_heads / 2
    }

    /// Enable or disable per-component decode timing on every block
    /// (opt-in: serving replicas default to [`TimingMode::Off`] so the
    /// hot loop pays no clock reads).
    pub fn set_timing(&mut self, mode: TimingMode) {
        for b in &mut self.blocks {
            b.timing.mode = mode;
        }
    }

    /// Fresh per-layer KV caches for a sequence budget.
    pub fn new_caches(&self, max_seq: usize) -> Vec<KvCache> {
        (0..self.cfg.n_layers)
            .map(|_| KvCache::new(max_seq, self.cfg.d_model))
            .collect()
    }

    /// Decode one token on caller-sized contiguous caches: returns the
    /// logits row [vocab]. Overflow is a sizing bug here — recoverable
    /// callers (the serving engine) use [`PackedModel::try_decode_step`]
    /// or [`PackedModel::decode_step_paged`].
    pub fn decode_step(&mut self, token: u32, pos: usize, caches: &mut [KvCache]) -> Vec<f32> {
        self.try_decode_step(token, pos, caches).expect("contiguous KV caches sized by caller")
    }

    /// Decode one token; a full cache is a recoverable error.
    pub fn try_decode_step(
        &mut self,
        token: u32,
        pos: usize,
        caches: &mut [KvCache],
    ) -> std::result::Result<Vec<f32>, KvError> {
        let d = self.cfg.d_model;
        self.rope.ensure(self.rope_half(), pos + 1);
        let mut x = self.embed[token as usize * d..(token as usize + 1) * d].to_vec();
        let rope = &self.rope;
        for (block, cache) in self.blocks.iter_mut().zip(caches.iter_mut()) {
            x = block.try_forward(&x, pos, cache, rope)?;
        }
        let xn = rmsnorm_vec(&x, &self.final_norm);
        Ok(crate::gemm::f32_gemv(&xn, &self.lm_head, d, self.cfg.vocab))
    }

    /// Decode one token against a paged sequence from a
    /// [`BlockPool`](crate::kvcache::BlockPool). Bit-identical to the
    /// contiguous path (both walk the cache as ordered segments); errors
    /// instead of panicking when the sequence outgrows its reservation.
    pub fn decode_step_paged(
        &mut self,
        token: u32,
        pos: usize,
        seq: &mut PagedSeq,
    ) -> std::result::Result<Vec<f32>, KvError> {
        let d = self.cfg.d_model;
        self.rope.ensure(self.rope_half(), pos + 1);
        let mut x = self.embed[token as usize * d..(token as usize + 1) * d].to_vec();
        let rope = &self.rope;
        for (l, block) in self.blocks.iter_mut().enumerate() {
            let mut layer = seq.layer(l);
            x = block.try_forward(&x, pos, &mut layer, rope)?;
        }
        let xn = rmsnorm_vec(&x, &self.final_norm);
        Ok(crate::gemm::f32_gemv(&xn, &self.lm_head, d, self.cfg.vocab))
    }

    /// One fused batch step over a mixed set of sequences (contiguous or
    /// paged KV, decoding or prefilling): every linear in every layer runs
    /// batched across all rows — each packed weight column read once per
    /// step — while attention and KV stay per-sequence. Greedy outputs are
    /// bit-identical to per-sequence [`PackedModel::decode_step`] calls
    /// (property-tested in `tests/integration_batch.rs`).
    ///
    /// Per-sequence cache failures land in [`SeqStep::err`] (the rest of
    /// the batch is unaffected). Logits of each step's last row — for
    /// steps with `want_logits` — are written into `scratch` and read back
    /// via [`Scratch::logits_row`]. Once `scratch` is warm, the loop
    /// performs no heap allocation in the linear layers
    /// (`tests/alloc_free.rs`).
    pub fn decode_step_batch(&mut self, steps: &mut [SeqStep<'_>], scratch: &mut Scratch) {
        let d = self.cfg.d_model;
        let b: usize = steps.iter().map(|s| s.tokens.len()).sum();
        if b == 0 {
            return;
        }
        let max_pos = steps.iter().map(|s| s.pos + s.tokens.len()).max().unwrap_or(1);
        self.rope.ensure(self.rope_half(), max_pos);
        scratch.ensure(&self.cfg, b, steps.len());

        // Embed every row.
        let mut xs = std::mem::take(&mut scratch.xs);
        {
            let mut r = 0usize;
            for step in steps.iter() {
                for &tok in step.tokens {
                    let t = tok as usize;
                    xs[r * d..(r + 1) * d].copy_from_slice(&self.embed[t * d..(t + 1) * d]);
                    r += 1;
                }
            }
        }

        let rope = &self.rope;
        for (l, block) in self.blocks.iter_mut().enumerate() {
            block.try_forward_batch(l, &mut xs[..b * d], steps, rope, scratch);
        }

        // Final norm + batched lm_head for the rows that want logits: one
        // row per decode/prefill-completing step, every run row for a
        // speculative verify step (`all_logits`). Slots are packed in step
        // order; the per-step table lets callers read them back by
        // (step, row).
        let mut logits = std::mem::take(&mut scratch.logits);
        let mut w = 0usize;
        let mut r0 = 0usize;
        for (si, step) in steps.iter().enumerate() {
            let rows = step.tokens.len();
            let wanted = step.wanted_rows();
            scratch.step_logit0[si] = w;
            scratch.step_logit_n[si] = wanted;
            for j in 0..wanted {
                // `Last` wants the single final row; `All` wants each row.
                let r = r0 + rows - wanted + j;
                rmsnorm_into(
                    &xs[r * d..(r + 1) * d],
                    &self.final_norm,
                    &mut scratch.head_rows[w * d..(w + 1) * d],
                );
                w += 1;
            }
            r0 += rows;
        }
        if w > 0 {
            let vocab = self.cfg.vocab;
            let yf = scratch.acc.f32_acc(vocab * w);
            crate::gemm::f32_gemm_batch_into(
                &scratch.head_rows[..w * d],
                &self.lm_head,
                w,
                d,
                vocab,
                yf,
            );
            for wi in 0..w {
                let row = &mut logits[wi * vocab..(wi + 1) * vocab];
                for (j, out) in row.iter_mut().enumerate() {
                    *out = yf[j * w + wi];
                }
            }
        }
        scratch.logits = logits;
        scratch.xs = xs;
    }

    /// Greedy generation: feed `prompt`, then emit `n_new` tokens.
    pub fn generate(&mut self, prompt: &[u32], n_new: usize) -> Vec<u32> {
        let mut caches = self.new_caches(prompt.len() + n_new);
        let mut logits = vec![0.0f32; self.cfg.vocab];
        for (pos, &t) in prompt.iter().enumerate() {
            logits = self.decode_step(t, pos, &mut caches);
        }
        let mut out = Vec::with_capacity(n_new);
        let mut pos = prompt.len();
        for _ in 0..n_new {
            let next = argmax(&logits) as u32;
            out.push(next);
            logits = self.decode_step(next, pos, &mut caches);
            pos += 1;
        }
        out
    }

    /// Total per-component decode timing across blocks (Fig 8).
    pub fn timing_summary(&self) -> super::block::BlockTiming {
        let mut total = super::block::BlockTiming::default();
        for b in &self.blocks {
            total.attn_proj += b.timing.attn_proj;
            total.attn_core += b.timing.attn_core;
            total.ffn_1bit += b.timing.ffn_1bit;
            total.ffn_8bit += b.timing.ffn_8bit;
            total.router += b.timing.router;
            total.norm_quant += b.timing.norm_quant;
        }
        total
    }

    pub fn reset_timing(&mut self) {
        for b in &mut self.blocks {
            b.timing.reset();
        }
    }

    /// Resident weight bytes (embeddings fp16 + packed blocks).
    pub fn storage_bytes(&self) -> usize {
        let embed = (self.embed.len() + self.lm_head.len() + self.final_norm.len()) * 2;
        embed + self.blocks.iter().map(|b| b.storage_bytes()).sum::<usize>()
    }
}

/// Greedy decode argmax. Shared with the serving sampler: engine-greedy
/// output stays bit-exact with [`PackedModel::generate`] only while both
/// paths use this one function (ties break to the lowest index).
pub(crate) fn argmax(x: &[f32]) -> usize {
    let mut bi = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in x.iter().enumerate() {
        if v > bv {
            bi = i;
            bv = v;
        }
    }
    bi
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nano_cfg(variant: Variant) -> ModelConfig {
        ModelConfig {
            name: format!("test-{}", variant.name()),
            variant,
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 96,
            r: if variant == Variant::PQuant { 16 } else { 0 },
            n_experts: if variant == Variant::PQuant { 2 } else { 1 },
            seq_len: 16,
            alpha_init: 2.0,
            beta_init: 0.2,
        }
    }

    #[test]
    fn generate_produces_tokens_in_vocab() {
        for v in [Variant::Fp16, Variant::BitNet, Variant::BitNet158, Variant::PQuant] {
            let mut m = PackedModel::random(&nano_cfg(v), 11);
            let out = m.generate(&[1, 2, 3], 5);
            assert_eq!(out.len(), 5, "{v:?}");
            assert!(out.iter().all(|&t| (t as usize) < 64), "{v:?}");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let mut a = PackedModel::random(&nano_cfg(Variant::PQuant), 5);
        let mut b = PackedModel::random(&nano_cfg(Variant::PQuant), 5);
        assert_eq!(a.generate(&[1, 2], 6), b.generate(&[1, 2], 6));
    }

    #[test]
    fn paged_decode_matches_contiguous_bit_exactly() {
        use crate::kvcache::{BlockPool, KvPoolOptions, PrefixTag};
        use std::sync::Arc;
        let cfg = nano_cfg(Variant::PQuant);
        let mut a = PackedModel::random(&cfg, 9);
        let mut b = PackedModel::random(&cfg, 9);
        let pool = Arc::new(BlockPool::new(
            KvPoolOptions { n_blocks: 64, block_size: 4, ..Default::default() },
            cfg.n_layers,
            cfg.d_model,
        ));
        let adm = pool.admit(&[], 12, PrefixTag::default()).unwrap();
        let mut seq = PagedSeq::new(&pool, adm);
        let mut caches = a.new_caches(12);
        for (pos, &t) in [1u32, 5, 9, 2, 7].iter().enumerate() {
            let la = a.decode_step(t, pos, &mut caches);
            let lb = b.decode_step_paged(t, pos, &mut seq).unwrap();
            assert_eq!(la, lb, "paged logits diverge at pos {pos}");
        }
    }

    #[test]
    fn storage_ordering_across_variants() {
        let sz = |v| PackedModel::random(&nano_cfg(v), 1).storage_bytes();
        assert!(sz(Variant::PQuant) < sz(Variant::Fp16));
        assert!(sz(Variant::BitNet) < sz(Variant::BitNet158));
    }

    #[test]
    fn timing_summary_accumulates_across_blocks() {
        let mut m = PackedModel::random(&nano_cfg(Variant::PQuant), 2);
        m.set_timing(TimingMode::Accumulate);
        m.generate(&[1], 3);
        assert!(m.timing_summary().total().as_nanos() > 0);
        m.reset_timing();
        assert_eq!(m.timing_summary().total().as_nanos(), 0);
    }

    #[test]
    fn timing_is_off_by_default() {
        let mut m = PackedModel::random(&nano_cfg(Variant::PQuant), 2);
        m.generate(&[1], 3);
        assert_eq!(
            m.timing_summary().total().as_nanos(),
            0,
            "serving replicas must not pay clock reads unless profiling is on"
        );
    }
}
