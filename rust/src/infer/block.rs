//! One packed transformer block: 1-bit MHA with RoPE + KV cache, plus the
//! variant FFN (dense quantized, or pQuant's decoupled branches with a
//! top-1 router over the INT8 experts).
//!
//! The decode path is per-token GEMV — the edge regime the paper's
//! Appendix A targets ("the batch size is typically one and the most
//! time-consuming operation becomes GEMV").

use std::time::Duration;

use crate::config::Variant;
use crate::kvcache::{KvError, KvStore};

use super::{rmsnorm_vec, silu, softmax, QLinear, QuantActs};

/// Per-layer attention KV cache, contiguous layout — the fast path for
/// single-sequence decode ([`PackedModel::generate`]) where the caller
/// sizes the cache up front. The paged serving path lives in
/// [`crate::kvcache`]; both implement [`KvStore`] and produce
/// bit-identical attention.
///
/// [`PackedModel::generate`]: crate::infer::PackedModel::generate
pub struct KvCache {
    pub k: Vec<f32>, // [t, d]
    pub v: Vec<f32>,
    pub len: usize,
    d: usize,
}

impl KvCache {
    pub fn new(max_seq: usize, d: usize) -> KvCache {
        KvCache { k: vec![0.0; max_seq * d], v: vec![0.0; max_seq * d], len: 0, d }
    }

    /// Append one row. A full cache is a recoverable error (a failed
    /// request), not a panic (a dead serving worker).
    pub fn push(&mut self, k: &[f32], v: &[f32]) -> Result<(), KvError> {
        if self.len * self.d + self.d > self.k.len() {
            return Err(KvError::CacheOverflow { cap: self.k.len() / self.d.max(1) });
        }
        self.k[self.len * self.d..(self.len + 1) * self.d].copy_from_slice(k);
        self.v[self.len * self.d..(self.len + 1) * self.d].copy_from_slice(v);
        self.len += 1;
        Ok(())
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }
}

impl KvStore for KvCache {
    fn len(&self) -> usize {
        self.len
    }

    fn push(&mut self, k: &[f32], v: &[f32]) -> Result<(), KvError> {
        KvCache::push(self, k, v)
    }

    fn for_each_segment<'a>(&'a self, f: &mut dyn FnMut(&'a [f32], &'a [f32])) {
        f(&self.k[..self.len * self.d], &self.v[..self.len * self.d]);
    }
}

/// The pQuant decoupled FFN weights (§3.2-3.3).
#[derive(Clone)]
pub struct DecoupledFfn {
    pub up_1bit: QLinear,
    pub down_1bit: QLinear,
    /// N experts: (up [d, r], down [r, d]).
    pub experts: Vec<(QLinear, QLinear)>,
    /// Router [d, N] full precision (tiny).
    pub router: Vec<f32>,
    pub alpha: f32,
    pub beta: f32,
}

/// FFN variants.
#[derive(Clone)]
pub enum Ffn {
    Dense { up: QLinear, down: QLinear },
    Decoupled(DecoupledFfn),
}

/// One transformer block with packed weights. `Clone` backs per-worker
/// serving replicas and the registry's hand-out path.
#[derive(Clone)]
pub struct PackedBlock {
    pub attn_norm: Vec<f32>,
    pub ffn_norm: Vec<f32>,
    pub wq: QLinear,
    pub wk: QLinear,
    pub wv: QLinear,
    pub wo: QLinear,
    pub ffn: Ffn,
    pub n_heads: usize,
    /// Accumulated decode-time by component (Fig 8 instrumentation).
    pub timing: BlockTiming,
}

/// Per-component cumulative wall time (Fig 8: "computation time across
/// components in a Transformer block").
#[derive(Debug, Clone, Default)]
pub struct BlockTiming {
    pub attn_proj: Duration,
    pub attn_core: Duration,
    pub ffn_1bit: Duration,
    pub ffn_8bit: Duration,
    pub router: Duration,
    pub norm_quant: Duration,
}

impl BlockTiming {
    pub fn total(&self) -> Duration {
        self.attn_proj + self.attn_core + self.ffn_1bit + self.ffn_8bit
            + self.router + self.norm_quant
    }

    pub fn reset(&mut self) {
        *self = BlockTiming::default();
    }
}

fn rope_rotate(x: &mut [f32], pos: usize, n_heads: usize) {
    let hd = x.len() / n_heads;
    let half = hd / 2;
    for h in 0..n_heads {
        let base = h * hd;
        for i in 0..half {
            let freq = 1.0f32 / 10000f32.powf(i as f32 / half as f32);
            let angle = pos as f32 * freq;
            let (sin, cos) = angle.sin_cos();
            let a = x[base + i];
            let b = x[base + half + i];
            x[base + i] = a * cos - b * sin;
            x[base + half + i] = a * sin + b * cos;
        }
    }
}

impl PackedBlock {
    /// Decode one token on the contiguous fast path: `x` is the residual
    /// stream vector [d]; returns the updated residual. `pos` is the cache
    /// position of this token. The cache is caller-sized, so overflow is a
    /// programming error here — recoverable callers use
    /// [`PackedBlock::try_forward`].
    pub fn forward(&mut self, x: &[f32], pos: usize, cache: &mut KvCache) -> Vec<f32> {
        self.try_forward(x, pos, cache).expect("contiguous KV cache sized by caller")
    }

    /// Decode one token against any [`KvStore`] (contiguous or paged).
    /// Attention walks the cache as ordered contiguous segments, so the
    /// float ops — and therefore the output bits — are identical across
    /// layouts.
    pub fn try_forward<C: KvStore + ?Sized>(
        &mut self,
        x: &[f32],
        pos: usize,
        cache: &mut C,
    ) -> Result<Vec<f32>, KvError> {
        let d = x.len();
        let hd = d / self.n_heads;

        // ---- attention ----
        let t0 = std::time::Instant::now();
        let xn = rmsnorm_vec(x, &self.attn_norm);
        let mut acts = QuantActs::quantize(&xn);
        self.timing.norm_quant += t0.elapsed();

        let t0 = std::time::Instant::now();
        let mut q = self.wq.forward(&xn, &mut acts);
        let mut k = self.wk.forward(&xn, &mut acts);
        let v = self.wv.forward(&xn, &mut acts);
        self.timing.attn_proj += t0.elapsed();

        let t0 = std::time::Instant::now();
        rope_rotate(&mut q, pos, self.n_heads);
        rope_rotate(&mut k, pos, self.n_heads);
        cache.push(&k, &v)?;
        let t_len = cache.len();
        let mut ctx = vec![0.0f32; d];
        let scale = 1.0 / (hd as f32).sqrt();
        let mut scores = vec![0.0f32; t_len];
        // The cache is walked as ordered contiguous segments (one for the
        // contiguous layout, one per page when paged) — same rows, same
        // order, same float ops, so the layouts are bit-identical.
        for h in 0..self.n_heads {
            let qh = &q[h * hd..(h + 1) * hd];
            let mut t = 0;
            cache.for_each_segment(&mut |ks, _| {
                for kr in ks.chunks_exact(d) {
                    let kh = &kr[h * hd..(h + 1) * hd];
                    scores[t] = qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
                    t += 1;
                }
            });
            softmax(&mut scores);
            let ch = &mut ctx[h * hd..(h + 1) * hd];
            let mut t = 0;
            cache.for_each_segment(&mut |_, vs| {
                for vr in vs.chunks_exact(d) {
                    let p = scores[t];
                    let vh = &vr[h * hd..(h + 1) * hd];
                    for (c, &vv) in ch.iter_mut().zip(vh) {
                        *c += p * vv;
                    }
                    t += 1;
                }
            });
        }
        self.timing.attn_core += t0.elapsed();

        let t0 = std::time::Instant::now();
        let mut acts_ctx = QuantActs::quantize(&ctx);
        let o = self.wo.forward(&ctx, &mut acts_ctx);
        self.timing.attn_proj += t0.elapsed();

        let mut x1: Vec<f32> = x.iter().zip(&o).map(|(a, b)| a + b).collect();

        // ---- FFN ----
        let t0 = std::time::Instant::now();
        let xn = rmsnorm_vec(&x1, &self.ffn_norm);
        let mut acts = QuantActs::quantize(&xn);
        self.timing.norm_quant += t0.elapsed();

        let y = match &self.ffn {
            Ffn::Dense { up, down } => {
                let t0 = std::time::Instant::now();
                let mut h = up.forward(&xn, &mut acts);
                silu(&mut h);
                let mut acts_h = QuantActs::quantize(&h);
                let out = down.forward(&h, &mut acts_h);
                self.timing.ffn_1bit += t0.elapsed();
                out
            }
            Ffn::Decoupled(dec) => {
                // 1-bit branch (shares acts/LUTs with the expert up-proj —
                // the Appendix A "no redundant data reads" point)
                let t0 = std::time::Instant::now();
                let mut h1 = dec.up_1bit.forward(&xn, &mut acts);
                silu(&mut h1);
                let mut acts_h1 = QuantActs::quantize(&h1);
                let y1 = dec.down_1bit.forward(&h1, &mut acts_h1);
                self.timing.ffn_1bit += t0.elapsed();

                // top-1 router (full precision, tiny)
                let t0 = std::time::Instant::now();
                let n_exp = dec.experts.len();
                let (expert_idx, gate) = if n_exp == 1 {
                    (0usize, 1.0f32)
                } else {
                    let mut logits =
                        crate::gemm::f32_gemv(&xn, &dec.router, xn.len(), n_exp);
                    softmax(&mut logits);
                    let (mut bi, mut bp) = (0usize, f32::NEG_INFINITY);
                    for (i, &p) in logits.iter().enumerate() {
                        if p > bp {
                            bi = i;
                            bp = p;
                        }
                    }
                    (bi, bp)
                };
                self.timing.router += t0.elapsed();

                // single activated INT8 expert (traffic constant in N)
                let t0 = std::time::Instant::now();
                let (up8, down8) = &dec.experts[expert_idx];
                let mut h8 = up8.forward(&xn, &mut acts);
                silu(&mut h8);
                let mut acts_h8 = QuantActs::quantize(&h8);
                let y8 = down8.forward(&h8, &mut acts_h8);
                self.timing.ffn_8bit += t0.elapsed();

                y1.iter()
                    .zip(&y8)
                    .map(|(a, b)| dec.beta * a + dec.alpha * gate * b)
                    .collect()
            }
        };
        for (xv, yv) in x1.iter_mut().zip(&y) {
            *xv += yv;
        }
        Ok(x1)
    }

    /// Resident weight bytes of this block.
    pub fn storage_bytes(&self) -> usize {
        let mut total = (self.attn_norm.len() + self.ffn_norm.len()) * 2;
        total += self.wq.storage_bytes()
            + self.wk.storage_bytes()
            + self.wv.storage_bytes()
            + self.wo.storage_bytes();
        total += match &self.ffn {
            Ffn::Dense { up, down } => up.storage_bytes() + down.storage_bytes(),
            Ffn::Decoupled(d) => {
                d.up_1bit.storage_bytes()
                    + d.down_1bit.storage_bytes()
                    + d.experts
                        .iter()
                        .map(|(u, dn)| u.storage_bytes() + dn.storage_bytes())
                        .sum::<usize>()
                    + d.router.len() * 2
            }
        };
        total
    }

    /// Build a random block of the given geometry (bench workloads at
    /// paper scale where no trained checkpoint exists).
    pub fn random(
        variant: Variant,
        d: usize,
        n_heads: usize,
        d_ff: usize,
        r: usize,
        n_experts: usize,
        seed: u64,
    ) -> PackedBlock {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mk = |rng: &mut crate::util::rng::Rng, k: usize, n: usize, v: Variant| {
            let wf = rng.normal_vec(k * n);
            match v {
                Variant::Fp16 => QLinear::f32(&wf, k, n),
                Variant::BitNet | Variant::PQuant => QLinear::one_bit(&wf, k, n),
                Variant::BitNet158 => QLinear::ternary(&wf, k, n),
            }
        };
        let ffn = if variant == Variant::PQuant {
            let n1 = d_ff - r;
            Ffn::Decoupled(DecoupledFfn {
                up_1bit: mk(&mut rng, d, n1, Variant::BitNet),
                down_1bit: mk(&mut rng, n1, d, Variant::BitNet),
                experts: (0..n_experts)
                    .map(|_| {
                        let up = rng.normal_vec(d * r);
                        let dn = rng.normal_vec(r * d);
                        (QLinear::int8(&up, d, r), QLinear::int8(&dn, r, d))
                    })
                    .collect(),
                router: rng.normal_vec(d * n_experts),
                alpha: 2.0,
                beta: 0.2,
            })
        } else {
            Ffn::Dense {
                up: mk(&mut rng, d, d_ff, variant),
                down: mk(&mut rng, d_ff, d, variant),
            }
        };
        PackedBlock {
            attn_norm: vec![1.0; d],
            ffn_norm: vec![1.0; d],
            wq: mk(&mut rng, d, d, variant),
            wk: mk(&mut rng, d, d, variant),
            wv: mk(&mut rng, d, d, variant),
            wo: mk(&mut rng, d, d, variant),
            ffn,
            n_heads,
            timing: BlockTiming::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_block(variant: Variant) -> Vec<f32> {
        let d = 64;
        let mut block = PackedBlock::random(variant, d, 4, 176, 16, 2, 42);
        let mut cache = KvCache::new(8, d);
        let x = crate::util::rng::Rng::new(1).normal_vec(d);
        let mut out = vec![];
        for pos in 0..4 {
            out = block.forward(&x, pos, &mut cache);
        }
        out
    }

    #[test]
    fn all_variants_produce_finite_outputs() {
        for v in [Variant::Fp16, Variant::BitNet, Variant::BitNet158, Variant::PQuant] {
            let y = run_block(v);
            assert_eq!(y.len(), 64);
            assert!(y.iter().all(|x| x.is_finite()), "{v:?} produced non-finite");
        }
    }

    #[test]
    fn kv_cache_grows_and_resets() {
        let mut cache = KvCache::new(4, 8);
        cache.push(&[1.0; 8], &[2.0; 8]).unwrap();
        cache.push(&[3.0; 8], &[4.0; 8]).unwrap();
        assert_eq!(cache.len, 2);
        cache.reset();
        assert_eq!(cache.len, 0);
    }

    #[test]
    fn kv_cache_overflow_is_recoverable() {
        let mut cache = KvCache::new(1, 4);
        cache.push(&[0.0; 4], &[0.0; 4]).unwrap();
        assert_eq!(
            cache.push(&[0.0; 4], &[0.0; 4]),
            Err(KvError::CacheOverflow { cap: 1 }),
            "a full cache must fail the push, not kill the thread"
        );
        assert_eq!(cache.len, 1, "failed push must not corrupt the cache");
    }

    #[test]
    fn timing_accumulates() {
        let d = 64;
        let mut block = PackedBlock::random(Variant::PQuant, d, 4, 176, 16, 4, 7);
        let mut cache = KvCache::new(8, d);
        let x = vec![0.5; d];
        block.forward(&x, 0, &mut cache);
        let t = block.timing.clone();
        assert!(t.total() > Duration::ZERO);
        assert!(t.ffn_8bit > Duration::ZERO, "expert branch must be timed");
        assert!(t.router > Duration::ZERO, "router must be timed");
        block.timing.reset();
        assert_eq!(block.timing.total(), Duration::ZERO);
    }

    #[test]
    fn pquant_storage_below_ternary_below_fp() {
        let mk = |v| PackedBlock::random(v, 128, 4, 352, 16, 1, 3).storage_bytes();
        let fp = mk(Variant::Fp16);
        let tern = mk(Variant::BitNet158);
        let pq = mk(Variant::PQuant);
        assert!(pq < tern, "pquant {pq} !< ternary {tern}");
        assert!(tern < fp);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x = crate::util::rng::Rng::new(3).normal_vec(32);
        let before: f32 = x.iter().map(|v| v * v).sum();
        rope_rotate(&mut x, 7, 4);
        let after: f32 = x.iter().map(|v| v * v).sum();
        assert!((before - after).abs() / before < 1e-5);
    }
}
