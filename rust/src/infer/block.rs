//! One packed transformer block: 1-bit MHA with RoPE + KV cache, plus the
//! variant FFN (dense quantized, or pQuant's decoupled branches with a
//! top-1 router over the INT8 experts).
//!
//! Two decode paths share every numeric: the per-token GEMV path — the
//! edge regime the paper's Appendix A targets ("the batch size is
//! typically one and the most time-consuming operation becomes GEMV") —
//! and [`PackedBlock::try_forward_batch`], the weight-stationary serving
//! path where one fused step advances many sequences and each packed
//! weight column is read once for the whole batch. Greedy outputs are
//! bit-identical across the two.

use std::time::{Duration, Instant};

use crate::config::Variant;
use crate::kvcache::{KvError, KvSegment, KvStore};

use super::batch::{grow_pow2, Scratch, SeqStep};
use super::{rmsnorm_into, rmsnorm_vec, silu, softmax, QLinear, QuantActs};

/// Per-layer attention KV cache, contiguous layout — the fast path for
/// single-sequence decode ([`PackedModel::generate`]) where the caller
/// sizes the cache up front. The paged serving path lives in
/// [`crate::kvcache`]; both implement [`KvStore`] and produce
/// bit-identical attention.
///
/// [`PackedModel::generate`]: crate::infer::PackedModel::generate
pub struct KvCache {
    pub k: Vec<f32>, // [t, d]
    pub v: Vec<f32>,
    pub len: usize,
    d: usize,
}

impl KvCache {
    pub fn new(max_seq: usize, d: usize) -> KvCache {
        KvCache { k: vec![0.0; max_seq * d], v: vec![0.0; max_seq * d], len: 0, d }
    }

    /// Append one row. A full cache is a recoverable error (a failed
    /// request), not a panic (a dead serving worker).
    pub fn push(&mut self, k: &[f32], v: &[f32]) -> Result<(), KvError> {
        if self.len * self.d + self.d > self.k.len() {
            return Err(KvError::CacheOverflow { cap: self.k.len() / self.d.max(1) });
        }
        self.k[self.len * self.d..(self.len + 1) * self.d].copy_from_slice(k);
        self.v[self.len * self.d..(self.len + 1) * self.d].copy_from_slice(v);
        self.len += 1;
        Ok(())
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Roll back to `len` tokens (speculative decode rejected a drafted
    /// suffix); rows beyond it are overwritten by later pushes. Growing is
    /// a no-op.
    pub fn truncate(&mut self, len: usize) {
        self.len = self.len.min(len);
    }
}

impl KvStore for KvCache {
    fn len(&self) -> usize {
        self.len
    }

    fn push(&mut self, k: &[f32], v: &[f32]) -> Result<(), KvError> {
        KvCache::push(self, k, v)
    }

    fn for_each_seg<'a>(&'a self, f: &mut dyn FnMut(KvSegment<'a>)) {
        f(KvSegment::F32 {
            k: &self.k[..self.len * self.d],
            v: &self.v[..self.len * self.d],
        });
    }
}

/// The pQuant decoupled FFN weights (§3.2-3.3).
#[derive(Clone)]
pub struct DecoupledFfn {
    pub up_1bit: QLinear,
    pub down_1bit: QLinear,
    /// N experts: (up [d, r], down [r, d]).
    pub experts: Vec<(QLinear, QLinear)>,
    /// Router [d, N] full precision (tiny).
    pub router: Vec<f32>,
    pub alpha: f32,
    pub beta: f32,
}

/// FFN variants.
#[derive(Clone)]
pub enum Ffn {
    Dense { up: QLinear, down: QLinear },
    Decoupled(DecoupledFfn),
}

/// One transformer block with packed weights. `Clone` backs per-worker
/// serving replicas and the registry's hand-out path.
#[derive(Clone)]
pub struct PackedBlock {
    pub attn_norm: Vec<f32>,
    pub ffn_norm: Vec<f32>,
    pub wq: QLinear,
    pub wk: QLinear,
    pub wv: QLinear,
    pub wo: QLinear,
    pub ffn: Ffn,
    pub n_heads: usize,
    /// Accumulated decode-time by component (Fig 8 instrumentation).
    pub timing: BlockTiming,
}

/// Whether a block accumulates per-component wall time. `Off` (the
/// default) skips every `Instant::now()` in the decode hot loop — eight
/// clock reads per layer per token are measurable at serving rates — so
/// profiling is opt-in (the Fig 8 harness turns it on).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TimingMode {
    #[default]
    Off,
    Accumulate,
}

/// Per-component cumulative wall time (Fig 8: "computation time across
/// components in a Transformer block"), gated by [`TimingMode`].
#[derive(Debug, Clone, Default)]
pub struct BlockTiming {
    pub mode: TimingMode,
    pub attn_proj: Duration,
    pub attn_core: Duration,
    pub ffn_1bit: Duration,
    pub ffn_8bit: Duration,
    pub router: Duration,
    pub norm_quant: Duration,
}

impl BlockTiming {
    pub fn total(&self) -> Duration {
        self.attn_proj + self.attn_core + self.ffn_1bit + self.ffn_8bit
            + self.router + self.norm_quant
    }

    /// Clear the accumulators, keeping the mode.
    pub fn reset(&mut self) {
        *self = BlockTiming { mode: self.mode, ..BlockTiming::default() };
    }

    /// Read the clock only when accumulating.
    #[inline]
    fn tick(&self) -> Option<Instant> {
        match self.mode {
            TimingMode::Off => None,
            TimingMode::Accumulate => Some(Instant::now()),
        }
    }
}

/// Fold an elapsed interval into `acc` (no-op when timing is off).
#[inline]
fn lap(acc: &mut Duration, t0: Option<Instant>) {
    if let Some(t) = t0 {
        *acc += t.elapsed();
    }
}

/// Precomputed RoPE sin/cos rows ([position, half-dim]), grown on demand.
/// The old per-call `powf`/`sin_cos` ran per head per layer per token in
/// the decode hot loop; the table computes each (pos, i) angle once with
/// the identical expressions, so rotation output is bit-identical.
#[derive(Debug, Clone, Default)]
pub struct RopeTable {
    half: usize,
    len: usize,
    sin: Vec<f32>,
    cos: Vec<f32>,
}

impl RopeTable {
    /// Make rows `0..n_pos` available for head half-dim `half` (grows in
    /// power-of-two jumps so steady-state decode never reallocates).
    pub fn ensure(&mut self, half: usize, n_pos: usize) {
        if half != self.half {
            self.half = half;
            self.len = 0;
            self.sin.clear();
            self.cos.clear();
        }
        if n_pos <= self.len || half == 0 {
            self.len = self.len.max(n_pos);
            return;
        }
        let cap = n_pos.next_power_of_two();
        self.sin.resize(cap * half, 0.0);
        self.cos.resize(cap * half, 0.0);
        for pos in self.len..cap {
            for i in 0..half {
                let freq = 1.0f32 / 10000f32.powf(i as f32 / half as f32);
                let angle = pos as f32 * freq;
                let (s, c) = angle.sin_cos();
                self.sin[pos * half + i] = s;
                self.cos[pos * half + i] = c;
            }
        }
        self.len = cap;
    }

    /// Positions currently tabulated.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    fn row(&self, pos: usize) -> (&[f32], &[f32]) {
        let half = self.half;
        (
            &self.sin[pos * half..(pos + 1) * half],
            &self.cos[pos * half..(pos + 1) * half],
        )
    }
}

/// Rotate q/k in place from the precomputed table (`rope.ensure` must
/// cover `pos`).
pub fn rope_rotate(x: &mut [f32], pos: usize, n_heads: usize, rope: &RopeTable) {
    let hd = x.len() / n_heads;
    let half = hd / 2;
    debug_assert_eq!(half, rope.half, "RopeTable built for another head size");
    assert!(pos < rope.len, "RopeTable not ensured through pos {pos}");
    let (sin, cos) = rope.row(pos);
    for h in 0..n_heads {
        let base = h * hd;
        for i in 0..half {
            let (s, c) = (sin[i], cos[i]);
            let a = x[base + i];
            let b = x[base + half + i];
            x[base + i] = a * c - b * s;
            x[base + half + i] = a * s + b * c;
        }
    }
}

/// One row of attention over any [`KvStore`]: scores (len == cache.len())
/// are scratch, `ctx` must be zeroed [d]. Both the single-token and the
/// batched paths call this one function, so their float ops — and
/// therefore their output bits — are identical by construction. The cache
/// is walked as ordered contiguous segments (one for the contiguous
/// layout, one per page when paged) — same rows, same order, same float
/// ops, so in F32 storage the layouts are bit-identical too. Quantized
/// segments dequantize per element inside the same walk (`x = q / γ`,
/// the [`dequant_i8_row_into`](crate::quant::dequant_i8_row_into)
/// expression) — no staging buffers, no allocation on the hot path.
fn attend_into<C: KvStore + ?Sized>(
    q: &[f32],
    cache: &C,
    n_heads: usize,
    scores: &mut [f32],
    ctx: &mut [f32],
) {
    let d = q.len();
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    for h in 0..n_heads {
        let qh = &q[h * hd..(h + 1) * hd];
        let mut t = 0;
        cache.for_each_seg(&mut |seg| match seg {
            KvSegment::F32 { k: ks, .. } => {
                for kr in ks.chunks_exact(d) {
                    let kh = &kr[h * hd..(h + 1) * hd];
                    scores[t] = qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
                    t += 1;
                }
            }
            KvSegment::Int8 { k: ks, k_scale, .. } => {
                for (r, kr) in ks.chunks_exact(d).enumerate() {
                    let g = k_scale[r];
                    let kh = &kr[h * hd..(h + 1) * hd];
                    scores[t] = qh
                        .iter()
                        .zip(kh)
                        .map(|(a, &b)| a * (b as f32 / g))
                        .sum::<f32>()
                        * scale;
                    t += 1;
                }
            }
        });
        softmax(scores);
        let ch = &mut ctx[h * hd..(h + 1) * hd];
        let mut t = 0;
        cache.for_each_seg(&mut |seg| match seg {
            KvSegment::F32 { v: vs, .. } => {
                for vr in vs.chunks_exact(d) {
                    let p = scores[t];
                    let vh = &vr[h * hd..(h + 1) * hd];
                    for (c, &vv) in ch.iter_mut().zip(vh) {
                        *c += p * vv;
                    }
                    t += 1;
                }
            }
            KvSegment::Int8 { v: vs, v_scale, .. } => {
                for (r, vr) in vs.chunks_exact(d).enumerate() {
                    let p = scores[t];
                    let g = v_scale[r];
                    let vh = &vr[h * hd..(h + 1) * hd];
                    for (c, &qv) in ch.iter_mut().zip(vh) {
                        *c += p * (qv as f32 / g);
                    }
                    t += 1;
                }
            }
        });
    }
}

/// One sequence's attention within a batch step: rope-rotate and push its
/// rows in position order, attending each against the sequence's own
/// cache. `q`/`k`/`v`/`ctx`/`xs` are this sequence's row spans ([rows, d]);
/// `scores` is pre-grown to cover the final cache length. On a cache
/// failure the sequence's `err` is set and its rows zeroed — the rest of
/// the batch is unaffected. Self-contained (no `&mut PackedBlock`), so
/// sequences can run on separate scoped threads.
#[allow(clippy::too_many_arguments)]
fn attend_seq(
    step: &mut SeqStep<'_>,
    layer: usize,
    q: &mut [f32],
    k: &mut [f32],
    v: &[f32],
    ctx: &mut [f32],
    xs: &mut [f32],
    scores: &mut [f32],
    rope: &RopeTable,
    n_heads: usize,
    d: usize,
) {
    if step.err.is_some() {
        return;
    }
    let rows = step.tokens.len();
    let mut cache = step.kv.layer(layer);
    for i in 0..rows {
        let pos = step.pos + i;
        rope_rotate(&mut q[i * d..(i + 1) * d], pos, n_heads, rope);
        rope_rotate(&mut k[i * d..(i + 1) * d], pos, n_heads, rope);
        if let Err(e) = cache.push(&k[i * d..(i + 1) * d], &v[i * d..(i + 1) * d]) {
            step.err = Some(e);
            // Dead sequence: zero its rows so later layers stay finite
            // (outputs are discarded by the caller).
            xs.fill(0.0);
            ctx.fill(0.0);
            return;
        }
        let t_len = cache.len();
        ctx[i * d..(i + 1) * d].fill(0.0);
        attend_into(
            &q[i * d..(i + 1) * d],
            &cache,
            n_heads,
            &mut scores[..t_len],
            &mut ctx[i * d..(i + 1) * d],
        );
    }
}

impl PackedBlock {
    /// Decode one token on the contiguous fast path: `x` is the residual
    /// stream vector [d]; returns the updated residual. `pos` is the cache
    /// position of this token. The cache is caller-sized, so overflow is a
    /// programming error here — recoverable callers use
    /// [`PackedBlock::try_forward`]. `rope` must cover `pos`.
    pub fn forward(
        &mut self,
        x: &[f32],
        pos: usize,
        cache: &mut KvCache,
        rope: &RopeTable,
    ) -> Vec<f32> {
        self.try_forward(x, pos, cache, rope).expect("contiguous KV cache sized by caller")
    }

    /// Decode one token against any [`KvStore`] (contiguous or paged).
    /// Attention walks the cache as ordered contiguous segments, so the
    /// float ops — and therefore the output bits — are identical across
    /// layouts.
    pub fn try_forward<C: KvStore + ?Sized>(
        &mut self,
        x: &[f32],
        pos: usize,
        cache: &mut C,
        rope: &RopeTable,
    ) -> Result<Vec<f32>, KvError> {
        let d = x.len();

        // ---- attention ----
        let t0 = self.timing.tick();
        let xn = rmsnorm_vec(x, &self.attn_norm);
        let mut acts = QuantActs::quantize(&xn);
        lap(&mut self.timing.norm_quant, t0);

        let t0 = self.timing.tick();
        let mut q = self.wq.forward(&xn, &mut acts);
        let mut k = self.wk.forward(&xn, &mut acts);
        let v = self.wv.forward(&xn, &mut acts);
        lap(&mut self.timing.attn_proj, t0);

        let t0 = self.timing.tick();
        rope_rotate(&mut q, pos, self.n_heads, rope);
        rope_rotate(&mut k, pos, self.n_heads, rope);
        cache.push(&k, &v)?;
        let t_len = cache.len();
        let mut ctx = vec![0.0f32; d];
        let mut scores = vec![0.0f32; t_len];
        attend_into(&q, cache, self.n_heads, &mut scores, &mut ctx);
        lap(&mut self.timing.attn_core, t0);

        let t0 = self.timing.tick();
        let mut acts_ctx = QuantActs::quantize(&ctx);
        let o = self.wo.forward(&ctx, &mut acts_ctx);
        lap(&mut self.timing.attn_proj, t0);

        let mut x1: Vec<f32> = x.iter().zip(&o).map(|(a, b)| a + b).collect();

        // ---- FFN ----
        let t0 = self.timing.tick();
        let xn = rmsnorm_vec(&x1, &self.ffn_norm);
        let mut acts = QuantActs::quantize(&xn);
        lap(&mut self.timing.norm_quant, t0);

        let y = match &self.ffn {
            Ffn::Dense { up, down } => {
                let t0 = self.timing.tick();
                let mut h = up.forward(&xn, &mut acts);
                silu(&mut h);
                let mut acts_h = QuantActs::quantize(&h);
                let out = down.forward(&h, &mut acts_h);
                lap(&mut self.timing.ffn_1bit, t0);
                out
            }
            Ffn::Decoupled(dec) => {
                // 1-bit branch (shares acts/LUTs with the expert up-proj —
                // the Appendix A "no redundant data reads" point)
                let t0 = self.timing.tick();
                let mut h1 = dec.up_1bit.forward(&xn, &mut acts);
                silu(&mut h1);
                let mut acts_h1 = QuantActs::quantize(&h1);
                let y1 = dec.down_1bit.forward(&h1, &mut acts_h1);
                lap(&mut self.timing.ffn_1bit, t0);

                // top-1 router (full precision, tiny)
                let t0 = self.timing.tick();
                let n_exp = dec.experts.len();
                let (expert_idx, gate) = if n_exp == 1 {
                    (0usize, 1.0f32)
                } else {
                    let mut logits =
                        crate::gemm::f32_gemv(&xn, &dec.router, xn.len(), n_exp);
                    softmax(&mut logits);
                    let (mut bi, mut bp) = (0usize, f32::NEG_INFINITY);
                    for (i, &p) in logits.iter().enumerate() {
                        if p > bp {
                            bi = i;
                            bp = p;
                        }
                    }
                    (bi, bp)
                };
                lap(&mut self.timing.router, t0);

                // single activated INT8 expert (traffic constant in N);
                // the up-projection reads the shared `acts` built for the
                // 1-bit branch — one quantization, one set of tables.
                let t0 = self.timing.tick();
                let (up8, down8) = &dec.experts[expert_idx];
                let mut h8 = up8.forward(&xn, &mut acts);
                silu(&mut h8);
                let mut acts_h8 = QuantActs::quantize(&h8);
                let y8 = down8.forward(&h8, &mut acts_h8);
                lap(&mut self.timing.ffn_8bit, t0);

                y1.iter()
                    .zip(&y8)
                    .map(|(a, b)| dec.beta * a + dec.alpha * gate * b)
                    .collect()
            }
        };
        for (xv, yv) in x1.iter_mut().zip(&y) {
            *xv += yv;
        }
        Ok(x1)
    }

    /// One fused batch step through this block: `xs` holds the residual
    /// rows of every sequence's tokens ([b, d], ordered as `steps`), and
    /// is updated in place. Linears run batched (each weight column read
    /// once for the whole batch); attention runs per sequence against its
    /// own cache, rows in position order, so batched output is
    /// bit-identical to B single-token calls. A cache failure marks that
    /// step's `err` and zeroes its rows — the rest of the batch is
    /// unaffected. All intermediates live in `scratch`; once warm, no
    /// allocation happens here.
    pub fn try_forward_batch(
        &mut self,
        layer: usize,
        xs: &mut [f32],
        steps: &mut [SeqStep<'_>],
        rope: &RopeTable,
        scratch: &mut Scratch,
    ) {
        let d = self.attn_norm.len();
        let b = xs.len() / d;
        debug_assert_eq!(b, steps.iter().map(|s| s.tokens.len()).sum::<usize>());
        let Scratch {
            xn,
            q,
            kr,
            v,
            ctx,
            o,
            h1,
            y1,
            router,
            gates,
            eidx,
            groups,
            xq_g,
            hg,
            yg,
            scores_pool,
            acts,
            acts_ctx,
            acts_h,
            acts_e,
            acc,
            grew,
            ..
        } = scratch;

        // ---- attention: norm + one shared quantization + batched QKV ----
        let t0 = self.timing.tick();
        for r in 0..b {
            rmsnorm_into(&xs[r * d..(r + 1) * d], &self.attn_norm, &mut xn[r * d..(r + 1) * d]);
        }
        acts.quantize_rows(&xn[..b * d], b, d);
        lap(&mut self.timing.norm_quant, t0);

        let t0 = self.timing.tick();
        self.wq.forward_batch_into(&xn[..b * d], acts, &mut q[..b * d], acc);
        self.wk.forward_batch_into(&xn[..b * d], acts, &mut kr[..b * d], acc);
        self.wv.forward_batch_into(&xn[..b * d], acts, &mut v[..b * d], acc);
        lap(&mut self.timing.attn_proj, t0);

        // ---- attention core: per sequence, rows in position order.
        // Different sequences are independent (own cache, own rows), so
        // with several in flight each runs on its own thread — the only
        // per-row serial section of the step otherwise. Score buffers are
        // pre-grown here (sequentially), so the spawned work allocates
        // nothing.
        let t0 = self.timing.tick();
        let n_heads = self.n_heads;
        for (si, step) in steps.iter().enumerate() {
            if step.err.is_none() {
                let need = step.kv.len() + step.tokens.len();
                grow_pow2(&mut scores_pool[si], need, grew);
            }
        }
        // Rough attention MAC count decides whether spawning is worth it;
        // groups of contiguous sequences keep the spawn count at or below
        // the core count.
        let attn_work: usize = steps
            .iter()
            .map(|s| (s.kv.len() + s.tokens.len()) * s.tokens.len())
            .sum::<usize>()
            * d;
        let t_groups = crate::util::threads::num_threads()
            .min(steps.len())
            .min(attn_work / (1 << 17) + 1);
        if t_groups > 1 {
            let per = steps.len().div_ceil(t_groups);
            std::thread::scope(|scope| {
                let mut q_rest = &mut q[..b * d];
                let mut k_rest = &mut kr[..b * d];
                let mut v_rest = &v[..b * d];
                let mut c_rest = &mut ctx[..b * d];
                let mut x_rest = &mut xs[..b * d];
                let mut steps_rest = &mut steps[..];
                let mut pool_rest = &mut scores_pool[..];
                while !steps_rest.is_empty() {
                    let take = per.min(steps_rest.len());
                    let (sgrp, st) = steps_rest.split_at_mut(take);
                    steps_rest = st;
                    let (pgrp, pt) = pool_rest.split_at_mut(take);
                    pool_rest = pt;
                    let rows_grp: usize = sgrp.iter().map(|s| s.tokens.len()).sum();
                    let (qh, qt) = q_rest.split_at_mut(rows_grp * d);
                    q_rest = qt;
                    let (kh, kt) = k_rest.split_at_mut(rows_grp * d);
                    k_rest = kt;
                    let (vh, vt) = v_rest.split_at(rows_grp * d);
                    v_rest = vt;
                    let (ch, ct) = c_rest.split_at_mut(rows_grp * d);
                    c_rest = ct;
                    let (xh, xt) = x_rest.split_at_mut(rows_grp * d);
                    x_rest = xt;
                    scope.spawn(move || {
                        let mut r0 = 0usize;
                        for (step, sbuf) in sgrp.iter_mut().zip(pgrp.iter_mut()) {
                            let rows = step.tokens.len();
                            let span = r0 * d..(r0 + rows) * d;
                            attend_seq(
                                step,
                                layer,
                                &mut qh[span.clone()],
                                &mut kh[span.clone()],
                                &vh[span.clone()],
                                &mut ch[span.clone()],
                                &mut xh[span],
                                sbuf,
                                rope,
                                n_heads,
                                d,
                            );
                            r0 += rows;
                        }
                    });
                }
            });
        } else {
            let mut r0 = 0usize;
            for (si, step) in steps.iter_mut().enumerate() {
                let rows = step.tokens.len();
                let span = r0 * d..(r0 + rows) * d;
                attend_seq(
                    step,
                    layer,
                    &mut q[span.clone()],
                    &mut kr[span.clone()],
                    &v[span.clone()],
                    &mut ctx[span.clone()],
                    &mut xs[span],
                    &mut scores_pool[si],
                    rope,
                    n_heads,
                    d,
                );
                r0 += rows;
            }
        }
        lap(&mut self.timing.attn_core, t0);

        // ---- output projection + residual ----
        let t0 = self.timing.tick();
        acts_ctx.quantize_rows(&ctx[..b * d], b, d);
        self.wo.forward_batch_into(&ctx[..b * d], acts_ctx, &mut o[..b * d], acc);
        lap(&mut self.timing.attn_proj, t0);
        for (xv, ov) in xs[..b * d].iter_mut().zip(o[..b * d].iter()) {
            *xv += ov;
        }

        // ---- FFN: norm + one shared quantization for both branches ----
        let t0 = self.timing.tick();
        for r in 0..b {
            rmsnorm_into(&xs[r * d..(r + 1) * d], &self.ffn_norm, &mut xn[r * d..(r + 1) * d]);
        }
        acts.quantize_rows(&xn[..b * d], b, d);
        lap(&mut self.timing.norm_quant, t0);

        match &self.ffn {
            Ffn::Dense { up, down } => {
                let t0 = self.timing.tick();
                let (_, n_ff) = up.shape();
                up.forward_batch_into(&xn[..b * d], acts, &mut h1[..b * n_ff], acc);
                for r in 0..b {
                    silu(&mut h1[r * n_ff..(r + 1) * n_ff]);
                }
                acts_h.quantize_rows(&h1[..b * n_ff], b, n_ff);
                down.forward_batch_into(&h1[..b * n_ff], acts_h, &mut y1[..b * d], acc);
                lap(&mut self.timing.ffn_1bit, t0);
                for (xv, yv) in xs[..b * d].iter_mut().zip(y1[..b * d].iter()) {
                    *xv += yv;
                }
            }
            Ffn::Decoupled(dec) => {
                // 1-bit branch (shares acts/LUTs with the expert up-proj —
                // the Appendix A "no redundant data reads" point)
                let t0 = self.timing.tick();
                let (_, n1) = dec.up_1bit.shape();
                dec.up_1bit.forward_batch_into(&xn[..b * d], acts, &mut h1[..b * n1], acc);
                for r in 0..b {
                    silu(&mut h1[r * n1..(r + 1) * n1]);
                }
                acts_h.quantize_rows(&h1[..b * n1], b, n1);
                dec.down_1bit.forward_batch_into(&h1[..b * n1], acts_h, &mut y1[..b * d], acc);
                lap(&mut self.timing.ffn_1bit, t0);

                // top-1 router per row (full precision, tiny)
                let t0 = self.timing.tick();
                let n_exp = dec.experts.len();
                if n_exp == 1 {
                    for r in 0..b {
                        eidx[r] = 0;
                        gates[r] = 1.0;
                    }
                } else {
                    let yf = acc.f32_acc(n_exp * b);
                    crate::gemm::f32_gemm_batch_into(&xn[..b * d], &dec.router, b, d, n_exp, yf);
                    for r in 0..b {
                        let row = &mut router[r * n_exp..(r + 1) * n_exp];
                        for (j, out) in row.iter_mut().enumerate() {
                            *out = yf[j * b + r];
                        }
                        softmax(row);
                        let (mut bi, mut bp) = (0usize, f32::NEG_INFINITY);
                        for (i, &p) in row.iter().enumerate() {
                            if p > bp {
                                bi = i;
                                bp = p;
                            }
                        }
                        eidx[r] = bi;
                        gates[r] = bp;
                    }
                }
                lap(&mut self.timing.router, t0);

                // group rows by routed expert; each group runs batched on
                // the shared quantized activations (no re-quantization)
                let t0 = self.timing.tick();
                for grp in groups.iter_mut() {
                    grp.clear();
                }
                let mut r0 = 0usize;
                for step in steps.iter() {
                    let rows = step.tokens.len();
                    if step.err.is_none() {
                        for i in 0..rows {
                            groups[eidx[r0 + i]].push(r0 + i);
                        }
                    }
                    r0 += rows;
                }
                for (e, grp) in groups.iter().enumerate().take(n_exp) {
                    if grp.is_empty() {
                        continue;
                    }
                    let (up8, down8) = &dec.experts[e];
                    let gb = grp.len();
                    match (up8.int8_parts(), down8.int8_parts()) {
                        (Some((uw, ug, uk, un)), Some((dw, dg, dk, dn))) => {
                            debug_assert_eq!(uk, d);
                            debug_assert_eq!(dn, d);
                            debug_assert_eq!(dk, un);
                            for (gi, &r) in grp.iter().enumerate() {
                                xq_g[gi * uk..(gi + 1) * uk].copy_from_slice(acts.x_q_row(r));
                            }
                            let yi = acc.i32_acc(un * gb);
                            crate::gemm::i8_gemm_batch_into(&xq_g[..gb * uk], uw, gb, uk, un, yi);
                            for (gi, &r) in grp.iter().enumerate() {
                                let s = 1.0 / (ug * acts.gammas()[r]);
                                let row = &mut hg[gi * un..(gi + 1) * un];
                                for (j, out) in row.iter_mut().enumerate() {
                                    *out = yi[j * gb + gi] as f32 * s;
                                }
                                silu(row);
                            }
                            acts_e.quantize_rows(&hg[..gb * un], gb, un);
                            let yi = acc.i32_acc(dn * gb);
                            crate::gemm::i8_gemm_batch_into(acts_e.x_q(), dw, gb, dk, dn, yi);
                            for (gi, _) in grp.iter().enumerate() {
                                let s = 1.0 / (dg * acts_e.gammas()[gi]);
                                let yrow = &mut yg[gi * d..(gi + 1) * d];
                                for (j, out) in yrow.iter_mut().enumerate() {
                                    *out = yi[j * gb + gi] as f32 * s;
                                }
                            }
                            for (gi, &r) in grp.iter().enumerate() {
                                let gate = gates[r];
                                for j in 0..d {
                                    xs[r * d + j] +=
                                        dec.beta * y1[r * d + j] + dec.alpha * gate * yg[gi * d + j];
                                }
                            }
                        }
                        _ => {
                            // Non-INT8 experts (no packer produces them):
                            // per-row fallback through the single path.
                            for &r in grp.iter() {
                                let xrow = &xn[r * d..(r + 1) * d];
                                let mut a = QuantActs::quantize(xrow);
                                let mut h8 = up8.forward(xrow, &mut a);
                                silu(&mut h8);
                                let mut a8 = QuantActs::quantize(&h8);
                                let y8 = down8.forward(&h8, &mut a8);
                                let gate = gates[r];
                                for j in 0..d {
                                    xs[r * d + j] +=
                                        dec.beta * y1[r * d + j] + dec.alpha * gate * y8[j];
                                }
                            }
                        }
                    }
                }
                lap(&mut self.timing.ffn_8bit, t0);
            }
        }
    }

    /// Resident weight bytes of this block.
    pub fn storage_bytes(&self) -> usize {
        let mut total = (self.attn_norm.len() + self.ffn_norm.len()) * 2;
        total += self.wq.storage_bytes()
            + self.wk.storage_bytes()
            + self.wv.storage_bytes()
            + self.wo.storage_bytes();
        total += match &self.ffn {
            Ffn::Dense { up, down } => up.storage_bytes() + down.storage_bytes(),
            Ffn::Decoupled(d) => {
                d.up_1bit.storage_bytes()
                    + d.down_1bit.storage_bytes()
                    + d.experts
                        .iter()
                        .map(|(u, dn)| u.storage_bytes() + dn.storage_bytes())
                        .sum::<usize>()
                    + d.router.len() * 2
            }
        };
        total
    }

    /// Build a random block of the given geometry (bench workloads at
    /// paper scale where no trained checkpoint exists).
    pub fn random(
        variant: Variant,
        d: usize,
        n_heads: usize,
        d_ff: usize,
        r: usize,
        n_experts: usize,
        seed: u64,
    ) -> PackedBlock {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mk = |rng: &mut crate::util::rng::Rng, k: usize, n: usize, v: Variant| {
            let wf = rng.normal_vec(k * n);
            match v {
                Variant::Fp16 => QLinear::f32(&wf, k, n),
                Variant::BitNet | Variant::PQuant => QLinear::one_bit(&wf, k, n),
                Variant::BitNet158 => QLinear::ternary(&wf, k, n),
            }
        };
        let ffn = if variant == Variant::PQuant {
            let n1 = d_ff - r;
            Ffn::Decoupled(DecoupledFfn {
                up_1bit: mk(&mut rng, d, n1, Variant::BitNet),
                down_1bit: mk(&mut rng, n1, d, Variant::BitNet),
                experts: (0..n_experts)
                    .map(|_| {
                        let up = rng.normal_vec(d * r);
                        let dn = rng.normal_vec(r * d);
                        (QLinear::int8(&up, d, r), QLinear::int8(&dn, r, d))
                    })
                    .collect(),
                router: rng.normal_vec(d * n_experts),
                alpha: 2.0,
                beta: 0.2,
            })
        } else {
            Ffn::Dense {
                up: mk(&mut rng, d, d_ff, variant),
                down: mk(&mut rng, d_ff, d, variant),
            }
        };
        PackedBlock {
            attn_norm: vec![1.0; d],
            ffn_norm: vec![1.0; d],
            wq: mk(&mut rng, d, d, variant),
            wk: mk(&mut rng, d, d, variant),
            wv: mk(&mut rng, d, d, variant),
            wo: mk(&mut rng, d, d, variant),
            ffn,
            n_heads,
            timing: BlockTiming::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rope_for(d: usize, n_heads: usize, n_pos: usize) -> RopeTable {
        let mut rope = RopeTable::default();
        rope.ensure(d / n_heads / 2, n_pos);
        rope
    }

    fn run_block(variant: Variant) -> Vec<f32> {
        let d = 64;
        let mut block = PackedBlock::random(variant, d, 4, 176, 16, 2, 42);
        let mut cache = KvCache::new(8, d);
        let rope = rope_for(d, 4, 8);
        let x = crate::util::rng::Rng::new(1).normal_vec(d);
        let mut out = vec![];
        for pos in 0..4 {
            out = block.forward(&x, pos, &mut cache, &rope);
        }
        out
    }

    #[test]
    fn all_variants_produce_finite_outputs() {
        for v in [Variant::Fp16, Variant::BitNet, Variant::BitNet158, Variant::PQuant] {
            let y = run_block(v);
            assert_eq!(y.len(), 64);
            assert!(y.iter().all(|x| x.is_finite()), "{v:?} produced non-finite");
        }
    }

    #[test]
    fn kv_cache_grows_and_resets() {
        let mut cache = KvCache::new(4, 8);
        cache.push(&[1.0; 8], &[2.0; 8]).unwrap();
        cache.push(&[3.0; 8], &[4.0; 8]).unwrap();
        assert_eq!(cache.len, 2);
        cache.reset();
        assert_eq!(cache.len, 0);
    }

    #[test]
    fn kv_cache_overflow_is_recoverable() {
        let mut cache = KvCache::new(1, 4);
        cache.push(&[0.0; 4], &[0.0; 4]).unwrap();
        assert_eq!(
            cache.push(&[0.0; 4], &[0.0; 4]),
            Err(KvError::CacheOverflow { cap: 1 }),
            "a full cache must fail the push, not kill the thread"
        );
        assert_eq!(cache.len, 1, "failed push must not corrupt the cache");
    }

    #[test]
    fn timing_accumulates_when_enabled() {
        let d = 64;
        let mut block = PackedBlock::random(Variant::PQuant, d, 4, 176, 16, 4, 7);
        block.timing.mode = TimingMode::Accumulate;
        let mut cache = KvCache::new(8, d);
        let rope = rope_for(d, 4, 8);
        let x = vec![0.5; d];
        block.forward(&x, 0, &mut cache, &rope);
        let t = block.timing.clone();
        assert!(t.total() > Duration::ZERO);
        assert!(t.ffn_8bit > Duration::ZERO, "expert branch must be timed");
        assert!(t.router > Duration::ZERO, "router must be timed");
        block.timing.reset();
        assert_eq!(block.timing.total(), Duration::ZERO);
        assert_eq!(block.timing.mode, TimingMode::Accumulate, "reset keeps the mode");
    }

    #[test]
    fn timing_off_is_free() {
        let d = 64;
        let mut block = PackedBlock::random(Variant::PQuant, d, 4, 176, 16, 2, 7);
        assert_eq!(block.timing.mode, TimingMode::Off, "profiling must be opt-in");
        let mut cache = KvCache::new(8, d);
        let rope = rope_for(d, 4, 8);
        block.forward(&vec![0.5; d], 0, &mut cache, &rope);
        assert_eq!(block.timing.total(), Duration::ZERO, "Off must not accumulate");
    }

    #[test]
    fn pquant_storage_below_ternary_below_fp() {
        let mk = |v| PackedBlock::random(v, 128, 4, 352, 16, 1, 3).storage_bytes();
        let fp = mk(Variant::Fp16);
        let tern = mk(Variant::BitNet158);
        let pq = mk(Variant::PQuant);
        assert!(pq < tern, "pquant {pq} !< ternary {tern}");
        assert!(tern < fp);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x = crate::util::rng::Rng::new(3).normal_vec(32);
        let rope = rope_for(32, 4, 8);
        let before: f32 = x.iter().map(|v| v * v).sum();
        rope_rotate(&mut x, 7, 4, &rope);
        let after: f32 = x.iter().map(|v| v * v).sum();
        assert!((before - after).abs() / before < 1e-5);
    }

    #[test]
    fn rope_table_matches_on_the_fly_math() {
        // The table must store exactly what the old inline computation
        // produced: freq = 10000^(-i/half), angle = pos * freq.
        let mut rope = RopeTable::default();
        rope.ensure(4, 10);
        assert!(rope.len() >= 10);
        for pos in [0usize, 3, 9] {
            let (sin, cos) = rope.row(pos);
            for i in 0..4 {
                let freq = 1.0f32 / 10000f32.powf(i as f32 / 4.0);
                let (s, c) = (pos as f32 * freq).sin_cos();
                assert_eq!(sin[i].to_bits(), s.to_bits(), "sin pos {pos} i {i}");
                assert_eq!(cos[i].to_bits(), c.to_bits(), "cos pos {pos} i {i}");
            }
        }
        // Growing keeps earlier rows intact.
        let before = rope.row(3).0.to_vec();
        rope.ensure(4, 100);
        assert_eq!(rope.row(3).0, &before[..]);
    }
}
