//! Evaluation: held-out perplexity (the WikiText-2 analog) and the seven
//! synthetic zero-shot tasks (ARC-E/ARC-C/HS/BQ/OQ/PQ/WGe analogs).
//!
//! Scoring follows lm-evaluation-harness: each option continuation is
//! scored by mean token log-likelihood under the model; the argmax option
//! is the prediction.

pub mod tasks;

pub use tasks::{task_suite, Task, TaskItem};

use anyhow::Result;

use crate::infer::PackedModel;
use crate::runtime::{CompiledEntry, TrainState};

/// log-softmax over one logit row.
fn log_softmax(row: &[f32]) -> Vec<f32> {
    let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse = row.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
    row.iter().map(|&x| x - lse).collect()
}

/// Perplexity of a token stream under the AOT fwd entry.
///
/// The stream is cut into non-overlapping (seq_len+1) windows; each window
/// contributes seq_len next-token NLL terms.  `max_tokens` bounds the work.
pub fn perplexity(
    state: &TrainState,
    fwd: &CompiledEntry,
    stream: &[u32],
    seq_len: usize,
    vocab: usize,
    max_tokens: usize,
) -> Result<f64> {
    let batch = fwd.spec.batch;
    let window = seq_len + 1;
    let n_windows = (stream.len() / window).min(max_tokens.div_ceil(seq_len)).max(1);
    let mut total_nll = 0.0f64;
    let mut total_tokens = 0usize;

    let mut w = 0usize;
    while w < n_windows {
        let this_batch = batch.min(n_windows - w).max(1);
        // Build a [batch, seq_len] token block; short tail reuses window 0.
        let mut tokens = Vec::with_capacity(batch * seq_len);
        let mut targets: Vec<Vec<u32>> = Vec::with_capacity(batch);
        for b in 0..batch {
            let src = if b < this_batch { w + b } else { 0 };
            let start = src * window;
            tokens.extend(stream[start..start + seq_len].iter().map(|&t| t as i32));
            targets.push(stream[start + 1..start + window].to_vec());
        }
        let (logits, _) = state.forward(fwd, &tokens)?;
        for b in 0..this_batch {
            for t in 0..seq_len {
                let row = &logits[(b * seq_len + t) * vocab..(b * seq_len + t + 1) * vocab];
                let lp = log_softmax(row);
                total_nll -= lp[targets[b][t] as usize] as f64;
                total_tokens += 1;
            }
        }
        w += this_batch;
    }
    Ok((total_nll / total_tokens.max(1) as f64).exp())
}

/// Perplexity of a token stream under a *packed* model (the `.pqm` serving
/// engine — no PJRT involved), so `eval --model out.pqm` can score a
/// shipped artifact.  Same windowing as [`perplexity`]: non-overlapping
/// (seq_len+1) windows, each decoded token-by-token with a fresh KV cache;
/// `max_tokens` bounds the work.
pub fn packed_perplexity(model: &mut PackedModel, stream: &[u32], max_tokens: usize) -> f64 {
    assert!(stream.len() >= 2, "perplexity needs at least two tokens");
    let seq_len = model.cfg.seq_len.min(stream.len() - 1).max(1);
    let window = seq_len + 1;
    let n_windows = (stream.len() / window).max(1).min(max_tokens.div_ceil(seq_len).max(1));
    let mut total_nll = 0.0f64;
    let mut total_tokens = 0usize;
    for w in 0..n_windows {
        let toks = &stream[w * window..w * window + window];
        let mut caches = model.new_caches(seq_len);
        for t in 0..seq_len {
            let logits = model.decode_step(toks[t], t, &mut caches);
            let lp = log_softmax(&logits);
            total_nll -= lp[toks[t + 1] as usize] as f64;
            total_tokens += 1;
        }
    }
    (total_nll / total_tokens.max(1) as f64).exp()
}

/// Mean log-likelihood of `cont` tokens following `prompt` tokens.
///
/// The fwd entry has a fixed [batch, seq_len] signature; sequences are
/// right-padded with token 0 and only real positions are scored.
pub fn continuation_logprob(
    state: &TrainState,
    fwd: &CompiledEntry,
    prompt: &[u32],
    cont: &[u32],
    seq_len: usize,
    vocab: usize,
) -> Result<f64> {
    assert!(!cont.is_empty());
    let batch = fwd.spec.batch;
    let mut seq: Vec<u32> = prompt.iter().chain(cont.iter()).copied().collect();
    if seq.len() > seq_len {
        // keep the tail (the continuation must stay)
        seq = seq[seq.len() - seq_len..].to_vec();
    }
    let real = seq.len();
    let mut tokens = vec![0i32; batch * seq_len];
    for (i, &t) in seq.iter().enumerate() {
        tokens[i] = t as i32;
    }
    let (logits, _) = state.forward(fwd, &tokens)?;
    // positions predicting the continuation: the token at index i is
    // predicted by logits at index i-1
    let cont_start = real - cont.len();
    let mut total = 0.0f64;
    for (k, &target) in seq[cont_start..].iter().enumerate() {
        let pos = cont_start + k - 1; // logits row predicting this token
        let row = &logits[pos * vocab..(pos + 1) * vocab];
        let lp = log_softmax(row);
        total += lp[target as usize] as f64;
    }
    Ok(total / cont.len() as f64)
}

/// Accuracy of the model on one task (fraction of items answered right).
pub fn task_accuracy(
    state: &TrainState,
    fwd: &CompiledEntry,
    bpe: &crate::tokenizer::Bpe,
    task: &Task,
    seq_len: usize,
    vocab: usize,
) -> Result<f64> {
    let mut correct = 0usize;
    for item in &task.items {
        let prompt = bpe.encode(&item.prompt);
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (oi, option) in item.options.iter().enumerate() {
            let cont = bpe.encode(option);
            if cont.is_empty() {
                continue;
            }
            let lp = continuation_logprob(state, fwd, &prompt, &cont, seq_len, vocab)?;
            if lp > best.0 {
                best = (lp, oi);
            }
        }
        if best.1 == item.correct {
            correct += 1;
        }
    }
    Ok(correct as f64 / task.items.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalizes() {
        let lp = log_softmax(&[1.0, 2.0, 3.0]);
        let total: f32 = lp.iter().map(|x| x.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
        assert!(lp[2] > lp[1] && lp[1] > lp[0]);
    }

    #[test]
    fn log_softmax_handles_large_logits() {
        let lp = log_softmax(&[1000.0, 999.0]);
        assert!(lp.iter().all(|x| x.is_finite()));
        // f32 spacing at |1000| is ~6e-5; allow the rounding it induces
        assert!((lp[0].exp() + lp[1].exp() - 1.0).abs() < 1e-3);
    }
}
