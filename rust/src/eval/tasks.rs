//! The seven synthetic zero-shot tasks — analogs of the paper's benchmark
//! suite (Table 2 columns), generated from the same grammar the corpus
//! teaches (DESIGN.md §Substitutions):
//!
//! | paper      | analog probe                                   |
//! |------------|-------------------------------------------------|
//! | ARC-E      | category membership, 4-way                      |
//! | ARC-C      | two-hop category+property, 4-way                |
//! | HellaSwag  | ordered-sequence continuation, 4-way            |
//! | BoolQ      | yes/no membership questions                     |
//! | OpenbookQA | antonym completion, 4-way                       |
//! | PIQA       | tool affordance, 2-way                          |
//! | Winogrande | subject-verb number agreement, 2-way            |

use crate::data::corpus::{AFFORDANCES, CATEGORIES, NOUNS, OPPOSITES, SEQUENCES};
use crate::util::rng::Rng;

/// One multiple-choice item.
#[derive(Debug, Clone)]
pub struct TaskItem {
    pub prompt: String,
    pub options: Vec<String>,
    pub correct: usize,
}

/// A named task with its items.
#[derive(Debug, Clone)]
pub struct Task {
    pub name: &'static str,
    pub paper_name: &'static str,
    pub items: Vec<TaskItem>,
    /// Chance accuracy (1 / n_options) for reporting.
    pub chance: f64,
}

fn pick_distractors(rng: &mut Rng, pool: &[&'static str], correct: &str, n: usize) -> Vec<String> {
    let mut out = Vec::new();
    let mut guard = 0;
    while out.len() < n && guard < 1000 {
        guard += 1;
        let cand = pool[rng.below(pool.len())];
        if cand != correct && !out.iter().any(|o| o == cand) {
            out.push(cand.to_string());
        }
    }
    out
}

fn shuffle_in(rng: &mut Rng, correct: String, mut distractors: Vec<String>) -> (Vec<String>, usize) {
    let pos = rng.below(distractors.len() + 1);
    distractors.insert(pos, correct);
    (distractors, pos)
}

/// ARC-E analog: "a fox is an" → {animal, tool, food, place}.
pub fn arc_easy(rng: &mut Rng, n: usize) -> Task {
    let mut items = Vec::new();
    for _ in 0..n {
        let noun = &NOUNS[rng.below(NOUNS.len())];
        let distractors: Vec<String> = CATEGORIES
            .iter()
            .filter(|c| **c != noun.category)
            .map(|c| c.to_string())
            .collect();
        let (options, correct) = shuffle_in(rng, noun.category.to_string(), distractors);
        items.push(TaskItem {
            prompt: format!("the {} is a", noun.word),
            options,
            correct,
        });
    }
    Task { name: "arc_e", paper_name: "ARC-E", items, chance: 0.25 }
}

/// ARC-C analog (harder, two-hop): "the <property> one is a" with the
/// property pointing at a noun, options are categories.
pub fn arc_challenge(rng: &mut Rng, n: usize) -> Task {
    let mut items = Vec::new();
    for _ in 0..n {
        let noun = &NOUNS[rng.below(NOUNS.len())];
        let distractors: Vec<String> = CATEGORIES
            .iter()
            .filter(|c| **c != noun.category)
            .map(|c| c.to_string())
            .collect();
        let (options, correct) = shuffle_in(rng, noun.category.to_string(), distractors);
        items.push(TaskItem {
            prompt: format!("the {} is {} . the {} is a", noun.word, noun.property, noun.word),
            options,
            correct,
        });
    }
    Task { name: "arc_c", paper_name: "ARC-C", items, chance: 0.25 }
}

/// HellaSwag analog: continue an ordered sequence.
pub fn hellaswag(rng: &mut Rng, n: usize) -> Task {
    let mut items = Vec::new();
    let flat_words: Vec<&'static str> = SEQUENCES.iter().flat_map(|s| s.iter().copied()).collect();
    for _ in 0..n {
        let seq = SEQUENCES[rng.below(SEQUENCES.len())];
        let pos = 1 + rng.below(seq.len() - 2);
        let prompt = seq[..pos.min(3).max(2).min(pos)].to_vec();
        let prompt_start = pos.saturating_sub(3);
        let prompt = seq[prompt_start..pos].join(" ");
        let correct_word = seq[pos];
        let distractors = pick_distractors(rng, &flat_words, correct_word, 3);
        let (options, correct) = shuffle_in(rng, correct_word.to_string(), distractors);
        items.push(TaskItem { prompt, options, correct });
        let _ = prompt_start;
    }
    Task { name: "hellaswag", paper_name: "HS", items, chance: 0.25 }
}

/// BoolQ analog: yes/no category membership.
pub fn boolq(rng: &mut Rng, n: usize) -> Task {
    let mut items = Vec::new();
    for i in 0..n {
        let noun = &NOUNS[rng.below(NOUNS.len())];
        let truthy = i % 2 == 0;
        let category = if truthy {
            noun.category.to_string()
        } else {
            CATEGORIES[(CATEGORIES.iter().position(|c| *c == noun.category).unwrap()
                + 1 + rng.below(3))
                % 4]
            .to_string()
        };
        // The corpus states facts as "a fox is an animal ."; a yes/no probe
        // scores which completion the model finds more likely.
        let correct_stmt = format!("{}", noun.category);
        let options = vec![category.clone(), correct_stmt.clone()];
        // If the claim is true, the claimed category IS the correct word,
        // so both options coincide — instead probe with "is/is not".
        let _ = options;
        let prompt = format!("the {} is a", noun.word);
        let (options, correct) = if truthy {
            let d = pick_distractors(
                rng,
                &CATEGORIES,
                noun.category,
                1,
            );
            let (o, c) = shuffle_in(rng, noun.category.to_string(), d);
            (o, c)
        } else {
            let (o, c) = shuffle_in(rng, noun.category.to_string(), vec![category]);
            (o, c)
        };
        items.push(TaskItem { prompt, options, correct });
    }
    Task { name: "boolq", paper_name: "BQ", items, chance: 0.5 }
}

/// OpenbookQA analog: antonym completion.
pub fn openbookqa(rng: &mut Rng, n: usize) -> Task {
    let all_words: Vec<&'static str> =
        OPPOSITES.iter().flat_map(|(a, b)| [*a, *b]).collect();
    let mut items = Vec::new();
    for _ in 0..n {
        let (a, b) = OPPOSITES[rng.below(OPPOSITES.len())];
        let (q, ans) = if rng.below(2) == 0 { (a, b) } else { (b, a) };
        let distractors = pick_distractors(rng, &all_words, ans, 3)
            .into_iter()
            .filter(|d| d != q)
            .take(3)
            .collect::<Vec<_>>();
        let (options, correct) = shuffle_in(rng, ans.to_string(), distractors);
        items.push(TaskItem {
            prompt: format!("the opposite of {q} is"),
            options,
            correct,
        });
    }
    Task { name: "openbookqa", paper_name: "OQ", items, chance: 0.25 }
}

/// PIQA analog: tool affordance, 2-way.
pub fn piqa(rng: &mut Rng, n: usize) -> Task {
    let tools: Vec<&'static str> = AFFORDANCES.iter().map(|(_, t)| *t).collect();
    let mut items = Vec::new();
    for _ in 0..n {
        let (action, tool) = AFFORDANCES[rng.below(AFFORDANCES.len())];
        let food = loop {
            let n = &NOUNS[rng.below(NOUNS.len())];
            if n.category == "food" {
                break n;
            }
        };
        let distractors = pick_distractors(rng, &tools, tool, 1);
        let (options, correct) = shuffle_in(rng, tool.to_string(), distractors);
        items.push(TaskItem {
            prompt: format!("you {action} the {} with a", food.word),
            options,
            correct,
        });
    }
    Task { name: "piqa", paper_name: "PQ", items, chance: 0.5 }
}

/// Winogrande analog: number agreement (are/is after plural/singular).
pub fn winogrande(rng: &mut Rng, n: usize) -> Task {
    let mut items = Vec::new();
    for i in 0..n {
        let noun = &NOUNS[rng.below(NOUNS.len())];
        let plural = i % 2 == 0;
        let subject = if plural { noun.plural } else { noun.word };
        let correct_verb = if plural { "are" } else { "is" };
        let wrong_verb = if plural { "is" } else { "are" };
        let (options, correct) =
            shuffle_in(rng, correct_verb.to_string(), vec![wrong_verb.to_string()]);
        items.push(TaskItem {
            prompt: format!("the {subject}"),
            options,
            correct,
        });
    }
    Task { name: "winogrande", paper_name: "WGe", items, chance: 0.5 }
}

/// The full suite in paper column order.
pub fn task_suite(seed: u64, items_per_task: usize) -> Vec<Task> {
    let mut rng = Rng::new(seed);
    vec![
        arc_easy(&mut rng, items_per_task),
        arc_challenge(&mut rng, items_per_task),
        hellaswag(&mut rng, items_per_task),
        boolq(&mut rng, items_per_task),
        openbookqa(&mut rng, items_per_task),
        piqa(&mut rng, items_per_task),
        winogrande(&mut rng, items_per_task),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_seven_tasks() {
        let suite = task_suite(1, 10);
        assert_eq!(suite.len(), 7);
        let names: Vec<_> = suite.iter().map(|t| t.paper_name).collect();
        assert_eq!(names, vec!["ARC-E", "ARC-C", "HS", "BQ", "OQ", "PQ", "WGe"]);
    }

    #[test]
    fn items_well_formed() {
        for task in task_suite(2, 25) {
            assert_eq!(task.items.len(), 25, "{}", task.name);
            for item in &task.items {
                assert!(item.correct < item.options.len(), "{}", task.name);
                assert!(!item.prompt.is_empty());
                // options unique
                let mut opts = item.options.clone();
                opts.sort();
                opts.dedup();
                assert_eq!(opts.len(), item.options.len(),
                    "{}: duplicate options {:?}", task.name, item.options);
            }
        }
    }

    #[test]
    fn correct_option_matches_grammar() {
        let suite = task_suite(3, 40);
        let arc = &suite[0];
        for item in &arc.items {
            // "the fox is a" → correct option must be that noun's category
            let noun_word = item.prompt.split_whitespace().nth(1).unwrap();
            let noun = NOUNS.iter().find(|n| n.word == noun_word).unwrap();
            assert_eq!(item.options[item.correct], noun.category);
        }
    }

    #[test]
    fn deterministic() {
        let a = task_suite(7, 5);
        let b = task_suite(7, 5);
        for (x, y) in a.iter().zip(&b) {
            for (ix, iy) in x.items.iter().zip(&y.items) {
                assert_eq!(ix.prompt, iy.prompt);
                assert_eq!(ix.options, iy.options);
            }
        }
    }
}
