//! Per-request structured traces and Chrome trace-event export.
//!
//! When tracing is enabled (`EngineOptions::trace` / `repro serve
//! --trace[-out]`), every submitted request carries a `TraceBuilder`
//! through the engine: submit, queue wait, KV reservation, each prefill
//! chunk, each fused batch step it rode (with rows/occupancy), spec
//! verify rounds (proposed/accepted), preempt/resume, and exactly one
//! terminal event. Completed traces land in a fixed-size ring on
//! `TraceShared`; pool-level KV events (copy-on-write, spill write,
//! fault-back, eviction) that have no single owning request are recorded
//! on a separate bounded ring and rendered as their own track.
//!
//! Everything exports as Chrome trace-event JSON (the object form:
//! `{"traceEvents": [...]}`), loadable in Perfetto / `chrome://tracing`:
//! one `tid` per request plus `tid` 0 for the KV pool track. Timestamps
//! are microseconds relative to the engine-start epoch (the absolute
//! epoch is carried in the `epochUnixUs` top-level key).
//!
//! With tracing disabled nothing here is ever constructed: the engine's
//! per-request trace handle is `None` and every hook is a skipped
//! `if let` — the steady-state decode loop stays allocation-free
//! (asserted by `tests/alloc_free.rs`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use crate::util::json::{arr, num, obj, s, Json};

/// Per-request span cap: beyond this, spans are counted in
/// `RequestTrace::dropped` instead of stored (terminal events always fit).
pub const MAX_SPANS: usize = 512;
/// Completed traces kept (FIFO eviction; evictions counted).
pub const TRACE_RING: usize = 256;
/// Pool-level KV events kept (FIFO eviction).
pub const KV_EVENT_RING: usize = 4096;

/// What a span marks. Durationful spans (`Queue`, `PrefillChunk`,
/// `BatchStep`, `SpecVerify`) carry t0 < t1; the rest are instants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Request entered `Engine::submit`. a = prompt len, b = n_new.
    Submit,
    /// Submit to worker admission. a = b = 0.
    Queue,
    /// KV reservation attached at admission. a = worst-case positions
    /// reserved, b = positions covered by a shared prefix (skipped).
    KvReserve,
    /// One prefill chunk fed in a fused step. a = start, b = end.
    PrefillChunk,
    /// One fused batch step the request rode. a = rows, b = sequences.
    BatchStep,
    /// One speculative verify round. a = proposed, b = accepted.
    SpecVerify,
    /// Preempted: KV freed, parked for deterministic recompute.
    Preempt,
    /// Re-admitted after preemption.
    Resume,
    /// Exactly one per trace. a = finish-reason code
    /// (0 stop, 1 length, 2 cancelled, 3 failed), b = tokens emitted.
    Terminal,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Submit => "submit",
            SpanKind::Queue => "queue",
            SpanKind::KvReserve => "kv_reserve",
            SpanKind::PrefillChunk => "prefill_chunk",
            SpanKind::BatchStep => "batch_step",
            SpanKind::SpecVerify => "spec_verify",
            SpanKind::Preempt => "preempt",
            SpanKind::Resume => "resume",
            SpanKind::Terminal => "terminal",
        }
    }

    /// Names for the two payload args in the Chrome export.
    fn arg_names(self) -> (&'static str, &'static str) {
        match self {
            SpanKind::Submit => ("prompt_len", "n_new"),
            SpanKind::Queue => ("a", "b"),
            SpanKind::KvReserve => ("reserved_positions", "cached_positions"),
            SpanKind::PrefillChunk => ("start", "end"),
            SpanKind::BatchStep => ("rows", "seqs"),
            SpanKind::SpecVerify => ("proposed", "accepted"),
            SpanKind::Preempt | SpanKind::Resume => ("a", "b"),
            SpanKind::Terminal => ("reason_code", "tokens"),
        }
    }
}

/// Pool-level KV events with no single owning request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvEventKind {
    /// Copy-on-write divergence from a shared page.
    CowCopy,
    /// Shared-prefix entry shed to the disk spill tier.
    SpillWrite,
    /// Spilled entry faulted back on prompt recurrence.
    SpillFault,
    /// Fault-back failed (degrades to a recompute miss).
    SpillFaultFail,
    /// Blocks evicted from the prefix-share map.
    Evict,
}

impl KvEventKind {
    pub fn name(self) -> &'static str {
        match self {
            KvEventKind::CowCopy => "kv_cow_copy",
            KvEventKind::SpillWrite => "kv_spill_write",
            KvEventKind::SpillFault => "kv_spill_fault",
            KvEventKind::SpillFaultFail => "kv_spill_fault_fail",
            KvEventKind::Evict => "kv_evict",
        }
    }
}

/// One recorded span. Times are µs since the `TraceShared` epoch;
/// instants have `t0_us == t1_us`.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub kind: SpanKind,
    pub t0_us: u64,
    pub t1_us: u64,
    pub a: u64,
    pub b: u64,
}

/// A completed request's spans, in recording order.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    pub id: u64,
    pub spans: Vec<Span>,
    /// Spans discarded past `MAX_SPANS` (the terminal is never dropped).
    pub dropped: usize,
}

impl RequestTrace {
    pub fn terminal(&self) -> Option<&Span> {
        self.spans.iter().find(|sp| sp.kind == SpanKind::Terminal)
    }

    /// This request alone as a Chrome trace-event JSON document.
    pub fn to_chrome_json(&self, epoch_unix_us: u64) -> Json {
        chrome_trace_json(std::slice::from_ref(self), &[], epoch_unix_us)
    }
}

/// One pool-level event on the KV track.
#[derive(Clone, Copy, Debug)]
pub struct KvEvent {
    pub t_us: u64,
    pub kind: KvEventKind,
    /// Blocks involved (copies made, blocks spilled/faulted/evicted).
    pub n: u64,
}

/// Shared trace state: the epoch clock, the completed-trace ring, and the
/// KV event ring. One per engine; cloned `Arc`s go to workers, the HTTP
/// front end, and (for KV events) the block pools.
pub struct TraceShared {
    epoch: Instant,
    epoch_unix_us: u64,
    ring: Mutex<VecDeque<RequestTrace>>,
    kv_events: Mutex<VecDeque<KvEvent>>,
    dropped_traces: AtomicU64,
}

impl TraceShared {
    #[allow(clippy::new_ret_no_self)]
    pub fn new() -> Arc<TraceShared> {
        Arc::new(TraceShared {
            epoch: Instant::now(),
            epoch_unix_us: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_micros() as u64)
                .unwrap_or(0),
            ring: Mutex::new(VecDeque::with_capacity(TRACE_RING)),
            kv_events: Mutex::new(VecDeque::with_capacity(256)),
            dropped_traces: AtomicU64::new(0),
        })
    }

    /// Microseconds since the engine-start epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    pub fn epoch_unix_us(&self) -> u64 {
        self.epoch_unix_us
    }

    /// Start recording a request. The builder travels with the request
    /// through admission, decode, and preemption; `TraceBuilder::finish`
    /// lands it back in the ring here.
    pub fn begin(self: &Arc<Self>, id: u64) -> Box<TraceBuilder> {
        Box::new(TraceBuilder {
            id,
            t_begin_us: self.now_us(),
            spans: Vec::with_capacity(32),
            dropped: 0,
            shared: Arc::clone(self),
        })
    }

    /// Record a pool-level KV event (no-op cost is borne by the caller's
    /// `if let Some(..)` — pools without an attached recorder never call).
    pub fn kv_event(&self, kind: KvEventKind, n: u64) {
        let ev = KvEvent { t_us: self.now_us(), kind, n };
        let mut ring = self.kv_events.lock().unwrap();
        if ring.len() >= KV_EVENT_RING {
            ring.pop_front();
        }
        ring.push_back(ev);
    }

    fn complete(&self, trace: RequestTrace) {
        let mut ring = self.ring.lock().unwrap();
        if ring.len() >= TRACE_RING {
            ring.pop_front();
            self.dropped_traces.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(trace);
    }

    /// Completed traces evicted from the ring so far.
    pub fn dropped_traces(&self) -> u64 {
        self.dropped_traces.load(Ordering::Relaxed)
    }

    /// Completed traces currently held in the ring.
    pub fn completed_count(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    /// Snapshot of all completed traces, oldest first.
    pub fn completed(&self) -> Vec<RequestTrace> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    /// Snapshot of the KV event ring, oldest first.
    pub fn kv_events(&self) -> Vec<KvEvent> {
        self.kv_events.lock().unwrap().iter().cloned().collect()
    }

    /// A completed request's trace by id.
    pub fn find(&self, id: u64) -> Option<RequestTrace> {
        self.ring.lock().unwrap().iter().find(|t| t.id == id).cloned()
    }

    /// The most recently completed trace.
    pub fn latest(&self) -> Option<RequestTrace> {
        self.ring.lock().unwrap().back().cloned()
    }

    /// Everything (all completed traces + the KV track) as one Chrome
    /// trace-event JSON document.
    pub fn to_chrome_json(&self) -> Json {
        let traces = self.completed();
        let kv = self.kv_events();
        chrome_trace_json(&traces, &kv, self.epoch_unix_us)
    }
}

/// Per-request span recorder. Boxed so moving it with the request through
/// channels stays cheap; methods never lock `TraceShared`.
pub struct TraceBuilder {
    id: u64,
    t_begin_us: u64,
    spans: Vec<Span>,
    dropped: usize,
    shared: Arc<TraceShared>,
}

impl TraceBuilder {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// µs since the engine epoch (for `span_since` starts).
    pub fn now_us(&self) -> u64 {
        self.shared.now_us()
    }

    /// When this builder was created (the submit timestamp).
    pub fn begin_us(&self) -> u64 {
        self.t_begin_us
    }

    fn push(&mut self, sp: Span) {
        if self.spans.len() >= MAX_SPANS && sp.kind != SpanKind::Terminal {
            self.dropped += 1;
            return;
        }
        self.spans.push(sp);
    }

    /// Record an instant (t0 == t1 == now).
    pub fn instant(&mut self, kind: SpanKind, a: u64, b: u64) {
        let t = self.shared.now_us();
        self.push(Span { kind, t0_us: t, t1_us: t, a, b });
    }

    /// Record a span that started at `t0_us` and ends now. Clamped so
    /// timestamps stay monotone even across clock-read races.
    pub fn span_since(&mut self, kind: SpanKind, t0_us: u64, a: u64, b: u64) {
        let t1 = self.shared.now_us().max(t0_us);
        self.push(Span { kind, t0_us, t1_us: t1, a, b });
    }

    /// Record the terminal event and land the trace in the shared ring.
    /// Consumes the builder: a request gets exactly one terminal.
    pub fn finish(mut self: Box<Self>, reason_code: u64, tokens: u64) {
        self.instant(SpanKind::Terminal, reason_code, tokens);
        let shared = Arc::clone(&self.shared);
        shared.complete(RequestTrace { id: self.id, spans: self.spans, dropped: self.dropped });
    }
}

/// Render traces + KV events as a Chrome trace-event JSON document
/// (object form). `ts`/`dur` are µs; request spans ride `tid` = request
/// id, pool-level KV events ride `tid` 0 ("kv-pool").
pub fn chrome_trace_json(traces: &[RequestTrace], kv: &[KvEvent], epoch_unix_us: u64) -> Json {
    let mut events: Vec<Json> = Vec::new();
    events.push(obj(vec![
        ("name", s("process_name")),
        ("ph", s("M")),
        ("pid", num(1.0)),
        ("tid", num(0.0)),
        ("args", obj(vec![("name", s("pquant-serve"))])),
    ]));
    events.push(obj(vec![
        ("name", s("thread_name")),
        ("ph", s("M")),
        ("pid", num(1.0)),
        ("tid", num(0.0)),
        ("args", obj(vec![("name", s("kv-pool"))])),
    ]));
    for t in traces {
        for sp in &t.spans {
            let (an, bn) = sp.kind.arg_names();
            let args = obj(vec![(an, num(sp.a as f64)), (bn, num(sp.b as f64))]);
            let mut fields = vec![
                ("name", s(sp.kind.name())),
                ("pid", num(1.0)),
                ("tid", num(t.id as f64)),
                ("ts", num(sp.t0_us as f64)),
                ("args", args),
            ];
            if sp.t1_us > sp.t0_us {
                fields.push(("ph", s("X")));
                fields.push(("dur", num((sp.t1_us - sp.t0_us) as f64)));
            } else {
                fields.push(("ph", s("i")));
                fields.push(("s", s("t")));
            }
            events.push(obj(fields));
        }
    }
    for ev in kv {
        events.push(obj(vec![
            ("name", s(ev.kind.name())),
            ("ph", s("i")),
            ("s", s("t")),
            ("pid", num(1.0)),
            ("tid", num(0.0)),
            ("ts", num(ev.t_us as f64)),
            ("args", obj(vec![("blocks", num(ev.n as f64))])),
        ]));
    }
    obj(vec![
        ("traceEvents", arr(events)),
        ("displayTimeUnit", s("ms")),
        ("epochUnixUs", num(epoch_unix_us as f64)),
    ])
}

/// What `validate_chrome_json` measured about a trace document.
#[derive(Debug, Default, Clone, Copy)]
pub struct ChromeSummary {
    pub events: usize,
    pub terminals: usize,
}

/// Structural validation of a Chrome trace-event JSON document: the
/// object form with a `traceEvents` array, every event carrying
/// name/ph/pid/tid (+ ts and, for "X", dur), and per-tid timestamps
/// monotone non-decreasing. Shared by `repro obs-check` and the tests.
pub fn validate_chrome_json(j: &Json) -> Result<ChromeSummary, String> {
    let events = j
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .map_err(|_| "missing traceEvents array".to_string())?;
    let mut last_ts: std::collections::HashMap<u64, f64> = std::collections::HashMap::new();
    let mut summary = ChromeSummary::default();
    for (i, ev) in events.iter().enumerate() {
        let name = ev
            .get("name")
            .and_then(|n| n.as_str())
            .map_err(|_| format!("event {i}: missing name"))?;
        let ph = ev
            .get("ph")
            .and_then(|p| p.as_str())
            .map_err(|_| format!("event {i} ({name}): missing ph"))?;
        let tid = ev
            .get("tid")
            .and_then(|t| t.as_f64())
            .map_err(|_| format!("event {i} ({name}): missing tid"))?;
        if ph == "M" {
            continue; // metadata events carry no timestamp
        }
        let ts = ev
            .get("ts")
            .and_then(|t| t.as_f64())
            .map_err(|_| format!("event {i} ({name}): missing ts"))?;
        if ph == "X" {
            ev.get("dur")
                .and_then(|d| d.as_f64())
                .map_err(|_| format!("event {i} ({name}): X without dur"))?;
        }
        let key = tid as u64;
        if let Some(&prev) = last_ts.get(&key) {
            if ts < prev {
                return Err(format!(
                    "event {i} ({name}): ts {ts} precedes {prev} on tid {key}"
                ));
            }
        }
        last_ts.insert(key, ts);
        summary.events += 1;
        if name == "terminal" {
            summary.terminals += 1;
        }
    }
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_records_and_completes_into_ring() {
        let shared = TraceShared::new();
        let mut b = shared.begin(7);
        b.instant(SpanKind::Submit, 3, 8);
        let t0 = b.now_us();
        b.span_since(SpanKind::Queue, t0, 0, 0);
        b.finish(1, 8);
        let tr = shared.find(7).expect("completed trace");
        assert_eq!(tr.spans.len(), 3);
        assert_eq!(tr.terminal().unwrap().a, 1);
        assert!(shared.find(8).is_none());
        assert_eq!(shared.latest().unwrap().id, 7);
    }

    #[test]
    fn span_cap_drops_but_keeps_terminal() {
        let shared = TraceShared::new();
        let mut b = shared.begin(1);
        for _ in 0..(MAX_SPANS + 10) {
            b.instant(SpanKind::BatchStep, 1, 1);
        }
        b.finish(0, 0);
        let tr = shared.latest().unwrap();
        assert_eq!(tr.spans.len(), MAX_SPANS + 1);
        assert_eq!(tr.dropped, 10);
        assert_eq!(tr.spans.last().unwrap().kind, SpanKind::Terminal);
    }

    #[test]
    fn chrome_export_validates() {
        let shared = TraceShared::new();
        for id in 1..=3u64 {
            let mut b = shared.begin(id);
            b.instant(SpanKind::Submit, 4, 4);
            let t0 = b.now_us();
            std::thread::sleep(std::time::Duration::from_micros(50));
            b.span_since(SpanKind::BatchStep, t0, 2, 2);
            b.finish(0, 4);
        }
        shared.kv_event(KvEventKind::CowCopy, 2);
        let j = shared.to_chrome_json();
        let summary = validate_chrome_json(&j).expect("valid chrome trace");
        assert_eq!(summary.terminals, 3);
        assert!(summary.events >= 9);
        // Round-trips through the hand-rolled JSON printer/parser.
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(validate_chrome_json(&reparsed).unwrap().terminals, 3);
    }
}
