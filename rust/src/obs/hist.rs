//! Lock-free log-linear histograms for hot-path latency recording.
//!
//! An HdrHistogram-style layout: values are scaled to fixed-point units
//! (1/1024 of the caller's unit, so sub-millisecond latencies keep
//! precision), bucketed linearly below `SUB` units and log-linearly above
//! — `SUB` sub-buckets per power-of-two octave. Recording is three relaxed
//! atomic adds (bucket, count, sum): no lock, no allocation, mergeable
//! across histograms with identical (compile-time) geometry.
//!
//! Quantiles are nearest-rank over the bucket counts, reported as the
//! bucket midpoint; the relative error is bounded by the bucket width,
//! `1/SUB` of the value (see `REL_ERROR`), versus an exact sort of the
//! same samples. Memory is a fixed `N_BUCKETS * 8` bytes (~15 KiB) per
//! histogram regardless of sample count — unlike the mutexed 4096-sample
//! rings this replaces, nothing is resampled away and no scrape sorts.

use std::sync::atomic::{AtomicU64, Ordering};

/// log2 of the sub-bucket count per octave.
const SUB_BITS: u32 = 5;
/// Sub-buckets per octave; also the linear-region width in units.
const SUB: usize = 1 << SUB_BITS;
/// Linear region + one octave of `SUB` buckets per remaining exponent.
const N_BUCKETS: usize = SUB + (64 - SUB_BITS as usize) * SUB;
/// Fixed-point scale: recorded values are quantized to 1/SCALE units.
const SCALE: f64 = 1024.0;

/// Worst-case relative quantile error versus an exact nearest-rank sort:
/// a sample lies anywhere in its bucket, the midpoint is reported, and
/// buckets are at most `value/SUB` wide. (Values under `SUB/SCALE` units
/// add an absolute quantization error of at most `1.5/SCALE`.)
pub const REL_ERROR: f64 = 1.0 / SUB as f64;

/// Lock-free log-bucketed histogram. All methods take `&self`; recording
/// is wait-free and allocation-free.
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    /// Sum of recorded values in fixed-point units (1/SCALE).
    sum_units: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_units: AtomicU64::new(0),
        }
    }

    /// Bucket index for a fixed-point value. Total order: linear below
    /// `SUB`, then `SUB` equal sub-buckets per power-of-two octave.
    fn index(u: u64) -> usize {
        if u < SUB as u64 {
            u as usize
        } else {
            let e = 63 - u.leading_zeros(); // u in [2^e, 2^{e+1}), e >= SUB_BITS
            let sub = ((u >> (e - SUB_BITS)) & (SUB as u64 - 1)) as usize;
            (e - SUB_BITS + 1) as usize * SUB + sub
        }
    }

    /// Midpoint of bucket `idx`, back in caller units.
    fn value_of(idx: usize) -> f64 {
        let mid = if idx < SUB {
            idx as f64 + 0.5
        } else {
            let shift = (idx / SUB - 1) as u32;
            let lo = (SUB as u64 + (idx % SUB) as u64) << shift;
            lo as f64 + (1u64 << shift) as f64 / 2.0
        };
        mid / SCALE
    }

    /// Record one value (negative / non-finite values clamp to zero).
    /// Three relaxed atomic adds: safe from any thread, never allocates.
    pub fn record(&self, v: f64) {
        let u = if v.is_finite() { (v * SCALE).round().max(0.0) as u64 } else { 0 };
        self.buckets[Self::index(u)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_units.fetch_add(u, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        self.sum_units.load(Ordering::Relaxed) as f64 / SCALE
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() / n as f64
        }
    }

    /// Nearest-rank quantile (`q` in percent, e.g. 50/95/99): the midpoint
    /// of the bucket holding the `ceil(q*n/100)`-th smallest sample. Walks
    /// at most `N_BUCKETS` counters; nothing is sorted. Empty => 0.
    pub fn quantile(&self, q: usize) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((n as u128 * q as u128 + 99) / 100).max(1) as u64;
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum = cum.saturating_add(b.load(Ordering::Relaxed));
            if cum >= rank {
                return Self::value_of(i);
            }
        }
        Self::value_of(N_BUCKETS - 1)
    }

    /// Add every bucket of `other` into `self` (same compile-time
    /// geometry, so the merge is exact: bucket-wise counter adds).
    pub fn merge_from(&self, other: &Histogram) {
        for (a, b) in self.buckets.iter().zip(other.buckets.iter()) {
            let v = b.load(Ordering::Relaxed);
            if v > 0 {
                a.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_units.fetch_add(other.sum_units.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Reset all counters to zero (scrape-and-reset style consumers).
    pub fn clear(&self) {
        for b in self.buckets.iter() {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_units.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_value_invert_within_bucket_width() {
        // Every power-of-two boundary and neighbors must map to a bucket
        // whose midpoint is within one bucket width of the raw value.
        for e in 0..63u32 {
            for delta in [0i64, 1, -1, 7] {
                let u = (1i64.checked_shl(e).unwrap_or(i64::MAX) + delta).max(0) as u64;
                let idx = Histogram::index(u);
                assert!(idx < N_BUCKETS, "u={u} idx={idx}");
                let mid = Histogram::value_of(idx) * SCALE;
                let width = if u < SUB as u64 {
                    1.0
                } else {
                    (u as f64 / SUB as f64).max(1.0)
                };
                assert!(
                    (mid - u as f64).abs() <= width,
                    "u={u} idx={idx} mid={mid} width={width}"
                );
            }
        }
    }

    #[test]
    fn bucket_indices_are_monotone() {
        let mut last = 0usize;
        let mut u = 0u64;
        while u < 1 << 40 {
            let idx = Histogram::index(u);
            assert!(idx >= last, "index must not decrease: u={u}");
            last = idx;
            u = u * 2 + 1;
        }
    }

    #[test]
    fn quantiles_of_small_exact_sets() {
        let h = Histogram::new();
        for v in 1..=10 {
            h.record(v as f64);
        }
        assert_eq!(h.count(), 10);
        // Nearest-rank: p50 of 1..=10 is the 5th sample, p95/p99 the 10th;
        // the estimate is the bucket midpoint, within REL_ERROR relative.
        assert!((h.quantile(50) - 5.0).abs() <= 5.0 * REL_ERROR, "p50={}", h.quantile(50));
        assert!((h.quantile(95) - 10.0).abs() <= 10.0 * REL_ERROR);
        assert!((h.quantile(99) - 10.0).abs() <= 10.0 * REL_ERROR);
        assert!((h.mean() - 5.5).abs() < 0.01);
    }

    #[test]
    fn merge_is_bucket_exact() {
        let (a, b, both) = (Histogram::new(), Histogram::new(), Histogram::new());
        let mut x = 0.37f64;
        for i in 0..500 {
            x = (x * 1103.515245 + 1.2345) % 997.0;
            if i % 2 == 0 { &a } else { &b }.record(x);
            both.record(x);
        }
        a.merge_from(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.sum_units.load(Ordering::Relaxed), both.sum_units.load(Ordering::Relaxed));
        for q in [1, 10, 50, 90, 95, 99, 100] {
            assert_eq!(a.quantile(q), both.quantile(q), "q={q}");
        }
    }
}
