//! Zero-dependency observability core: lock-free histograms, a named
//! counter/gauge registry, per-request structured traces, and Prometheus
//! text exposition.
//!
//! Three pillars, threaded through the serving stack:
//!
//! - [`hist::Histogram`] — log-bucketed, atomic, mergeable latency
//!   histograms. These replace the mutexed sample rings `ServeMetrics`
//!   used to keep: hot-path recording is three relaxed atomic adds and
//!   scrapes walk bucket counters instead of sorting 4096 samples.
//! - [`trace`] — opt-in per-request span recording (submit → queue →
//!   admission/KV → prefill chunks → fused batch steps → spec rounds →
//!   terminal) plus a pool-level KV event track, exportable as Chrome
//!   trace-event JSON (Perfetto-loadable) via `repro serve --trace-out`
//!   or `GET /v1/trace/<id>`. Disabled tracing is a skipped `if let`:
//!   the steady-state decode loop stays allocation-free.
//! - [`prom`] — Prometheus text exposition with family grouping, served
//!   by `GET /v1/metrics` under content negotiation (JSON stays the
//!   default), plus the minimal parser `repro obs-check` and the tests
//!   use to prove the exposition round-trips.
//!
//! The [`Registry`] ties named counters/gauges from anywhere in the
//! stack (e.g. the `infer::TimingMode` per-phase decode timers) into the
//! same exposition. Handles are `Arc`s resolved once at setup;
//! recording through a handle never takes the registry lock.

pub mod hist;
pub mod prom;
pub mod trace;

pub use hist::Histogram;
pub use trace::{KvEventKind, RequestTrace, Span, SpanKind, TraceBuilder, TraceShared};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonically increasing counter. Recording is a relaxed atomic add.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge holding an `f64` (stored as bits).
pub struct Gauge(AtomicU64);

impl Default for Gauge {
    fn default() -> Self {
        Gauge(AtomicU64::new(0.0f64.to_bits()))
    }
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

struct Entry {
    name: String,
    labels: Vec<(String, String)>,
    help: String,
    metric: Metric,
}

/// Named metric registry. Registration (get-or-create by name + label
/// set) takes a short lock and may allocate; the returned `Arc` handles
/// are lock-free to record through — resolve them once at setup, not on
/// the hot path. Scrapes iterate the entries under the same lock.
#[derive(Default)]
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        self.counter_with(name, &[], help)
    }

    pub fn counter_with(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        help: &str,
    ) -> Arc<Counter> {
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = find(&entries, name, labels) {
            if let Metric::Counter(c) = &e.metric {
                return Arc::clone(c);
            }
            debug_assert!(false, "metric {name} re-registered with a different type");
        }
        let c = Arc::new(Counter::default());
        entries.push(entry(name, labels, help, Metric::Counter(Arc::clone(&c))));
        c
    }

    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        self.gauge_with(name, &[], help)
    }

    pub fn gauge_with(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Arc<Gauge> {
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = find(&entries, name, labels) {
            if let Metric::Gauge(g) = &e.metric {
                return Arc::clone(g);
            }
            debug_assert!(false, "metric {name} re-registered with a different type");
        }
        let g = Arc::new(Gauge::default());
        entries.push(entry(name, labels, help, Metric::Gauge(Arc::clone(&g))));
        g
    }

    pub fn histogram(&self, name: &str, help: &str) -> Arc<Histogram> {
        self.histogram_with(name, &[], help)
    }

    pub fn histogram_with(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        help: &str,
    ) -> Arc<Histogram> {
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = find(&entries, name, labels) {
            if let Metric::Histogram(h) = &e.metric {
                return Arc::clone(h);
            }
            debug_assert!(false, "metric {name} re-registered with a different type");
        }
        let h = Arc::new(Histogram::new());
        entries.push(entry(name, labels, help, Metric::Histogram(Arc::clone(&h))));
        h
    }

    /// Counters and gauges as (display name, value) pairs for the JSON
    /// endpoint; labelled entries render as `name{k="v",..}`.
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        let entries = self.entries.lock().unwrap();
        entries
            .iter()
            .filter_map(|e| {
                let v = match &e.metric {
                    Metric::Counter(c) => c.get() as f64,
                    Metric::Gauge(g) => g.get(),
                    Metric::Histogram(_) => return None,
                };
                Some((display_name(e), v))
            })
            .collect()
    }

    /// Add every entry to a Prometheus exposition, with `extra` labels
    /// (e.g. the owning engine's `model`) merged onto each sample.
    pub fn render_into(&self, ex: &mut prom::Exposition, extra: &[(&str, &str)]) {
        let entries = self.entries.lock().unwrap();
        for e in entries.iter() {
            let mut labels: Vec<(&str, &str)> = extra.to_vec();
            for (k, v) in &e.labels {
                labels.push((k.as_str(), v.as_str()));
            }
            match &e.metric {
                Metric::Counter(c) => ex.counter(&e.name, &e.help, &labels, c.get() as f64),
                Metric::Gauge(g) => ex.gauge(&e.name, &e.help, &labels, g.get()),
                Metric::Histogram(h) => ex.summary(
                    &e.name,
                    &e.help,
                    &labels,
                    &[
                        ("0.5", h.quantile(50)),
                        ("0.95", h.quantile(95)),
                        ("0.99", h.quantile(99)),
                    ],
                    h.sum(),
                    h.count() as f64,
                ),
            }
        }
    }
}

fn find<'a>(entries: &'a [Entry], name: &str, labels: &[(&str, &str)]) -> Option<&'a Entry> {
    entries.iter().find(|e| {
        e.name == name
            && e.labels.len() == labels.len()
            && e.labels.iter().zip(labels).all(|((k, v), (lk, lv))| k == lk && v == lv)
    })
}

fn entry(name: &str, labels: &[(&str, &str)], help: &str, metric: Metric) -> Entry {
    Entry {
        name: name.to_string(),
        labels: labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
        help: help.to_string(),
        metric,
    }
}

fn display_name(e: &Entry) -> String {
    if e.labels.is_empty() {
        return e.name.clone();
    }
    let inner: Vec<String> =
        e.labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    format!("{}{{{}}}", e.name, inner.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_idempotent_and_snapshots() {
        let reg = Registry::new();
        let a = reg.counter("reqs_total", "requests");
        let b = reg.counter("reqs_total", "requests");
        assert!(Arc::ptr_eq(&a, &b));
        a.add(3);
        b.inc();
        let g = reg.gauge("depth", "queue depth");
        g.set(2.5);
        let labelled =
            reg.counter_with("phase_us_total", &[("phase", "attn_core")], "per-phase time");
        labelled.add(11);
        let snap = reg.snapshot();
        assert!(snap.contains(&("reqs_total".to_string(), 4.0)));
        assert!(snap.contains(&("depth".to_string(), 2.5)));
        assert!(snap.contains(&("phase_us_total{phase=\"attn_core\"}".to_string(), 11.0)));
        // Same name, different labels: a distinct counter.
        let other =
            reg.counter_with("phase_us_total", &[("phase", "router")], "per-phase time");
        assert!(!Arc::ptr_eq(&labelled, &other));
    }

    #[test]
    fn registry_renders_prometheus() {
        let reg = Registry::new();
        reg.counter("steps_total", "steps").add(9);
        reg.histogram("lat_ms", "latency").record(4.0);
        let mut ex = prom::Exposition::new("pquant_");
        reg.render_into(&mut ex, &[("model", "serve")]);
        let text = ex.render();
        assert!(text.contains("pquant_steps_total{model=\"serve\"} 9"));
        assert!(text.contains("# TYPE pquant_lat_ms summary"));
        assert!(text.contains("pquant_lat_ms_count{model=\"serve\"} 1"));
        assert!(prom::parse_text(&text).is_ok());
    }
}
