//! Prometheus text exposition (version 0.0.4) — renderer and a minimal
//! parser.
//!
//! The renderer groups samples into metric families so `# HELP` / `# TYPE`
//! headers appear exactly once per family even when several engines
//! contribute samples (distinguished by a `model` label). Histograms are
//! exposed as `summary` families: pre-computed `quantile`-labelled values
//! plus `_sum` / `_count`, matching how the engine already reports
//! p50/p95/p99.
//!
//! The parser is deliberately small — names, label sets, values — just
//! enough for `repro obs-check` and the tests to prove the exposition
//! round-trips: scrape → parse → the same counters the JSON endpoint
//! reports.

use std::fmt::Write as _;

/// Prometheus metric family types this exposition emits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Summary,
}

impl MetricKind {
    fn name(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Summary => "summary",
        }
    }
}

/// One line-to-be: an optional family suffix (`_sum`, `_count`),
/// pre-rendered label block, and the value.
struct SampleLine {
    suffix: &'static str,
    labels: String,
    value: f64,
}

struct Family {
    name: String,
    help: String,
    kind: MetricKind,
    lines: Vec<SampleLine>,
}

/// Accumulates samples across sources, then renders one valid exposition.
pub struct Exposition {
    prefix: String,
    families: Vec<Family>,
}

impl Exposition {
    /// `prefix` is prepended to every family name (e.g. `"pquant_"`).
    pub fn new(prefix: &str) -> Exposition {
        Exposition { prefix: prefix.to_string(), families: Vec::new() }
    }

    fn family(&mut self, name: &str, help: &str, kind: MetricKind) -> &mut Family {
        let full = format!("{}{}", self.prefix, sanitize_name(name));
        if let Some(i) = self.families.iter().position(|f| f.name == full) {
            debug_assert_eq!(self.families[i].kind, kind, "family {full} re-added as {kind:?}");
            return &mut self.families[i];
        }
        self.families.push(Family { name: full, help: help.to_string(), kind, lines: Vec::new() });
        self.families.last_mut().unwrap()
    }

    pub fn counter(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        let labels = fmt_labels(labels);
        self.family(name, help, MetricKind::Counter).lines.push(SampleLine {
            suffix: "",
            labels,
            value,
        });
    }

    pub fn gauge(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        let labels = fmt_labels(labels);
        self.family(name, help, MetricKind::Gauge).lines.push(SampleLine {
            suffix: "",
            labels,
            value,
        });
    }

    /// A summary family: `quantiles` are (`quantile` label value, value)
    /// pairs, plus the `_sum` / `_count` series.
    pub fn summary(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        quantiles: &[(&str, f64)],
        sum: f64,
        count: f64,
    ) {
        let base = fmt_labels(labels);
        let fam = self.family(name, help, MetricKind::Summary);
        for &(q, v) in quantiles {
            let mut ql: Vec<(&str, &str)> = labels.to_vec();
            ql.push(("quantile", q));
            fam.lines.push(SampleLine { suffix: "", labels: fmt_labels(&ql), value: v });
        }
        fam.lines.push(SampleLine { suffix: "_sum", labels: base.clone(), value: sum });
        fam.lines.push(SampleLine { suffix: "_count", labels: base, value: count });
    }

    /// Render the full exposition: one HELP/TYPE header per family, then
    /// its samples. Ends with a trailing newline as the format requires.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.families {
            let _ = writeln!(out, "# HELP {} {}", f.name, escape_help(&f.help));
            let _ = writeln!(out, "# TYPE {} {}", f.name, f.kind.name());
            for l in &f.lines {
                let _ = writeln!(out, "{}{}{} {}", f.name, l.suffix, l.labels, fmt_value(l.value));
            }
        }
        out
    }
}

/// Replace characters outside `[a-zA-Z0-9_:]` and guard a leading digit.
pub fn sanitize_name(name: &str) -> String {
    let mut out: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect();
    if out.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        out.insert(0, '_');
    }
    out
}

fn fmt_labels(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let mut out = String::from("{");
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{}=\"{}\"", sanitize_name(k), escape_label(v));
    }
    out.push('}');
    out
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn escape_help(v: &str) -> String {
    v.replace('\\', "\\\\").replace('\n', "\\n")
}

fn fmt_value(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// One parsed sample line.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl Sample {
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

/// Minimal Prometheus text-format parser: returns every sample line,
/// skipping comments and blanks, erroring on anything structurally
/// malformed. Enough to prove the exposition round-trips.
pub fn parse_text(text: &str) -> Result<Vec<Sample>, String> {
    let mut out = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP/TYPE headers and plain comments
        }
        let (name, rest) = match line.find('{') {
            Some(brace) => {
                let name = line[..brace].trim();
                let close = line[brace..]
                    .find('}')
                    .map(|i| brace + i)
                    .ok_or_else(|| format!("line {}: unterminated label block", ln + 1))?;
                let labels = parse_labels(&line[brace + 1..close])
                    .map_err(|e| format!("line {}: {e}", ln + 1))?;
                let value_part = line[close + 1..].trim();
                (name, Some((labels, value_part)))
            }
            None => (line, None),
        };
        let (labels, value_str) = match rest {
            Some((labels, v)) => (labels, v.to_string()),
            None => {
                let mut it = name.split_whitespace();
                let n = it.next().ok_or_else(|| format!("line {}: empty sample", ln + 1))?;
                let v = it
                    .next()
                    .ok_or_else(|| format!("line {}: sample without value", ln + 1))?;
                if !n.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':') {
                    return Err(format!("line {}: bad metric name {n:?}", ln + 1));
                }
                out.push(Sample {
                    name: n.to_string(),
                    labels: Vec::new(),
                    value: v
                        .parse::<f64>()
                        .map_err(|e| format!("line {}: bad value {v:?}: {e}", ln + 1))?,
                });
                continue;
            }
        };
        // Labelled form: `name` is clean, value may carry a timestamp we
        // ignore (first whitespace-separated token is the value).
        let v = value_str
            .split_whitespace()
            .next()
            .ok_or_else(|| format!("line {}: sample without value", ln + 1))?;
        if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
        {
            return Err(format!("line {}: bad metric name {name:?}", ln + 1));
        }
        out.push(Sample {
            name: name.to_string(),
            labels,
            value: v
                .parse::<f64>()
                .map_err(|e| format!("line {}: bad value {v:?}: {e}", ln + 1))?,
        });
    }
    Ok(out)
}

fn parse_labels(body: &str) -> Result<Vec<(String, String)>, String> {
    let mut labels = Vec::new();
    let mut chars = body.chars().peekable();
    loop {
        while matches!(chars.peek(), Some(c) if c.is_whitespace() || *c == ',') {
            chars.next();
        }
        if chars.peek().is_none() {
            return Ok(labels);
        }
        let mut key = String::new();
        for c in chars.by_ref() {
            if c == '=' {
                break;
            }
            key.push(c);
        }
        if chars.next() != Some('"') {
            return Err(format!("label {key:?}: expected opening quote"));
        }
        let mut val = String::new();
        loop {
            match chars.next() {
                Some('\\') => match chars.next() {
                    Some('n') => val.push('\n'),
                    Some(c) => val.push(c),
                    None => return Err("dangling escape in label value".into()),
                },
                Some('"') => break,
                Some(c) => val.push(c),
                None => return Err(format!("label {key:?}: unterminated value")),
            }
        }
        labels.push((key.trim().to_string(), val));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_groups_families_and_parses_back() {
        let mut ex = Exposition::new("pquant_");
        ex.counter("requests_completed_total", "done", &[("model", "a")], 3.0);
        ex.counter("requests_completed_total", "done", &[("model", "b")], 5.0);
        ex.gauge("kv_in_use_blocks", "blocks", &[("model", "a")], 7.0);
        ex.summary(
            "ttft_ms",
            "time to first token",
            &[("model", "a")],
            &[("0.5", 1.25), ("0.95", 4.0), ("0.99", 9.5)],
            100.5,
            42.0,
        );
        let text = ex.render();
        // Exactly one TYPE header per family, even with two models.
        assert_eq!(text.matches("# TYPE pquant_requests_completed_total counter").count(), 1);
        assert!(text.contains("pquant_ttft_ms{model=\"a\",quantile=\"0.95\"} 4"));
        assert!(text.contains("pquant_ttft_ms_count{model=\"a\"} 42"));
        let samples = parse_text(&text).unwrap();
        let get = |name: &str, model: &str| {
            samples
                .iter()
                .find(|smp| smp.name == name && smp.label("model") == Some(model))
                .map(|smp| smp.value)
        };
        assert_eq!(get("pquant_requests_completed_total", "a"), Some(3.0));
        assert_eq!(get("pquant_requests_completed_total", "b"), Some(5.0));
        assert_eq!(get("pquant_kv_in_use_blocks", "a"), Some(7.0));
        assert_eq!(get("pquant_ttft_ms_sum", "a"), Some(100.5));
        let q99 = samples
            .iter()
            .find(|smp| smp.name == "pquant_ttft_ms" && smp.label("quantile") == Some("0.99"))
            .unwrap();
        assert_eq!(q99.value, 9.5);
    }

    #[test]
    fn parser_rejects_malformed_lines() {
        assert!(parse_text("no_value_here").is_err());
        assert!(parse_text("bad{unterminated=\"x} 1").is_err());
        assert!(parse_text("ok 1\nbad-name 2").is_err());
        assert!(parse_text("x{a=\"1\"} notanumber").is_err());
    }

    #[test]
    fn sanitize_names_and_labels() {
        assert_eq!(sanitize_name("a.b-c"), "a_b_c");
        assert_eq!(sanitize_name("7up"), "_7up");
        let mut ex = Exposition::new("");
        ex.counter("n", "h", &[("k", "quote\"back\\slash\nnl")], 1.0);
        let text = ex.render();
        let parsed = parse_text(&text).unwrap();
        assert_eq!(parsed[0].label("k"), Some("quote\"back\\slash\nnl"));
    }
}
