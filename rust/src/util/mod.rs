//! Offline substrates: the environment has no crates.io access beyond the
//! `xla` closure, so the small libraries a project like this would normally
//! pull in are implemented here (DESIGN.md §Substitutions).

pub mod align;
pub mod bench;
pub mod failpoint;
pub mod json;
pub mod prop;
pub mod rng;
pub mod threads;
