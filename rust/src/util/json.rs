//! Minimal JSON: enough to parse `artifacts/*/manifest.json` / `golden.json`
//! and to write `results/*.json`.  No serde available offline.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Numbers are kept as f64 (the manifest only holds shapes,
/// counts and floats — all exactly representable).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a usize: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Compact serialization (stable key order: BTreeMap).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 1-space indent (matches python json.dump(indent=1)).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for the report writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => {
                self.i += 1;
                let mut a = Vec::new();
                self.ws();
                if self.peek()? == b']' {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                loop {
                    self.ws();
                    a.push(self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b']' => {
                            self.i += 1;
                            return Ok(Json::Arr(a));
                        }
                        c => bail!("expected ',' or ']' at byte {}, found {:?}", self.i, c as char),
                    }
                }
            }
            b'{' => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.ws();
                if self.peek()? == b'}' {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.eat(b':')?;
                    self.ws();
                    m.insert(k, self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b'}' => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.i, c as char),
                    }
                }
            }
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs unsupported (never appear in our manifests).
                            s.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xf0 {
                            4
                        } else if c >= 0xe0 {
                            3
                        } else {
                            2
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "3", "-2.5", "\"hi\\nthere\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("d").unwrap(), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""αβ""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "αβ");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"λ/γ\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "λ/γ");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn pretty_reparses() {
        let v = Json::parse(r#"{"x": [1, {"y": 2}]}"#).unwrap();
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }
}
