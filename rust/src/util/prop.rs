//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! `check(seed, cases, gen, prop)` runs `prop` over `cases` generated
//! inputs; on failure it reports the failing case index and seed so the
//! exact input can be reproduced deterministically.

use super::rng::Rng;

/// Run a property over `cases` random inputs. Panics with the case seed on
/// the first failure (re-run with that seed to reproduce).
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed on case {case} (case_seed={case_seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially() {
        check(0, 50, |r| r.below(100), |&x| {
            if x < 100 { Ok(()) } else { Err(format!("{x} out of range")) }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        check(0, 50, |r| r.below(100), |&x| {
            if x < 5 { Ok(()) } else { Err("too big".into()) }
        });
    }
}
