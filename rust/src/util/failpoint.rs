//! Seeded fault injection for the chaos harness (ISSUE 9).
//!
//! A *failpoint* is a named site in production code that can be armed to
//! fail on purpose: `crate::failpoint!("kv.reserve")` evaluates to `true`
//! when the site is armed and its seeded draw fires, and the caller turns
//! that into the same error (or panic) a real fault would produce. The
//! design constraints, in order:
//!
//!   * **Zero cost when off.** Production never arms anything, so the
//!     disarmed path must stay off the profile *and* off the allocator —
//!     `tests/alloc_free.rs` runs with failpoints compiled in. Disarmed,
//!     [`should_fail`] is one relaxed atomic load and an immediate return;
//!     the registry lock is only ever touched while at least one site is
//!     armed.
//!   * **Deterministic.** Every site draws from its own xorshift stream
//!     seeded by (schedule seed ⊕ site-name hash), so a chaos schedule is
//!     a pure function of its seed — CI replays the same faults every run,
//!     and two sites armed with one seed stay uncorrelated.
//!   * **Scoped.** Tests arm by name ([`arm`] / [`arm_limited`]) and tear
//!     down with [`disarm_all`]; operators reproduce a schedule out of
//!     process via `PQUANT_FAILPOINTS=name=prob[:seed],…`
//!     ([`arm_from_env`], consulted once at the first engine start).
//!
//! The site catalog lives in `docs/robustness.md`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

use crate::util::rng::Rng;

/// One armed site. Sites are registered by [`arm`] and looked up by name;
/// the handful of armed sites in any schedule makes a Vec scan cheaper
/// than a map.
struct Site {
    name: String,
    /// Fire probability per evaluation; `>= 1.0` always fires.
    prob: f64,
    rng: Rng,
    fires: usize,
    /// Stop firing (stay armed, draw nothing) after this many fires.
    max_fires: Option<usize>,
}

/// Fast-path gate: false whenever no site is armed, so production code
/// pays one relaxed load per failpoint evaluation and nothing else.
static ARMED: AtomicBool = AtomicBool::new(false);

fn sites() -> MutexGuard<'static, Vec<Site>> {
    static SITES: OnceLock<Mutex<Vec<Site>>> = OnceLock::new();
    // A panic injected *through* a failpoint can poison this lock from
    // the panicking thread; the registry stays valid (arming is atomic
    // per call), so recover rather than cascade.
    SITES.get_or_init(|| Mutex::new(Vec::new())).lock().unwrap_or_else(|e| e.into_inner())
}

/// Mix the site name into the schedule seed (FNV-1a) so sites armed with
/// the same seed draw distinct streams; force nonzero for the xorshift.
fn site_seed(name: &str, seed: u64) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    (h ^ seed) | 1
}

fn arm_impl(name: &str, prob: f64, seed: u64, max_fires: Option<usize>) {
    let mut sites = sites();
    sites.retain(|s| s.name != name);
    sites.push(Site {
        name: name.to_string(),
        prob,
        rng: Rng::new(site_seed(name, seed)),
        fires: 0,
        max_fires,
    });
    ARMED.store(true, Ordering::Release);
}

/// Arm `name` to fire with probability `prob` per evaluation, drawing
/// from a stream derived from `seed`. Re-arming replaces the site.
pub fn arm(name: &str, prob: f64, seed: u64) {
    arm_impl(name, prob, seed, None);
}

/// [`arm`], but the site goes quiet after `max_fires` fires — e.g. inject
/// exactly one worker panic, then let the respawned worker run clean.
pub fn arm_limited(name: &str, prob: f64, seed: u64, max_fires: usize) {
    arm_impl(name, prob, seed, Some(max_fires));
}

/// Disarm one site (a no-op if it was never armed).
pub fn disarm(name: &str) {
    let mut sites = sites();
    sites.retain(|s| s.name != name);
    if sites.is_empty() {
        ARMED.store(false, Ordering::Release);
    }
}

/// Disarm every site — the test-teardown guarantee that no schedule
/// leaks into the next test.
pub fn disarm_all() {
    let mut sites = sites();
    sites.clear();
    ARMED.store(false, Ordering::Release);
}

/// How many times `name` has fired since it was (re-)armed.
pub fn fire_count(name: &str) -> usize {
    sites().iter().find(|s| s.name == name).map_or(0, |s| s.fires)
}

/// Evaluate a site: `true` iff it is armed, under its fire budget, and
/// this draw fires. Prefer the [`crate::failpoint!`] macro at call sites.
pub fn should_fail(name: &str) -> bool {
    if !ARMED.load(Ordering::Relaxed) {
        return false;
    }
    let mut sites = sites();
    let Some(site) = sites.iter_mut().find(|s| s.name == name) else {
        return false;
    };
    if site.max_fires.is_some_and(|m| site.fires >= m) {
        return false;
    }
    let fire = site.prob >= 1.0 || site.rng.f64() < site.prob;
    if fire {
        site.fires += 1;
    }
    fire
}

/// Arm sites from `PQUANT_FAILPOINTS=name=prob[:seed],…` exactly once
/// per process (subsequent calls are no-ops, so every engine start may
/// call it). Malformed entries are skipped — an operator typo must not
/// take down the server it was meant to probe.
pub fn arm_from_env() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let Ok(spec) = std::env::var("PQUANT_FAILPOINTS") else { return };
        for part in spec.split(',') {
            let part = part.trim();
            let Some((name, rest)) = part.split_once('=') else { continue };
            let (prob_s, seed_s) = match rest.split_once(':') {
                Some((p, s)) => (p, Some(s)),
                None => (rest, None),
            };
            let Ok(prob) = prob_s.trim().parse::<f64>() else { continue };
            let seed = seed_s.and_then(|s| s.trim().parse::<u64>().ok()).unwrap_or(0);
            arm(name.trim(), prob, seed);
        }
    });
}

/// `crate::failpoint!("site.name")` → `bool`: does the named fault fire
/// here, now? Expands to one function call whose disarmed fast path is a
/// single relaxed atomic load (no lock, no allocation).
#[macro_export]
macro_rules! failpoint {
    ($name:expr) => {
        $crate::util::failpoint::should_fail($name)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // One registry per process: every test arms under its own site names
    // and disarms them, so parallel test threads don't observe each other.

    #[test]
    fn disarmed_site_never_fires() {
        assert!(!should_fail("t.never-armed"));
        assert_eq!(fire_count("t.never-armed"), 0);
    }

    #[test]
    fn certain_site_fires_every_time_until_disarmed() {
        arm("t.always", 1.0, 7);
        assert!((0..10).all(|_| should_fail("t.always")));
        assert_eq!(fire_count("t.always"), 10);
        disarm("t.always");
        assert!(!should_fail("t.always"));
    }

    #[test]
    fn seeded_schedule_is_deterministic() {
        let draw = |seed: u64| {
            arm("t.seeded", 0.5, seed);
            let fires: Vec<bool> = (0..64).map(|_| should_fail("t.seeded")).collect();
            disarm("t.seeded");
            fires
        };
        assert_eq!(draw(3), draw(3));
        assert_ne!(draw(3), draw(4), "distinct seeds should give distinct schedules");
    }

    #[test]
    fn fire_budget_caps_a_limited_site() {
        arm_limited("t.limited", 1.0, 1, 2);
        let fired: usize = (0..8).filter(|_| should_fail("t.limited")).count();
        assert_eq!(fired, 2);
        assert_eq!(fire_count("t.limited"), 2);
        disarm("t.limited");
    }

    #[test]
    fn same_seed_distinct_sites_draw_distinct_streams() {
        arm("t.stream-a", 0.5, 11);
        arm("t.stream-b", 0.5, 11);
        let a: Vec<bool> = (0..64).map(|_| should_fail("t.stream-a")).collect();
        let b: Vec<bool> = (0..64).map(|_| should_fail("t.stream-b")).collect();
        disarm("t.stream-a");
        disarm("t.stream-b");
        assert_ne!(a, b, "site-name mixing should decorrelate streams");
    }
}
