//! 32-byte-aligned growable buffers for the GEMM accumulator planes.
//!
//! The batched kernels stream their `[n, b]` accumulators with 256-bit
//! vector moves (see [`crate::gemm::simd`]). `Vec<i32>`/`Vec<f32>` only
//! guarantee 4-byte alignment, so element 0 of a plane can sit anywhere in
//! a cache line and every vector access risks a line-split penalty.
//! [`AlignedVec`] backs the same grow-only slices with 32-byte-aligned
//! storage so the plane starts on a vector boundary. Semantics are
//! unchanged — the kernels still use unaligned loads, which are free on
//! aligned data — this is purely a layout guarantee.

use std::marker::PhantomData;

/// One vector register's worth of backing storage; the `align(32)` is the
/// whole point of the type.
#[repr(C, align(32))]
#[derive(Clone, Copy)]
struct Chunk32([u8; 32]);

const ZERO_CHUNK: Chunk32 = Chunk32([0; 32]);

/// Element types the aligned buffer may be viewed as. Safety contract:
/// any 32-byte-aligned, zero-initialized allocation is a valid `[T]`.
pub unsafe trait Pod: Copy + Default {}
unsafe impl Pod for i32 {}
unsafe impl Pod for f32 {}

/// Grow-only, zero-filled, 32-byte-aligned buffer viewed as `&mut [T]`.
pub struct AlignedVec<T: Pod> {
    buf: Vec<Chunk32>,
    len: usize,
    _elem: PhantomData<T>,
}

impl<T: Pod> Default for AlignedVec<T> {
    fn default() -> Self {
        AlignedVec { buf: Vec::new(), len: 0, _elem: PhantomData }
    }
}

impl<T: Pod> AlignedVec<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Elements currently materialized (always zero-initialized on growth).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Grow to hold at least `len` elements; returns `true` iff the
    /// backing allocation moved (the alloc-free steady-state probe).
    pub fn grow(&mut self, len: usize) -> bool {
        let chunks = (len * std::mem::size_of::<T>()).div_ceil(32);
        let grew = chunks > self.buf.capacity();
        if self.buf.len() < chunks {
            self.buf.resize(chunks, ZERO_CHUNK);
        }
        self.len = self.len.max(len);
        grew
    }

    /// View the first `len` elements mutably. `len` must have been covered
    /// by a prior [`grow`](Self::grow).
    pub fn slice_mut(&mut self, len: usize) -> &mut [T] {
        assert!(len <= self.len, "slice past grown length");
        // Safety: the allocation holds ≥ len * size_of::<T>() bytes
        // (guaranteed by grow), is 32-byte aligned (Chunk32), and every
        // byte is initialized (resize with ZERO_CHUNK); Pod permits any
        // bit pattern reinterpretation.
        unsafe { std::slice::from_raw_parts_mut(self.buf.as_mut_ptr() as *mut T, len) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_zeroed_and_aligned() {
        let mut v: AlignedVec<i32> = AlignedVec::new();
        assert!(v.grow(5), "first grow must allocate");
        let s = v.slice_mut(5);
        assert_eq!(s, &[0; 5]);
        assert_eq!(s.as_ptr() as usize % 32, 0, "element 0 must be 32B-aligned");
        s[3] = 42;
        assert!(!v.grow(4), "shrinking request must not reallocate");
        assert_eq!(v.slice_mut(5)[3], 42, "contents survive non-growing calls");
    }

    #[test]
    fn growth_reports_only_reallocations() {
        let mut v: AlignedVec<f32> = AlignedVec::new();
        v.grow(64);
        let p = v.slice_mut(1).as_ptr();
        assert!(!v.grow(64), "same size is steady-state");
        assert_eq!(v.slice_mut(1).as_ptr(), p);
        assert_eq!(v.len(), 64);
    }

    #[test]
    #[should_panic(expected = "slice past grown length")]
    fn slice_past_growth_panics() {
        let mut v: AlignedVec<i32> = AlignedVec::new();
        v.grow(3);
        let _ = v.slice_mut(4);
    }
}
