//! Scoped data-parallel helper (rayon is unavailable offline).
//!
//! `par_chunks_mut` splits a mutable slice into contiguous chunks and runs a
//! worker per chunk on std::thread::scope — the only parallel pattern the
//! GEMM hot paths need (disjoint output rows).

/// Number of worker threads to use for data-parallel loops.
pub fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Split `out` into `n_chunks` near-equal contiguous chunks and call
/// `f(chunk_index, start_offset, chunk)` for each in parallel.
pub fn par_chunks_mut<T: Send, F>(out: &mut [T], n_chunks: usize, f: F)
where
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    let n_chunks = n_chunks.clamp(1, n);
    let chunk = n.div_ceil(n_chunks);
    if n_chunks == 1 {
        f(0, 0, out);
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut start = 0usize;
        let mut idx = 0usize;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let fref = &f;
            scope.spawn(move || fref(idx, start, head));
            start += take;
            idx += 1;
            rest = tail;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_elements() {
        let mut v = vec![0usize; 103];
        par_chunks_mut(&mut v, 7, |_, start, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = start + i;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i);
        }
    }

    #[test]
    fn single_chunk() {
        let mut v = vec![0u8; 5];
        par_chunks_mut(&mut v, 1, |idx, start, chunk| {
            assert_eq!((idx, start, chunk.len()), (0, 0, 5));
            chunk.fill(1);
        });
        assert_eq!(v, vec![1; 5]);
    }

    #[test]
    fn empty_ok() {
        let mut v: Vec<u32> = vec![];
        par_chunks_mut(&mut v, 4, |_, _, _| panic!("should not run"));
    }
}
