//! Scoped data-parallel helper (rayon is unavailable offline).
//!
//! `par_chunks_mut` splits a mutable slice into contiguous chunks and runs a
//! worker per chunk on std::thread::scope — the only parallel pattern the
//! GEMM hot paths need (disjoint output rows).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Optional global cap on worker threads (0 = uncapped). Tests that count
/// heap allocations set this to 1 so the kernels take the no-spawn fast
/// path; serving deployments can use it to co-tenant workers.
static THREAD_CAP: AtomicUsize = AtomicUsize::new(0);

/// Cap [`num_threads`] at `cap` (0 restores the hardware default).
pub fn set_thread_cap(cap: usize) {
    THREAD_CAP.store(cap, Ordering::Relaxed);
}

/// Number of worker threads to use for data-parallel loops.
pub fn num_threads() -> usize {
    let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    match THREAD_CAP.load(Ordering::Relaxed) {
        0 => n,
        cap => n.min(cap),
    }
}

/// Split `out` into `n_chunks` near-equal contiguous chunks and call
/// `f(chunk_index, start_offset, chunk)` for each in parallel.
pub fn par_chunks_mut<T: Send, F>(out: &mut [T], n_chunks: usize, f: F)
where
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    let n_chunks = n_chunks.clamp(1, n);
    let chunk = n.div_ceil(n_chunks);
    if n_chunks == 1 {
        f(0, 0, out);
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut start = 0usize;
        let mut idx = 0usize;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let fref = &f;
            scope.spawn(move || fref(idx, start, head));
            start += take;
            idx += 1;
            rest = tail;
        }
    });
}

/// [`par_chunks_mut`], but every chunk boundary falls on a multiple of
/// `granule` — the batched GEMM kernels use `granule = b` so one output
/// column's `b` accumulators never straddle two threads. `out.len()` must
/// be a multiple of `granule`.
pub fn par_chunks_mut_granular<T: Send, F>(out: &mut [T], n_chunks: usize, granule: usize, f: F)
where
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    let granule = granule.max(1);
    // Release-mode assert, not debug_assert: a non-multiple length would
    // split silently wrong (chunks straddling a granule, callers computing
    // `start / granule` off by one) instead of panicking where the bug is.
    assert_eq!(n % granule, 0, "length must be a granule multiple");
    let units = n / granule;
    let n_chunks = n_chunks.clamp(1, units);
    if n_chunks == 1 {
        f(0, 0, out);
        return;
    }
    let per = units.div_ceil(n_chunks) * granule;
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut start = 0usize;
        let mut idx = 0usize;
        while !rest.is_empty() {
            let take = per.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let fref = &f;
            scope.spawn(move || fref(idx, start, head));
            start += take;
            idx += 1;
            rest = tail;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_elements() {
        let mut v = vec![0usize; 103];
        par_chunks_mut(&mut v, 7, |_, start, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = start + i;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i);
        }
    }

    #[test]
    fn single_chunk() {
        let mut v = vec![0u8; 5];
        par_chunks_mut(&mut v, 1, |idx, start, chunk| {
            assert_eq!((idx, start, chunk.len()), (0, 0, 5));
            chunk.fill(1);
        });
        assert_eq!(v, vec![1; 5]);
    }

    #[test]
    fn empty_ok() {
        let mut v: Vec<u32> = vec![];
        par_chunks_mut(&mut v, 4, |_, _, _| panic!("should not run"));
    }

    #[test]
    fn granular_boundaries_respect_granule() {
        // 7 granules of 3: any chunking must split on multiples of 3.
        let mut v = vec![0usize; 21];
        par_chunks_mut_granular(&mut v, 4, 3, |_, start, chunk| {
            assert_eq!(start % 3, 0);
            assert_eq!(chunk.len() % 3, 0);
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = start + i;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i);
        }
    }

    #[test]
    #[should_panic(expected = "granule multiple")]
    fn granular_rejects_non_multiple_length_in_release_too() {
        // 10 is not a multiple of 4: must panic (plain assert!, not
        // debug_assert!) rather than split into straddling chunks.
        let mut v = vec![0u8; 10];
        par_chunks_mut_granular(&mut v, 2, 4, |_, _, _| {});
    }

    #[test]
    fn thread_cap_limits_and_restores() {
        // The cap is process-global and sibling tests in this binary run
        // concurrently, so only use caps at or above any real core count —
        // tests relying on cap = 1 live alone in tests/alloc_free.rs.
        set_thread_cap(usize::MAX);
        assert!(num_threads() >= 1, "huge cap must not zero the count");
        set_thread_cap(1 << 20);
        assert!(num_threads() <= 1 << 20);
        set_thread_cap(0);
        assert!(num_threads() >= 1, "cap 0 restores the hardware default");
    }
}
