//! Tiny criterion-style bench harness (criterion is unavailable offline).
//!
//! Provides warmup, multiple timed samples, median/mean/stddev reporting and
//! JSON output under `results/bench/`.  Used by every `[[bench]]` target
//! (`harness = false`) and by the experiment harnesses that time kernels.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
    pub iters_per_sample: u64,
}

impl Stats {
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[s.len() / 2]
    }

    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / self.samples.len() as f64)
            .sqrt()
    }

    pub fn report(&self) -> String {
        format!(
            "{:48} {:>12} median {:>12} mean ±{:>10}",
            self.name,
            fmt_duration(self.median()),
            fmt_duration(self.mean()),
            fmt_duration(self.stddev()),
        )
    }
}

pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Benchmark runner: calibrates iteration count to ~`target_sample` per
/// sample, takes `n_samples` samples after one warmup sample.
pub struct Bencher {
    pub n_samples: usize,
    pub target_sample: Duration,
    pub results: Vec<Stats>,
    /// Scalar capacity/throughput metrics recorded alongside the timings
    /// (e.g. sequences-per-MB); serialized into the same JSON file.
    pub metrics: Vec<(String, f64)>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            n_samples: 15,
            target_sample: Duration::from_millis(120),
            results: Vec::new(),
            metrics: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            n_samples: 7,
            target_sample: Duration::from_millis(40),
            results: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// Record a scalar metric (not a timing) to report and serialize with
    /// the run — capacity counts, ratios, bytes.
    pub fn metric(&mut self, name: &str, value: f64) {
        println!("{name:48} {value:>12.3}");
        self.metrics.push((name.to_string(), value));
    }

    /// Time `f`, which should perform one unit of work and return a value
    /// that is black-boxed to stop the optimizer deleting the work.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Stats {
        // Calibrate: how many iterations fit the target sample time?
        let mut iters: u64 = 1;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            let dt = t0.elapsed();
            if dt >= self.target_sample / 4 || iters >= 1 << 24 {
                let scale =
                    (self.target_sample.as_secs_f64() / dt.as_secs_f64().max(1e-9)).max(1.0);
                iters = ((iters as f64 * scale) as u64).max(1);
                break;
            }
            iters *= 8;
        }
        let mut samples = Vec::with_capacity(self.n_samples);
        for _ in 0..self.n_samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        let stats = Stats { name: name.to_string(), samples, iters_per_sample: iters };
        println!("{}", stats.report());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Write all collected stats as JSON under results/bench/<file>.json.
    pub fn write_json(&self, file: &str) {
        use super::json::{arr, num, obj, s, Json};
        std::fs::create_dir_all("results/bench").ok();
        let mut entries: Vec<Json> = self
            .results
            .iter()
            .map(|st| {
                obj(vec![
                    ("name", s(&st.name)),
                    ("median_s", num(st.median())),
                    ("mean_s", num(st.mean())),
                    ("stddev_s", num(st.stddev())),
                    ("iters_per_sample", num(st.iters_per_sample as f64)),
                ])
            })
            .collect();
        entries.extend(
            self.metrics.iter().map(|(name, v)| obj(vec![("name", s(name)), ("value", num(*v))])),
        );
        let path = format!("results/bench/{file}.json");
        std::fs::write(&path, arr(entries).to_string_pretty()).ok();
        println!("[bench] wrote {path}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = Stats {
            name: "x".into(),
            samples: vec![1.0, 2.0, 3.0],
            iters_per_sample: 1,
        };
        assert_eq!(s.median(), 2.0);
        assert!((s.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn formats() {
        assert!(fmt_duration(1.5e-9).contains("ns"));
        assert!(fmt_duration(1.5e-5).contains("µs"));
        assert!(fmt_duration(1.5e-2).contains("ms"));
        assert!(fmt_duration(2.0).ends_with("s"));
    }
}
