//! Seeded xorshift64* RNG — deterministic across runs, no external crates.
//! Used by the corpus generator, property tests and workload generators.

/// xorshift64* with the standard multiplier; passes BigCrush smallset for
/// our purposes (synthetic data + test-case generation, not crypto).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Rng { state: seed.wrapping_mul(0x9E3779B97F4A7C15).max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(42);
        let xs: Vec<f32> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 2];
        for _ in 0..2000 {
            counts[r.weighted(&[1.0, 9.0])] += 1;
        }
        assert!(counts[1] > counts[0] * 4);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
