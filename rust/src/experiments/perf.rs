//! Analytic + engine-level experiments: Table 1, Table 6, Fig 6, Fig 8,
//! Fig 9, and the serving throughput study (§4.5 / Appendix A).

use anyhow::Result;

use crate::config::{paper_configs, paper_pquant_n, Variant};
use crate::coordinator::TwoPhaseSchedule;
use crate::infer::{KvCache, PackedBlock, PackedModel};
use crate::memory::{footprint, gib};
use crate::report::{save, Table};
use crate::serve::{load_test, ServeOptions};
use crate::util::json::{arr, num, obj, s, Json};

/// Table 1: pQuant configurations (paper scale + our scaled mirror).
pub fn tab1() -> Result<()> {
    let mut t = Table::new(
        "Table 1 — pQuant configurations (paper scale)",
        &["Parameters", "D_Model", "D_FF", "r", "1-bit %", "8-bit %", "avg bits"],
    );
    for c in paper_configs().into_iter().filter(|c| c.variant == Variant::PQuant && !c.name.contains("7B")) {
        let d = c.d_model as f64;
        let one = 4.0 * d * d + 2.0 * d * c.d_ff_1bit() as f64;
        let eight = c.n_experts as f64 * 2.0 * d * c.r as f64;
        let total = one + eight;
        t.row(vec![
            c.name.replace("paper-", "").replace("-pquant", ""),
            c.d_model.to_string(),
            format!("{}({}-{})", c.d_ff - c.r, c.d_ff, c.r),
            c.r.to_string(),
            format!("{:.0}%", 100.0 * one / total),
            format!("{:.0}%", 100.0 * eight / total),
            format!("{:.2}", c.avg_bits_per_weight()),
        ]);
    }
    t.print();

    let mut t2 = Table::new(
        "Table 1b — scaled testbed mirror (ratios preserved)",
        &["config", "D_Model", "D_FF", "r", "params", "avg bits"],
    );
    for name in ["nano-pquant", "micro-pquant", "tiny-pquant", "small-pquant"] {
        if let Ok(art) = crate::runtime::load_artifact(name) {
            let c = &art.manifest.config;
            t2.row(vec![
                c.name.clone(),
                c.d_model.to_string(),
                c.d_ff.to_string(),
                c.r.to_string(),
                format!("{:.2}M", c.param_count() as f64 / 1e6),
                format!("{:.2}", c.avg_bits_per_weight()),
            ]);
        }
    }
    t2.print();
    save("tab1", &obj(vec![("note", s("see tab1.md"))]), &[&t, &t2]);
    Ok(())
}

/// Table 6: total parameters of pQuant vs N (paper scale, analytic).
pub fn tab6() -> Result<()> {
    let mut t = Table::new(
        "Table 6 — total parameters vs number of 8-bit branches N",
        &["Base", "N=1", "N=2", "N=4", "N=8"],
    );
    let mut payload = Vec::new();
    for base_name in ["paper-300M-pquant", "paper-700M-pquant", "paper-1.3B-pquant"] {
        let base = paper_configs().into_iter().find(|c| c.name == base_name).unwrap();
        let counts: Vec<f64> = [1, 2, 4, 8]
            .iter()
            .map(|&n| paper_pquant_n(&base, n).param_count() as f64 / 1e9)
            .collect();
        t.row(vec![
            base_name.replace("paper-", "").replace("-pquant", ""),
            format!("{:.2}B", counts[0]),
            format!("{:.2}B", counts[1]),
            format!("{:.2}B", counts[2]),
            format!("{:.2}B", counts[3]),
        ]);
        payload.push(obj(vec![
            ("base", s(base_name)),
            ("params_b", arr(counts.into_iter().map(num))),
        ]));
    }
    t.print();
    save("tab6", &Json::Arr(payload), &[&t]);
    Ok(())
}

/// Fig 6: weight bytes transferred per forward pass vs model size.
pub fn fig6() -> Result<()> {
    let mut t = Table::new(
        "Figure 6 — weight traffic per forward pass (GiB, paper scale)",
        &["Size", "LLaMA-2 fp16", "BitNet1.58", "pQuant", "pQuant vs fp16", "pQuant vs 1.58"],
    );
    let mut payload = Vec::new();
    for size in ["300M", "700M", "1.3B"] {
        let by = |v: &str| {
            let name = format!("paper-{size}-{v}");
            footprint(&paper_configs().into_iter().find(|c| c.name == name).unwrap())
        };
        let fp = by("fp16").traffic();
        let b158 = by("bitnet158").traffic();
        let pq = by("pquant").traffic();
        t.row(vec![
            size.to_string(),
            format!("{:.3}", gib(fp)),
            format!("{:.3}", gib(b158)),
            format!("{:.3}", gib(pq)),
            format!("-{:.0}%", 100.0 * (1.0 - pq as f64 / fp as f64)),
            format!("-{:.0}%", 100.0 * (1.0 - pq as f64 / b158 as f64)),
        ]);
        payload.push(obj(vec![
            ("size", s(size)),
            ("fp16_bytes", num(fp as f64)),
            ("bitnet158_bytes", num(b158 as f64)),
            ("pquant_bytes", num(pq as f64)),
        ]));
    }
    t.print();
    println!("paper: pQuant −92% vs LLaMA-2, −31% vs BitNet1.58 (block weights only;");
    println!("our model includes fp16 embeddings, which dilute the small sizes)");
    save("fig6", &Json::Arr(payload), &[&t]);
    Ok(())
}

/// Fig 9: the two-phase LR/WD schedule trace.
pub fn fig9() -> Result<()> {
    let sched = TwoPhaseSchedule::paper(1000, 1.5e-3);
    let trace = sched.trace(40);
    let mut t = Table::new(
        "Figure 9 — two-phase schedule (1000 steps, peak 1.5e-3)",
        &["step", "lr", "wd"],
    );
    for (step, lr, wd) in &trace {
        t.row(vec![step.to_string(), format!("{lr:.2e}"), format!("{wd}")]);
    }
    t.print();
    let lrs: Vec<f32> = trace.iter().map(|&(_, lr, _)| lr).collect();
    println!("{}", crate::report::ascii_chart(&[("lr", &lrs)], 60, 12));
    save(
        "fig9",
        &arr(trace.iter().map(|&(st, lr, wd)| {
            obj(vec![("step", num(st as f64)), ("lr", num(lr as f64)), ("wd", num(wd as f64))])
        })),
        &[&t],
    );
    Ok(())
}

/// Fig 8: per-component decode time in one transformer block at the
/// paper's 7B geometry, for FP16 / BitNet1.58 / pQuant engines.
pub fn fig8() -> Result<()> {
    // 7B block geometry (Table 4 / LLaMA-2-7B): d=4096, ff=11008, r=512.
    let (d, heads, ff, r) = (4096usize, 32usize, 11008usize, 512usize);
    let seq = 256usize; // paper: "input sequence length of 256"
    let decode_tokens = 8usize;

    let mut t = Table::new(
        "Figure 8 — per-component time in one 7B block (decode, ms/token)",
        &["engine", "attn proj", "attn core", "ffn 1-bit/dense", "ffn 8-bit", "router", "norm+quant", "total"],
    );
    let mut payload = Vec::new();
    let mut totals = std::collections::HashMap::new();
    for (label, variant) in [
        ("LLaMA-2 fp16", Variant::Fp16),
        ("BitNet1.58", Variant::BitNet158),
        ("pQuant", Variant::PQuant),
    ] {
        let mut block = PackedBlock::random(variant, d, heads, ff, r, 1, 99);
        block.timing.mode = crate::infer::TimingMode::Accumulate;
        let mut cache = KvCache::new(seq + decode_tokens + 1, d);
        let mut rope = crate::infer::RopeTable::default();
        rope.ensure(d / heads / 2, seq + decode_tokens + 1);
        let x = crate::util::rng::Rng::new(1).normal_vec(d);
        // fill the cache to seq entries (prefill context)
        for pos in 0..seq {
            block.forward(&x, pos, &mut cache, &rope);
        }
        block.timing.reset();
        for pos in seq..seq + decode_tokens {
            block.forward(&x, pos, &mut cache, &rope);
        }
        let tm = block.timing.clone();
        let per = |dur: std::time::Duration| dur.as_secs_f64() * 1e3 / decode_tokens as f64;
        let total = per(tm.total());
        totals.insert(label, total);
        t.row(vec![
            label.to_string(),
            format!("{:.2}", per(tm.attn_proj)),
            format!("{:.2}", per(tm.attn_core)),
            format!("{:.2}", per(tm.ffn_1bit)),
            format!("{:.2}", per(tm.ffn_8bit)),
            format!("{:.3}", per(tm.router)),
            format!("{:.2}", per(tm.norm_quant)),
            format!("{:.2}", total),
        ]);
        payload.push(obj(vec![
            ("engine", s(label)),
            ("attn_proj_ms", num(per(tm.attn_proj))),
            ("attn_core_ms", num(per(tm.attn_core))),
            ("ffn_1bit_ms", num(per(tm.ffn_1bit))),
            ("ffn_8bit_ms", num(per(tm.ffn_8bit))),
            ("router_ms", num(per(tm.router))),
            ("norm_quant_ms", num(per(tm.norm_quant))),
            ("total_ms", num(total)),
        ]));
    }
    t.print();
    let vs_fp = 100.0 * (1.0 - totals["pQuant"] / totals["LLaMA-2 fp16"]);
    let vs_158 = 100.0 * (1.0 - totals["pQuant"] / totals["BitNet1.58"]);
    println!("pQuant vs fp16: -{vs_fp:.0}% (paper: -82%) | vs BitNet1.58: -{vs_158:.0}% (paper: -38%)");
    save("fig8", &Json::Arr(payload), &[&t]);
    Ok(())
}

/// §4.5 / Table 3 speedup: serving throughput of the packed engines.
pub fn serving() -> Result<()> {
    // Memory-bound geometry (the edge regime the paper targets): weight
    // working set ≫ L2, so packed traffic — not FLOPs — sets throughput.
    // (Perf pass note: the first version used d=256 where fp16 weights fit
    // in cache and the LUT engine lost; see EXPERIMENTS.md §Perf.)
    let mk = |variant: Variant, n_experts: usize| {
        PackedModel::random(
            &crate::config::ModelConfig {
                name: format!("serve-{}", variant.name()),
                variant,
                vocab: 512,
                d_model: 768,
                n_layers: 4,
                n_heads: 12,
                d_ff: 2048,
                r: if variant == Variant::PQuant { 96 } else { 0 },
                n_experts: if variant == Variant::PQuant { n_experts } else { 1 },
                seq_len: 128,
                alpha_init: 2.0,
                beta_init: 0.2,
            },
            7,
        )
    };
    let n_req = 8;
    let (prompt, gen) = (8, 16);
    let opts = ServeOptions { max_batch: 4, workers: 1 };

    let mut t = Table::new(
        "Serving throughput (memory-bound geometry d=768, 8 reqs × 16 new tokens)",
        &["engine", "tokens/s", "mean latency ms", "p95 ms", "speedup vs fp16"],
    );
    let mut payload = Vec::new();
    let mut fp16_tps = 0.0;
    for (label, variant, n_exp) in [
        ("LLaMA-2 fp16", Variant::Fp16, 1),
        ("BitNet1.58", Variant::BitNet158, 1),
        ("pQuant N=1", Variant::PQuant, 1),
        ("pQuant N=8", Variant::PQuant, 8),
    ] {
        let (responses, _, tps) = load_test(vec![mk(variant, n_exp)], n_req, prompt, gen, &opts);
        let mut lats: Vec<f64> = responses
            .iter()
            .map(|r| (r.queue_wait + r.service_time).as_secs_f64() * 1e3)
            .collect();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = lats.iter().sum::<f64>() / lats.len() as f64;
        let p95 = lats[(lats.len() * 95 / 100).min(lats.len() - 1)];
        if variant == Variant::Fp16 {
            fp16_tps = tps;
        }
        t.row(vec![
            label.to_string(),
            format!("{tps:.1}"),
            format!("{mean:.1}"),
            format!("{p95:.1}"),
            format!("{:.2}x", tps / fp16_tps),
        ]);
        payload.push(obj(vec![
            ("engine", s(label)),
            ("tokens_per_s", num(tps)),
            ("mean_latency_ms", num(mean)),
            ("p95_latency_ms", num(p95)),
        ]));
    }
    t.print();
    println!("paper claims: >2x tokens/s vs FP16; +18.2% throughput vs 2-bit when scaled");
    save("serving", &Json::Arr(payload), &[&t]);
    Ok(())
}
