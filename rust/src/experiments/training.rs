//! Training-based experiments: the main results table and its derivatives.

use anyhow::Result;

use crate::report::{ascii_chart, save, Table};
use crate::util::json::{arr, num, obj, s, Json};

use super::{default_steps, Lab, RunResult};

fn size_of(config: &str) -> &str {
    config.split('-').next().unwrap()
}

fn steps_for(config: &str, over: Option<u64>) -> u64 {
    over.unwrap_or_else(|| default_steps(size_of(config)))
}

fn result_row(t: &mut Table, label: &str, bits: f64, r: &RunResult) {
    let mut cells = vec![label.to_string(), format!("{bits:.2}")];
    for (_, acc) in &r.task_acc {
        cells.push(format!("{:.1}", acc * 100.0));
    }
    cells.push(format!("{:.1}", r.avg_acc()));
    cells.push(format!("{:.2}", r.ppl));
    cells.push(format!("{:.3}", r.tail_loss));
    t.row(cells);
}

fn results_table(title: &str) -> Table {
    Table::new(
        title,
        &["model", "bits", "ARC-E", "ARC-C", "HS", "BQ", "OQ", "PQ", "WGe", "Avg", "PPL", "loss"],
    )
}

fn run_json(r: &RunResult) -> Json {
    obj(vec![
        ("config", s(&r.config)),
        ("ppl", num(r.ppl)),
        ("avg_acc", num(r.avg_acc())),
        ("tail_loss", num(r.tail_loss as f64)),
        (
            "task_acc",
            arr(r.task_acc.iter().map(|(n, a)| arr([s(n), num(*a)]))),
        ),
    ])
}

/// Table 2: main results at matched size and data budget.
pub fn tab2(lab: &mut Lab, steps: Option<u64>) -> Result<()> {
    let mut t = results_table(
        "Table 2 — main results (micro scale, matched data budget; + tiny pQuant)",
    );
    let mut payload = Vec::new();
    for config in [
        "micro-fp16",
        "micro-bitnet",
        "micro-bitnet158",
        "micro-pquant",
        "tiny-pquant",
    ] {
        let r = lab.run(config, steps_for(config, steps), "", |_| {})?;
        let bits = lab.artifact(config)?.manifest.avg_bits_per_weight;
        result_row(&mut t, config, bits, &r);
        payload.push(run_json(&r));
    }
    t.print();
    println!("paper shape: pQuant > BitNet at matched size; pQuant(1.3x bits) ~ BitNet1.58(2 bits);");
    println!("larger pQuant beats smaller FP16 baselines on Avg.");
    save("tab2", &Json::Arr(payload), &[&t]);
    Ok(())
}

/// Fig 1: perplexity vs bit-width overview (derived from tab2 runs).
pub fn fig1(lab: &mut Lab, steps: Option<u64>) -> Result<()> {
    let mut t = Table::new(
        "Figure 1 — perplexity vs bits per weight (micro scale)",
        &["model", "bits", "PPL"],
    );
    let mut payload = Vec::new();
    for config in ["micro-fp16", "micro-bitnet", "micro-bitnet158", "micro-pquant", "micro-pquant-n8"] {
        let r = lab.run(config, steps_for(config, steps), "", |_| {})?;
        let bits = lab.artifact(config)?.manifest.avg_bits_per_weight;
        t.row(vec![config.to_string(), format!("{bits:.2}"), format!("{:.2}", r.ppl)]);
        payload.push(obj(vec![
            ("config", s(config)),
            ("bits", num(bits)),
            ("ppl", num(r.ppl)),
        ]));
    }
    t.print();
    println!("paper shape: pQuant sits on the Pareto frontier — below BitNet at ~1.3 bits,");
    println!("approaching the 2-bit and fp16 points.");
    save("fig1", &Json::Arr(payload), &[&t]);
    Ok(())
}

/// Fig 4: final training loss vs parameter count per variant.
pub fn fig4(lab: &mut Lab, steps: Option<u64>) -> Result<()> {
    let sizes = ["nano", "micro", "tiny"];
    let variants: [(&str, fn(&str) -> String); 4] = [
        ("fp16", |s| format!("{s}-fp16")),
        ("bitnet", |s| format!("{s}-bitnet")),
        ("bitnet158", |s| format!("{s}-bitnet158")),
        // paper plots pQuant N=8; nano only has N∈{1,4} artifacts → N=4
        ("pquant-nmax", |s| {
            if s == "nano" { format!("{s}-pquant-n4") } else { format!("{s}-pquant-n8") }
        }),
    ];
    let mut t = Table::new(
        "Figure 4 — final training loss vs parameters",
        &["size", "params(M)", "fp16", "bitnet", "bitnet158", "pquant(N)"],
    );
    let mut payload = Vec::new();
    for size in sizes {
        let mut cells = vec![size.to_string(), String::new()];
        let mut entry = vec![("size", s(size))];
        let mut jvals = Vec::new();
        for (vname, f) in &variants {
            let config = f(size);
            // nano-pquant-n8/micro... may be missing; skip gracefully
            let r = match lab.artifact(&config) {
                Ok(art) => {
                    if cells[1].is_empty() {
                        cells[1] = format!("{:.1}", art.manifest.param_count as f64 / 1e6);
                    }
                    lab.run(&config, steps_for(&config, steps), "", |_| {})?
                }
                Err(_) => {
                    cells.push("-".into());
                    continue;
                }
            };
            cells.push(format!("{:.3}", r.tail_loss));
            jvals.push(obj(vec![("variant", s(vname)), ("loss", num(r.tail_loss as f64))]));
        }
        entry.push(("losses", Json::Arr(jvals)));
        payload.push(obj(entry));
        t.row(cells);
    }
    t.print();
    println!("paper shape: the pquant(N) column tracks fp16 losses much closer than");
    println!("bitnet/bitnet158 as size grows.");
    save("fig4", &Json::Arr(payload), &[&t]);
    Ok(())
}

/// Fig 5b: feature-scaling ablation — different (α, β) inits.
pub fn fig5b(lab: &mut Lab, steps: Option<u64>) -> Result<()> {
    let config = "micro-pquant";
    let n = steps_for(config, steps);
    let settings: [(&str, Option<(f32, f32)>); 4] = [
        ("alpha2.0-beta0.2 (converged init)", Some((2.0, 0.2))),
        ("alpha1.0-beta0.5 (paper init)", Some((1.0, 0.5))),
        ("alpha1.0-beta1.0 (no prioritization)", Some((1.0, 1.0))),
        ("alpha0.2-beta2.0 (inverted)", Some((0.2, 2.0))),
    ];
    let mut t = Table::new(
        "Figure 5b — feature scaling ablation (micro-pquant)",
        &["init", "final loss", "tail loss", "PPL"],
    );
    let mut series: Vec<(String, Vec<f32>)> = Vec::new();
    let mut payload = Vec::new();
    for (label, fs) in settings {
        let tag = match fs {
            Some((a, b)) => format!("fs{a}-{b}"),
            None => "fsdefault".into(),
        };
        let r = lab.run(config, n, &tag, |o| {
            o.feature_scaling_override = fs;
        })?;
        t.row(vec![
            label.to_string(),
            format!("{:.3}", r.final_loss),
            format!("{:.3}", r.tail_loss),
            format!("{:.2}", r.ppl),
        ]);
        payload.push(obj(vec![
            ("init", s(label)),
            ("tail_loss", num(r.tail_loss as f64)),
            ("ppl", num(r.ppl)),
            ("losses", arr(r.losses.iter().map(|&l| num(l as f64)))),
        ]));
        series.push((label.to_string(), r.losses));
    }
    t.print();
    let refs: Vec<(&str, &[f32])> =
        series.iter().map(|(n, l)| (n.as_str(), l.as_slice())).collect();
    println!("{}", ascii_chart(&refs, 64, 14));
    println!("paper shape: α≫β init reaches lower loss; configurations do NOT converge");
    println!("to the same final loss (persistent structural effect).");
    save("fig5b", &Json::Arr(payload), &[&t]);
    Ok(())
}

/// Fig 10: training stability — spike injection + rollback vs clean run.
pub fn fig10(lab: &mut Lab, steps: Option<u64>) -> Result<()> {
    let n = steps.unwrap_or(160);
    // BitNet at an aggressive LR with an injected divergence (the nano
    // scale is too small to reproduce organic 1-bit blowups reliably —
    // documented substitution, DESIGN.md §3).
    let unstable = lab.run("micro-bitnet", n, "unstable", |o| {
        o.peak_lr = 8e-3;
        o.inject_spike_at = Some(n / 2);
        o.snapshot_every = 10;
    })?;
    let stable = lab.run("micro-pquant", n, "stable-hi-lr", |o| {
        o.peak_lr = 8e-3;
        o.snapshot_every = 10;
    })?;
    let mut t = Table::new(
        "Figure 10 — training stability at aggressive LR (8e-3)",
        &["run", "rollbacks", "final loss", "finished"],
    );
    t.row(vec![
        "bitnet + injected spike".into(),
        unstable.rollbacks.to_string(),
        format!("{:.3}", unstable.final_loss),
        "yes (recovered via checkpoint reload)".into(),
    ]);
    t.row(vec![
        "pquant (same LR)".into(),
        stable.rollbacks.to_string(),
        format!("{:.3}", stable.final_loss),
        "yes".into(),
    ]);
    t.print();
    println!(
        "{}",
        ascii_chart(
            &[("bitnet-unstable", &unstable.losses), ("pquant", &stable.losses)],
            64,
            14
        )
    );
    save(
        "fig10",
        &obj(vec![
            ("bitnet_rollbacks", num(unstable.rollbacks as f64)),
            ("pquant_rollbacks", num(stable.rollbacks as f64)),
            ("bitnet_losses", arr(unstable.losses.iter().map(|&l| num(l as f64)))),
            ("pquant_losses", arr(stable.losses.iter().map(|&l| num(l as f64)))),
        ]),
        &[&t],
    );
    Ok(())
}

/// Table 3: matched-parameter comparison (total vs activated).
pub fn tab3(lab: &mut Lab, steps: Option<u64>) -> Result<()> {
    let mut t = Table::new(
        "Table 3 — matched-parameter comparison (micro scale)",
        &["model", "total", "activated", "PPL", "storage MiB (packed)"],
    );
    let mut payload = Vec::new();
    for config in ["micro-pquant-n4", "micro-bitnet158", "micro-pquant-n8", "micro-fp16"] {
        let r = lab.run(config, steps_for(config, steps), "", |_| {})?;
        let art = lab.artifact(config)?;
        let (art2, state) = lab.load_run_state(&r)?;
        let model = crate::infer::PackedModel::from_state(&art2, &state)?;
        let mib = model.storage_bytes() as f64 / (1024.0 * 1024.0);
        t.row(vec![
            config.to_string(),
            format!("{:.2}M", art.manifest.param_count as f64 / 1e6),
            format!("{:.2}M", art.manifest.activated_param_count as f64 / 1e6),
            format!("{:.2}", r.ppl),
            format!("{mib:.2}"),
        ]);
        payload.push(obj(vec![
            ("config", s(config)),
            ("total", num(art.manifest.param_count as f64)),
            ("activated", num(art.manifest.activated_param_count as f64)),
            ("ppl", num(r.ppl)),
            ("storage_bytes", num(model.storage_bytes() as f64)),
        ]));
    }
    t.print();
    println!("paper shape: pQuant(N=4, more total) beats BitNet1.58 PPL; pQuant(N=8,");
    println!("fewer activated) matches it; fp16 costs ~3-4x the storage.");
    save("tab3", &Json::Arr(payload), &[&t]);
    Ok(())
}

/// Table 5: scaled pQuant (N=8) vs baselines across sizes.
pub fn tab5(lab: &mut Lab, steps: Option<u64>) -> Result<()> {
    let mut t = results_table("Table 5 — pQuant N=8 vs baselines across sizes");
    let mut payload = Vec::new();
    for config in [
        "micro-fp16",
        "micro-bitnet158",
        "micro-pquant-n8",
        "tiny-fp16",
        "tiny-bitnet158",
        "tiny-pquant-n8",
    ] {
        let r = lab.run(config, steps_for(config, steps), "", |_| {})?;
        let bits = lab.artifact(config)?.manifest.avg_bits_per_weight;
        result_row(&mut t, config, bits, &r);
        payload.push(run_json(&r));
    }
    t.print();
    println!("paper shape: with N=8 pQuant surpasses the 2-bit baseline and approaches fp16.");
    save("tab5", &Json::Arr(payload), &[&t]);
    Ok(())
}

/// Table 7: converged feature-scaling values per layer.
pub fn tab7(lab: &mut Lab, steps: Option<u64>) -> Result<()> {
    let config = "tiny-pquant";
    let r = lab.run(config, steps_for(config, steps), "", |_| {})?;
    let mut t = Table::new(
        "Table 7 — feature scaling after training (tiny-pquant)",
        &["layer", "alpha (8-bit)", "beta (1-bit)", "alpha/beta"],
    );
    let mut payload = Vec::new();
    for (l, (a, b)) in r.feature_scaling.iter().enumerate() {
        t.row(vec![
            (l + 1).to_string(),
            format!("{a:.3}"),
            format!("{b:.3}"),
            format!("{:.1}", a / b.max(1e-6)),
        ]);
        payload.push(obj(vec![
            ("layer", num((l + 1) as f64)),
            ("alpha", num(*a as f64)),
            ("beta", num(*b as f64)),
        ]));
    }
    t.print();
    println!("paper shape: α (8-bit) ≫ β (1-bit) at every layer — the model preserves");
    println!("the high-precision pathway's signal.");
    save("tab7", &Json::Arr(payload), &[&t]);
    Ok(())
}

/// Table 8: training-time overhead vs N (measured steps/s, extrapolated).
pub fn tab8(lab: &mut Lab, steps: Option<u64>) -> Result<()> {
    let n = steps.unwrap_or(60); // timing only — short runs, separate tag
    let mut t = Table::new(
        "Table 8 — training wall time vs number of experts N (micro)",
        &["config", "steps/s", "tokens/s", "relative cost"],
    );
    let mut payload = Vec::new();
    let mut base_tps = 0.0;
    for config in ["micro-pquant", "micro-pquant-n2", "micro-pquant-n4", "micro-pquant-n8"] {
        let r = lab.run(config, n, "timing", |o| {
            o.eval_every = 0;
            o.log_every = 0;
        })?;
        if base_tps == 0.0 {
            base_tps = r.tokens_per_second;
        }
        t.row(vec![
            config.to_string(),
            format!("{:.2}", r.steps as f64 / r.wall_seconds),
            format!("{:.0}", r.tokens_per_second),
            format!("{:.2}x", base_tps / r.tokens_per_second),
        ]);
        payload.push(obj(vec![
            ("config", s(config)),
            ("tokens_per_second", num(r.tokens_per_second)),
            ("wall_seconds", num(r.wall_seconds)),
        ]));
    }
    t.print();
    println!("paper shape: N=8 costs ~1.2-1.3x the N=1 training time (Table 8: 8.5→11.1 days).");
    save("tab8", &Json::Arr(payload), &[&t]);
    Ok(())
}

/// Appendix E: batch-size ablation (1M vs 4M tokens → scaled analog).
pub fn ablate_batch(lab: &mut Lab, steps: Option<u64>) -> Result<()> {
    // Matched token budget: batch 2/8/32 × steps so tokens are constant.
    let base_steps = steps.unwrap_or(320);
    let entries = [("train_step_b2", 2usize, base_steps * 4), ("train_step", 8, base_steps), ("train_step_b32", 32, base_steps / 4)];
    let art = lab.artifact("micro-pquant")?;
    let vocab = art.manifest.config.vocab;
    lab.dataset(vocab)?;
    let mut t = Table::new(
        "Appendix E — batch-size ablation at matched token budget (micro-pquant)",
        &["batch", "steps", "final loss", "PPL"],
    );
    let mut payload = Vec::new();
    for (entry, batch, n_steps) in entries {
        if !art.manifest.entries.contains_key(entry) {
            println!("[ablate-batch] entry {entry} missing (rebuild artifacts)");
            continue;
        }
        // distinct cache tag per batch size
        let cache_path = format!("results/cache/micro-pquant-ablate-{batch}-s{n_steps}.json");
        let r: RunResult = if let Ok(text) = std::fs::read_to_string(&cache_path) {
            RunResult::from_json(&Json::parse(&text)?)?
        } else {
            println!("[lab] training micro-pquant batch={batch} ...");
            let eval_tokens = lab.eval_tokens;
            let (dataset, _) = lab.dataset_ref(vocab);
            let mut trainer =
                crate::coordinator::Trainer::with_entry(&lab.runtime, &art, dataset, entry)?;
            let opts = crate::coordinator::TrainOptions {
                steps: n_steps,
                log_every: (n_steps / 4).max(1),
                ..Default::default()
            };
            let rep = trainer.run(&opts)?;
            let ppl = trainer.eval_perplexity(eval_tokens)?.unwrap_or(f64::NAN);
            let r = RunResult {
                config: "micro-pquant".into(),
                steps: n_steps,
                losses: rep.losses,
                final_loss: rep.final_loss,
                tail_loss: rep.tail_loss,
                ppl,
                task_acc: vec![],
                rollbacks: rep.rollbacks,
                wall_seconds: rep.wall_seconds,
                tokens_per_second: rep.tokens_per_second,
                feature_scaling: rep.feature_scaling,
                checkpoint: String::new(),
            };
            std::fs::write(&cache_path, r.to_json().to_string_pretty())?;
            r
        };
        t.row(vec![
            batch.to_string(),
            n_steps.to_string(),
            format!("{:.3}", r.tail_loss),
            format!("{:.2}", r.ppl),
        ]);
        payload.push(obj(vec![
            ("batch", num(batch as f64)),
            ("tail_loss", num(r.tail_loss as f64)),
            ("ppl", num(r.ppl)),
        ]));
    }
    t.print();
    println!("paper shape: smaller batches (more updates) win at matched token budget.");
    save("ablate-batch", &Json::Arr(payload), &[&t]);
    Ok(())
}
