//! Experiment harnesses — one per paper table/figure (DESIGN.md §5).
//!
//! All harnesses share a [`Lab`]: one PJRT runtime, cached datasets per
//! vocab size, and a disk cache of training runs (loss curves, eval
//! metrics, final checkpoints) under `results/cache/` so experiments
//! compose without retraining (fig1 reuses tab2's runs, fig5a reuses the
//! pquant checkpoint, ...).

pub mod analysis;
pub mod perf;
pub mod training;

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::coordinator::{TrainOptions, Trainer};
use crate::data::{default_cached_dataset, Dataset};
use crate::runtime::{load_artifact, Artifact, Runtime, TrainState};
use crate::tokenizer::Bpe;
use crate::util::json::{arr, num, obj, s, Json};

/// Default step counts per model size (tuned to the CPU budget; the
/// experiment CLI exposes `--steps` to override).
pub fn default_steps(size: &str) -> u64 {
    match size {
        "nano" => 300,
        "micro" => 250,
        "tiny" => 150,
        _ => 200,
    }
}

/// One cached training run's summary.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub config: String,
    pub steps: u64,
    pub losses: Vec<f32>,
    pub final_loss: f32,
    pub tail_loss: f32,
    pub ppl: f64,
    /// (task paper-name, accuracy) in suite order.
    pub task_acc: Vec<(String, f64)>,
    pub rollbacks: usize,
    pub wall_seconds: f64,
    pub tokens_per_second: f64,
    pub feature_scaling: Vec<(f32, f32)>,
    pub checkpoint: String,
}

impl RunResult {
    pub fn avg_acc(&self) -> f64 {
        if self.task_acc.is_empty() {
            return f64::NAN;
        }
        100.0 * self.task_acc.iter().map(|(_, a)| a).sum::<f64>() / self.task_acc.len() as f64
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("config", s(&self.config)),
            ("steps", num(self.steps as f64)),
            ("losses", arr(self.losses.iter().map(|&l| num(l as f64)))),
            ("final_loss", num(self.final_loss as f64)),
            ("tail_loss", num(self.tail_loss as f64)),
            ("ppl", num(self.ppl)),
            (
                "task_acc",
                arr(self
                    .task_acc
                    .iter()
                    .map(|(n, a)| arr([s(n), num(*a)]))),
            ),
            ("rollbacks", num(self.rollbacks as f64)),
            ("wall_seconds", num(self.wall_seconds)),
            ("tokens_per_second", num(self.tokens_per_second)),
            (
                "feature_scaling",
                arr(self
                    .feature_scaling
                    .iter()
                    .map(|(a, b)| arr([num(*a as f64), num(*b as f64)]))),
            ),
            ("checkpoint", s(&self.checkpoint)),
        ])
    }

    fn from_json(j: &Json) -> Result<RunResult> {
        let pair_list = |key: &str| -> Result<Vec<(String, f64)>> {
            j.get(key)?
                .as_arr()?
                .iter()
                .map(|p| {
                    let p = p.as_arr()?;
                    Ok((p[0].as_str()?.to_string(), p[1].as_f64()?))
                })
                .collect()
        };
        Ok(RunResult {
            config: j.get("config")?.as_str()?.to_string(),
            steps: j.get("steps")?.as_f64()? as u64,
            losses: j
                .get("losses")?
                .as_arr()?
                .iter()
                .map(|v| v.as_f64().map(|f| f as f32))
                .collect::<Result<_>>()?,
            final_loss: j.get("final_loss")?.as_f64()? as f32,
            tail_loss: j.get("tail_loss")?.as_f64()? as f32,
            ppl: j.get("ppl")?.as_f64()?,
            task_acc: pair_list("task_acc")?,
            rollbacks: j.get("rollbacks")?.as_f64()? as usize,
            wall_seconds: j.get("wall_seconds")?.as_f64()?,
            tokens_per_second: j.get("tokens_per_second")?.as_f64()?,
            feature_scaling: j
                .get("feature_scaling")?
                .as_arr()?
                .iter()
                .map(|p| {
                    let p = p.as_arr()?;
                    Ok((p[0].as_f64()? as f32, p[1].as_f64()? as f32))
                })
                .collect::<Result<_>>()?,
            checkpoint: j.get("checkpoint")?.as_str()?.to_string(),
        })
    }
}

/// Shared experiment infrastructure.
pub struct Lab {
    pub runtime: Runtime,
    datasets: HashMap<usize, (Dataset, Bpe)>,
    pub items_per_task: usize,
    pub eval_tokens: usize,
}

impl Lab {
    pub fn new() -> Result<Lab> {
        Ok(Lab {
            runtime: Runtime::cpu()?,
            datasets: HashMap::new(),
            items_per_task: 24,
            eval_tokens: 2048,
        })
    }

    /// Dataset + tokenizer for a vocab size (built once, cached on disk).
    pub fn dataset(&mut self, vocab: usize) -> Result<&(Dataset, Bpe)> {
        if !self.datasets.contains_key(&vocab) {
            let pair = default_cached_dataset(vocab)?;
            self.datasets.insert(vocab, pair);
        }
        Ok(&self.datasets[&vocab])
    }

    /// Immutable access to an already-built dataset (call [`Lab::dataset`]
    /// first to populate the cache).
    pub fn dataset_ref(&self, vocab: usize) -> &(Dataset, Bpe) {
        &self.datasets[&vocab]
    }

    pub fn artifact(&self, config: &str) -> Result<Artifact> {
        load_artifact(config)
    }

    /// Train (or fetch from cache) one run. `tag` distinguishes option
    /// variants of the same config (e.g. feature-scaling ablations).
    pub fn run(
        &mut self,
        config: &str,
        steps: u64,
        tag: &str,
        mutate: impl FnOnce(&mut TrainOptions),
    ) -> Result<RunResult> {
        std::fs::create_dir_all("results/cache").ok();
        let cache_key = if tag.is_empty() {
            format!("{config}-s{steps}")
        } else {
            format!("{config}-s{steps}-{tag}")
        };
        let cache_path = format!("results/cache/{cache_key}.json");
        if let Ok(text) = std::fs::read_to_string(&cache_path) {
            if let Ok(r) = RunResult::from_json(&Json::parse(&text)?) {
                println!("[lab] cache hit: {cache_key}");
                return Ok(r);
            }
        }
        println!("[lab] training {cache_key} ...");
        let art = self.artifact(config)?;
        let vocab = art.manifest.config.vocab;
        self.dataset(vocab)?; // ensure cached
        let ckpt_path = format!("results/cache/{cache_key}.ckpt");

        let mut opts = TrainOptions {
            steps,
            final_checkpoint: Some(ckpt_path.clone()),
            log_every: (steps / 8).max(1),
            ..Default::default()
        };
        mutate(&mut opts);

        let (dataset, bpe) = &self.datasets[&vocab];
        let mut trainer = Trainer::new(&self.runtime, &art, dataset)?;
        let report = trainer.run(&opts)?;

        // Evaluate: held-out perplexity + the 7-task suite.
        let fwd_key = if art.manifest.entries.contains_key("fwd_b8") { "fwd_b8" } else { "fwd" };
        let fwd = self.runtime.compile(&art, fwd_key)?;
        let ppl = crate::eval::perplexity(
            &trainer.state,
            &fwd,
            &dataset.valid,
            art.manifest.seq_len,
            vocab,
            self.eval_tokens,
        )?;
        let fwd1 = self.runtime.compile(&art, "fwd")?;
        let suite = crate::eval::task_suite(0x7A5C, self.items_per_task);
        let mut task_acc = Vec::new();
        for task in &suite {
            let acc = crate::eval::task_accuracy(
                &trainer.state,
                &fwd1,
                bpe,
                task,
                art.manifest.seq_len,
                vocab,
            )?;
            task_acc.push((task.paper_name.to_string(), acc));
        }

        let result = RunResult {
            config: config.to_string(),
            steps,
            losses: report.losses,
            final_loss: report.final_loss,
            tail_loss: report.tail_loss,
            ppl,
            task_acc,
            rollbacks: report.rollbacks,
            wall_seconds: report.wall_seconds,
            tokens_per_second: report.tokens_per_second,
            feature_scaling: report.feature_scaling,
            checkpoint: ckpt_path,
        };
        std::fs::write(&cache_path, result.to_json().to_string_pretty())?;
        Ok(result)
    }

    /// Load the TrainState recorded by a cached run.
    pub fn load_run_state(&self, run: &RunResult) -> Result<(Artifact, TrainState)> {
        let art = self.artifact(&run.config)?;
        let state = TrainState::load_checkpoint(&art, &run.checkpoint)?;
        Ok((art, state))
    }
}

/// All experiment ids in run order for `experiment all`.
pub const ALL_EXPERIMENTS: &[&str] = &[
    "tab1", "fig9", "tab6", "fig6", "fig8", "serving", "tab2", "fig1", "fig2",
    "fig4", "fig5a", "fig5b", "fig7", "tab3", "tab5", "tab7", "tab8", "fig10",
    "ablate-batch",
];

/// Dispatch one experiment by id.
pub fn run_experiment(lab: &mut Lab, id: &str, steps_override: Option<u64>) -> Result<()> {
    match id {
        "tab1" => perf::tab1(),
        "tab6" => perf::tab6(),
        "fig6" => perf::fig6(),
        "fig9" => perf::fig9(),
        "fig8" => perf::fig8(),
        "serving" => perf::serving(),
        "tab2" => training::tab2(lab, steps_override),
        "fig1" => training::fig1(lab, steps_override),
        "fig4" => training::fig4(lab, steps_override),
        "fig5b" => training::fig5b(lab, steps_override),
        "fig10" => training::fig10(lab, steps_override),
        "tab3" => training::tab3(lab, steps_override),
        "tab5" => training::tab5(lab, steps_override),
        "tab7" => training::tab7(lab, steps_override),
        "tab8" => training::tab8(lab, steps_override),
        "ablate-batch" => training::ablate_batch(lab, steps_override),
        "fig2" => analysis::fig2(lab, steps_override),
        "fig5a" => analysis::fig5a(lab, steps_override),
        "fig7" => analysis::fig7(lab, steps_override),
        "all" => {
            for id in ALL_EXPERIMENTS {
                println!("\n================ experiment {id} ================");
                run_experiment(lab, id, steps_override)?;
            }
            Ok(())
        }
        _ => Err(anyhow!("unknown experiment {id:?}; known: {ALL_EXPERIMENTS:?} or 'all'")),
    }
}
