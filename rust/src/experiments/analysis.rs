//! Sensitivity/analysis experiments: Fig 2 (democratization), Fig 5a
//! (branch sensitivity), Fig 7 (expert scaling + alternative quantizers).

use anyhow::Result;

use crate::config::Variant;
use crate::report::{save, Table};
use crate::sensitivity::{ascii_heatmap, dequantized_weights, sensitivity_map};
use crate::tensor::Matrix;
use crate::util::json::{arr, num, obj, s, Json};

use super::{default_steps, Lab};

fn steps_for(config: &str, over: Option<u64>) -> u64 {
    over.unwrap_or_else(|| default_steps(config.split('-').next().unwrap()))
}

/// Calibration activations: run the AOT fwd over a few valid windows and
/// collect the last block's normalized FFN inputs.
fn calibration_acts(
    lab: &mut Lab,
    config: &str,
    run: &super::RunResult,
    n_windows: usize,
) -> Result<Matrix> {
    let (art, state) = lab.load_run_state(run)?;
    let fwd = lab.runtime.compile(&art, "fwd")?;
    let seq = art.manifest.seq_len;
    let d = art.manifest.config.d_model;
    let vocab = art.manifest.config.vocab;
    let (dataset, _) = lab.dataset(vocab)?;
    let mut rows: Vec<f32> = Vec::new();
    for w in 0..n_windows {
        let start = w * seq;
        if start + seq > dataset.valid.len() {
            break;
        }
        let tokens: Vec<i32> = dataset.valid[start..start + seq].iter().map(|&t| t as i32).collect();
        let (_, ffn_in) = state.forward(&fwd, &tokens)?;
        rows.extend(ffn_in);
    }
    let n_rows = rows.len() / d;
    Ok(Matrix::from_vec(n_rows, d, rows))
}

/// Last layer's FFN down-projection weights (the matrix Fig 2 visualizes)
/// — for pquant this returns the 1-bit branch; use `expert_weights` for
/// the 8-bit branch.
fn last_ffn_weights(lab: &Lab, run: &super::RunResult) -> Result<(Matrix, Variant, usize)> {
    let (art, state) = lab.load_run_state(run)?;
    let cfg = &art.manifest.config;
    let l = cfg.n_layers - 1;
    let (name, rows) = match cfg.variant {
        Variant::PQuant => (format!("layers.{l}.ffn_up_1bit"), cfg.d_model),
        _ => (format!("layers.{l}.ffn_up"), cfg.d_model),
    };
    let (shape, data) = state.param_by_name(&art, &name)?;
    assert_eq!(shape[0], rows);
    Ok((Matrix::from_vec(shape[0], shape[1], data), cfg.variant, l))
}

/// Fig 2: sensitivity heatmaps — fp16 vs 1-bit (parameter democratization).
pub fn fig2(lab: &mut Lab, steps: Option<u64>) -> Result<()> {
    let mut t = Table::new(
        "Figure 2 — weight sensitivity concentration (last FFN up-proj)",
        &["model", "gini", "log-kurtosis", "top1% mass", "top10% mass"],
    );
    let mut payload = Vec::new();
    let mut maps = Vec::new();
    for config in ["micro-fp16", "micro-bitnet"] {
        let run = lab.run(config, steps_for(config, steps), "", |_| {})?;
        let acts = calibration_acts(lab, config, &run, 8)?;
        let (w, variant, _) = last_ffn_weights(lab, &run)?;
        // Analyze the weights the deployed model multiplies by.
        let w_eff = dequantized_weights(&w, variant);
        let rep = sensitivity_map(&w_eff, &acts, 1e-2)?;
        t.row(vec![
            config.to_string(),
            format!("{:.3}", rep.gini),
            format!("{:.2}", rep.log_kurtosis),
            format!("{:.3}", rep.top1pct_mass),
            format!("{:.3}", rep.top10pct_mass),
        ]);
        payload.push(obj(vec![
            ("config", s(config)),
            ("gini", num(rep.gini)),
            ("log_kurtosis", num(rep.log_kurtosis)),
            ("top1pct_mass", num(rep.top1pct_mass)),
            ("top10pct_mass", num(rep.top10pct_mass)),
        ]));
        maps.push((config, rep.map));
    }
    t.print();
    for (config, map) in &maps {
        println!("\n{config} log-sensitivity heatmap (max-pooled):");
        println!("{}", ascii_heatmap(map, 16, 48));
    }
    println!("paper shape: fp16 shows concentrated high-sensitivity regions; the 1-bit");
    println!("model's map is near-uniform — parameter democratization.");
    save("fig2", &Json::Arr(payload), &[&t]);
    Ok(())
}

/// Fig 5a: sensitivity of the 1-bit vs 8-bit branch in trained pQuant.
pub fn fig5a(lab: &mut Lab, steps: Option<u64>) -> Result<()> {
    let config = "micro-pquant";
    let run = lab.run(config, steps_for(config, steps), "", |_| {})?;
    let acts = calibration_acts(lab, config, &run, 8)?;
    let (art, state) = lab.load_run_state(&run)?;
    let cfg = &art.manifest.config;
    let l = cfg.n_layers - 1;

    // 1-bit branch up-projection (dequantized ±λ).
    let (s1, d1) = state.param_by_name(&art, &format!("layers.{l}.ffn_up_1bit"))?;
    let w1 = dequantized_weights(&Matrix::from_vec(s1[0], s1[1], d1), Variant::BitNet);
    // 8-bit branch up-projection (expert 0, dequantized int8).
    let (s8, d8) = state.param_by_name(&art, &format!("layers.{l}.ffn_up_8bit"))?;
    let (d, r) = (s8[1], s8[2]);
    let q = crate::quant::quantize_i8(&d8[..d * r]);
    let w8 = Matrix::from_vec(
        d,
        r,
        q.vals.iter().map(|&v| v as f32 / q.gamma).collect(),
    );

    let rep1 = sensitivity_map(&w1, &acts, 1e-2)?;
    let rep8 = sensitivity_map(&w8, &acts, 1e-2)?;

    // Mean per-weight sensitivity: the 8-bit branch should concentrate
    // disproportionately high sensitivity despite holding ~5% of weights.
    let mean = |m: &Matrix| m.data.iter().map(|&v| v as f64).sum::<f64>() / m.data.len() as f64;
    let m1 = mean(&rep1.map);
    let m8 = mean(&rep8.map);

    let mut t = Table::new(
        "Figure 5a — branch sensitivity in trained pQuant (last FFN up-proj)",
        &["branch", "weights", "mean s_ij", "gini", "top10% mass"],
    );
    t.row(vec![
        "1-bit (wide)".into(),
        w1.data.len().to_string(),
        format!("{m1:.3e}"),
        format!("{:.3}", rep1.gini),
        format!("{:.3}", rep1.top10pct_mass),
    ]);
    t.row(vec![
        "8-bit (r)".into(),
        w8.data.len().to_string(),
        format!("{m8:.3e}"),
        format!("{:.3}", rep8.gini),
        format!("{:.3}", rep8.top10pct_mass),
    ]);
    t.print();
    println!("8-bit/1-bit mean sensitivity ratio: {:.2}x", m8 / m1.max(1e-30));
    println!("\n1-bit branch heatmap:\n{}", ascii_heatmap(&rep1.map, 12, 44));
    println!("8-bit branch heatmap:\n{}", ascii_heatmap(&rep8.map, 12, 16));
    println!("paper shape: the compact 8-bit branch carries markedly higher per-weight");
    println!("sensitivity — the decoupling + feature scaling worked.");
    save(
        "fig5a",
        &obj(vec![
            ("mean_s_1bit", num(m1)),
            ("mean_s_8bit", num(m8)),
            ("ratio", num(m8 / m1.max(1e-30))),
            ("gini_1bit", num(rep1.gini)),
            ("gini_8bit", num(rep8.gini)),
        ]),
        &[&t],
    );
    Ok(())
}

/// Fig 7 left: PPL vs N. Fig 7 right: alternative quantization schemes as
/// post-hoc weight transforms of the trained bitnet model, evaluated by
/// the rust inference engine (DESIGN.md §3 substitution).
pub fn fig7(lab: &mut Lab, steps: Option<u64>) -> Result<()> {
    // ---- left: expert scaling ----
    let mut t1 = Table::new("Figure 7 (left) — perplexity vs N (micro)", &["N", "PPL"]);
    let mut left = Vec::new();
    for (n, config) in [
        (1, "micro-pquant"),
        (2, "micro-pquant-n2"),
        (4, "micro-pquant-n4"),
        (8, "micro-pquant-n8"),
    ] {
        let r = lab.run(config, steps_for(config, steps), "", |_| {})?;
        t1.row(vec![n.to_string(), format!("{:.2}", r.ppl)]);
        left.push(obj(vec![("n", num(n as f64)), ("ppl", num(r.ppl))]));
    }
    // 2-bit reference line
    let b158 = lab.run("micro-bitnet158", steps_for("micro-bitnet158", steps), "", |_| {})?;
    t1.row(vec!["(BitNet1.58)".into(), format!("{:.2}", b158.ppl)]);
    t1.print();
    println!("paper shape: PPL decreases monotonically in N; crosses the 2-bit line near N=4.");

    // ---- right: alternative quantizers on the trained bitnet ----
    let run = lab.run("micro-bitnet", steps_for("micro-bitnet", steps), "", |_| {})?;
    let (art, state) = lab.load_run_state(&run)?;
    let (dataset, _) = lab.dataset(art.manifest.config.vocab)?;
    let valid: Vec<u32> = dataset.valid.clone();
    let seq = art.manifest.config.seq_len;

    let schemes: [(&str, Scheme); 4] = [
        ("per-tensor 1-bit (BitNet)", Scheme::PerTensor),
        ("channel-wise 1-bit", Scheme::ChannelWise),
        ("group-wise 1-bit (g=64)", Scheme::GroupWise(64)),
        ("native mix (8% fp16)", Scheme::NativeMix(0.08)),
    ];
    let mut t2 = Table::new(
        "Figure 7 (right) — alternative quantizers (post-hoc on trained bitnet, engine PPL)",
        &["scheme", "PPL", "scale metadata bytes/matrix"],
    );
    let mut right = Vec::new();
    for (label, scheme) in schemes {
        let mut model = rebuild_with_scheme(&art, &state, scheme)?;
        let ppl = engine_perplexity(&mut model, &valid, seq, 1536);
        let meta = scheme_metadata_bytes(&art.manifest.config, scheme);
        t2.row(vec![label.to_string(), format!("{ppl:.2}"), meta.to_string()]);
        right.push(obj(vec![
            ("scheme", s(label)),
            ("ppl", num(ppl)),
            ("metadata_bytes", num(meta as f64)),
        ]));
    }
    // pQuant trained end-to-end for reference
    let pq = lab.run("micro-pquant", steps_for("micro-pquant", steps), "", |_| {})?;
    t2.row(vec!["pQuant (trained decoupled)".into(), format!("{:.2}", pq.ppl), "n/a".into()]);
    t2.print();
    println!("paper shape: channel-wise ≈ per-tensor; group-wise better but needs one");
    println!("scale per 64 weights; native mix worse than pQuant despite more hp params.");
    save(
        "fig7",
        &obj(vec![("left", Json::Arr(left)), ("right", Json::Arr(right))]),
        &[&t1, &t2],
    );
    Ok(())
}

#[derive(Clone, Copy)]
enum Scheme {
    PerTensor,
    ChannelWise,
    GroupWise(usize),
    NativeMix(f32),
}

/// Re-quantize every block linear of a trained model under `scheme` and
/// build an f32-engine model from the dequantized weights (accuracy study;
/// the speed study is Fig 8).
fn rebuild_with_scheme(
    art: &crate::runtime::Artifact,
    state: &crate::runtime::TrainState,
    scheme: Scheme,
) -> Result<crate::infer::PackedModel> {
    use crate::infer::{block::Ffn, PackedBlock, PackedModel, QLinear};
    let cfg = art.manifest.config.clone();
    let d = cfg.d_model;
    let requant = |wf: &[f32], k: usize, n: usize| -> Vec<f32> {
        match scheme {
            Scheme::PerTensor => {
                let b = crate::quant::binarize(wf);
                crate::quant::dequant_binary(&b)
            }
            Scheme::ChannelWise => {
                let (signs, lambdas, _) = crate::quant::binarize_channelwise(wf, k, n);
                (0..k * n)
                    .map(|idx| {
                        let j = idx % n;
                        if signs[idx] { lambdas[j] } else { -lambdas[j] }
                    })
                    .collect()
            }
            Scheme::GroupWise(g) => {
                if k % g != 0 {
                    // ragged: fall back to channel-wise for this matrix
                    let (signs, lambdas, _) = crate::quant::binarize_channelwise(wf, k, n);
                    return (0..k * n)
                        .map(|idx| {
                            let j = idx % n;
                            if signs[idx] { lambdas[j] } else { -lambdas[j] }
                        })
                        .collect();
                }
                let (signs, lambdas) = crate::quant::binarize_groupwise(wf, k, n, g);
                (0..k * n)
                    .map(|idx| {
                        let (i, j) = (idx / n, idx % n);
                        let lam = lambdas[(i / g) * n + j];
                        if signs[idx] { lam } else { -lam }
                    })
                    .collect()
            }
            Scheme::NativeMix(frac) => {
                // keep the top `frac` |w| in fp, binarize the rest
                let mut mags: Vec<(f32, usize)> =
                    wf.iter().enumerate().map(|(i, &w)| (w.abs(), i)).collect();
                mags.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                let keep = (wf.len() as f32 * frac) as usize;
                let mut kept = vec![false; wf.len()];
                for &(_, i) in mags.iter().take(keep) {
                    kept[i] = true;
                }
                let rest: Vec<f32> = wf
                    .iter()
                    .zip(&kept)
                    .map(|(&w, &k)| if k { 0.0 } else { w })
                    .collect();
                let b = crate::quant::binarize(&rest);
                let deq = crate::quant::dequant_binary(&b);
                wf.iter()
                    .zip(kept)
                    .zip(deq)
                    .map(|((&w, k), dq)| if k { w } else { dq })
                    .collect()
            }
        }
    };

    let get = |name: &str| state.param_by_name(art, name);
    let (_, embed) = get("tok_embed")?;
    let (_, lm_head) = get("lm_head")?;
    let (_, final_norm) = get("final_norm")?;
    let mut blocks = Vec::new();
    for l in 0..cfg.n_layers {
        let p = |f: &str| get(&format!("layers.{l}.{f}"));
        let (_, attn_norm) = p("attn_norm")?;
        let (_, ffn_norm) = p("ffn_norm")?;
        let lin = |name: &str, k: usize, n: usize| -> Result<QLinear> {
            let (_, wf) = p(name)?;
            Ok(QLinear::f32(&requant(&wf, k, n), k, n))
        };
        blocks.push(PackedBlock {
            attn_norm,
            ffn_norm,
            wq: lin("wq", d, d)?,
            wk: lin("wk", d, d)?,
            wv: lin("wv", d, d)?,
            wo: lin("wo", d, d)?,
            ffn: Ffn::Dense {
                up: lin("ffn_up", d, cfg.d_ff)?,
                down: lin("ffn_down", cfg.d_ff, d)?,
            },
            n_heads: cfg.n_heads,
            timing: Default::default(),
        });
    }
    Ok(PackedModel { cfg, embed, lm_head, final_norm, blocks, rope: Default::default() })
}

/// Teacher-forced perplexity under the rust engine.
fn engine_perplexity(
    model: &mut crate::infer::PackedModel,
    stream: &[u32],
    seq: usize,
    max_tokens: usize,
) -> f64 {
    let mut nll = 0.0f64;
    let mut count = 0usize;
    let n_windows = (stream.len() / (seq + 1)).min(max_tokens / seq).max(1);
    for w in 0..n_windows {
        let toks = &stream[w * (seq + 1)..(w + 1) * (seq + 1)];
        let mut caches = model.new_caches(seq + 1);
        for t in 0..seq {
            let logits = model.decode_step(toks[t], t, &mut caches);
            // log softmax target
            let max = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let lse = logits.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
            nll -= (logits[toks[t + 1] as usize] - lse) as f64;
            count += 1;
        }
    }
    (nll / count.max(1) as f64).exp()
}

/// Scale metadata bytes per FFN-up matrix under each scheme (the Fig 7
/// hardware-friendliness argument).
fn scheme_metadata_bytes(cfg: &crate::config::ModelConfig, scheme: Scheme) -> usize {
    let (k, n) = (cfg.d_model, cfg.d_ff);
    match scheme {
        Scheme::PerTensor => 2,
        Scheme::ChannelWise => 2 * n,
        Scheme::GroupWise(g) => 2 * (k / g.max(1)) * n,
        Scheme::NativeMix(frac) => ((k * n) as f32 * frac) as usize * (2 + 4), // fp16 + index
    }
}
