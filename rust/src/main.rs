//! `repro` — the pQuant coordinator CLI.
//!
//! Subcommands:
//!   experiment <id|all> [--steps N]   regenerate a paper table/figure
//!   train --config C [--steps N] [--lr F] [--checkpoint P] [--export P.pqm]
//!   eval --config C --checkpoint P    perplexity + 7-task suite
//!   eval --model P.pqm                packed-engine perplexity, no PJRT
//!   export <config> <out.pqm>         checkpoint → packed `.pqm` artifact
//!   inspect <path.pqm>                header + section table of an artifact
//!   serve --config C | --model P.pqm  continuous-batching load test
//!   obs-check --http ADDR | --trace P  observability self-check
//!   sensitivity --config C [--checkpoint P]
//!   list-configs                       artifacts found on disk
//!
//! (Arg parsing is hand-rolled: the offline crate set has no clap.)

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use pquant::experiments::{run_experiment, Lab};

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

fn parse_args(raw: &[String]) -> Result<Args> {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < raw.len() {
        let a = &raw[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                flags.insert(name.to_string(), raw[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Ok(Args { positional, flags })
}

impl Args {
    fn flag<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(name) {
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow!("bad value for --{name}: {e}")),
            None => Ok(default),
        }
    }

    fn opt_flag<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(name) {
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("bad value for --{name}: {e}")),
            None => Ok(None),
        }
    }

    fn require(&self, name: &str) -> Result<&str> {
        self.flags
            .get(name)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing required flag --{name}"))
    }
}

const USAGE: &str = "\
repro — pQuant coordinator (see README.md)

USAGE:
  repro experiment <id|all> [--steps N]
  repro train --config C [--steps N] [--lr F] [--checkpoint P] [--export P.pqm] [--eval-every N] [--single-phase]
  repro eval --config C --checkpoint P [--items N]
  repro eval --model P.pqm [--tokens N]
              [--draft-model D.pqm] [--spec-k K]    speculative agreement + acceptance report
  repro export <config> <out.pqm> [--checkpoint P] [--tokenizer] [--random SEED]
              (--random also accepts the built-in \"smoke\" CI config)
  repro inspect <path.pqm>
  repro serve (--config C [--checkpoint P] | --model P.pqm) [--requests N] [--new-tokens N]
              [--batch N] [--workers N] [--queue N] [--prefill-chunk N]
              [--temperature F] [--top-k N] [--seed N]
              [--kv-blocks N] [--kv-block-size N]   (0 kv-blocks: unmetered legacy caches)
              [--kv-mode f32|int8]                  KV block storage precision: int8 packs 4x
                                                    the tokens into the same block bytes
              [--kv-spill-dir P]                    cold tier: shed shared prefixes spill to
                                                    .pqm files here and fault back on reuse
              [--draft-model D.pqm] [--spec-k K]    speculative decode: the draft proposes K
                                                    tokens per round (same vocab required);
                                                    the target verifies them in one fused
                                                    batch step — greedy output is unchanged
              [--http ADDR [--duration SECS]]       HTTP/SSE front end instead of the batch
                                                    load test: POST /v1/generate (SSE stream),
                                                    GET /v1/metrics (JSON, or Prometheus text
                                                    via Accept/?format=prometheus),
                                                    GET /v1/trace/<id|latest|all>,
                                                    GET /v1/models,
                                                    GET /v1/health (200 ready / 503 not)
                                                    (0 duration: serve until killed)
              [--stall-budget-ms N]                 fused rounds longer than this mark the
                                                    engine degraded in /v1/health (default
                                                    5000)
              [--trace] [--trace-out P.json]        per-request span tracing (Chrome
                                                    trace-event JSON; --trace-out writes the
                                                    ring when the run ends and implies --trace)
              [--timing]                            fold per-component decode phase timers
                                                    into the metrics registry
  repro loadtest (--config C | --model P.pqm | --http ADDR) [--seed N] [--requests N]
              [--rate R] [--burst-factor F] [--burst-on S] [--burst-off S]
              [--prompt-lens L:W,..] [--output-lens L:W,..]
              [--shared-frac F] [--shared-prefix N] [--draft-frac F] [--spec-k K]
              [--max-retries N] [--out P.json]      trace-driven SLO report
              [--out-jsonl P.jsonl]                 per-request records, one JSON per line
              (engine flags as for serve; --http drives a live endpoint instead)
  repro obs-check [--http ADDR] [--trace P.json]    observability self-check: scrape
                                                    /v1/metrics in JSON + Prometheus text and
                                                    cross-check them, require /v1/health to
                                                    report ready, validate /v1/trace/latest
                                                    and/or a trace file as Chrome trace JSON
  repro sensitivity --config C [--checkpoint P]
  repro list-configs
";

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let args = parse_args(&raw[1..])?;
    match raw[0].as_str() {
        "experiment" => cmd_experiment(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "export" => cmd_export(&args),
        "inspect" => cmd_inspect(&args),
        "serve" => cmd_serve(&args),
        "loadtest" => cmd_loadtest(&args),
        "obs-check" => cmd_obs_check(&args),
        "sensitivity" => cmd_sensitivity(&args),
        "list-configs" => cmd_list(),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("experiment id required (or 'all')"))?;
    let steps = args.opt_flag::<u64>("steps")?;
    let mut lab = Lab::new()?;
    run_experiment(&mut lab, id, steps)
}

fn cmd_train(args: &Args) -> Result<()> {
    use pquant::coordinator::{TrainOptions, Trainer};
    let config = args.require("config")?;
    let steps = args.flag("steps", 200u64)?;
    let art = pquant::runtime::load_artifact(config)
        .with_context(|| format!("loading artifact {config}"))?;
    let runtime = pquant::runtime::Runtime::cpu()?;
    let (dataset, _bpe) = pquant::data::default_cached_dataset(art.manifest.config.vocab)?;
    let mut trainer = Trainer::new(&runtime, &art, &dataset)?;
    let opts = TrainOptions {
        steps,
        peak_lr: args.flag("lr", 1.5e-3f32)?,
        eval_every: args.flag("eval-every", 0u64)?,
        single_phase: args.flags.contains_key("single-phase"),
        final_checkpoint: args.flags.get("checkpoint").cloned(),
        export_pqm: args.flags.get("export").cloned(),
        log_every: args.flag("log-every", (steps / 20).max(1))?,
        ..Default::default()
    };
    let report = trainer.run(&opts)?;
    println!(
        "\ndone: final loss {:.4} (tail {:.4}), {:.1} tokens/s, {} rollbacks, {:.1}s wall",
        report.final_loss,
        report.tail_loss,
        report.tokens_per_second,
        report.rollbacks,
        report.wall_seconds
    );
    if let Some(ppl) = trainer.eval_perplexity(4096)? {
        println!("valid perplexity: {ppl:.2}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    // Packed path: score a shipped `.pqm` artifact on the rust engine
    // (no PJRT, no artifact dir, no checkpoint needed).
    if let Some(path) = args.flags.get("model") {
        let loaded = pquant::artifact::load_pqm(path)?;
        let mut model = loaded.model;
        let max_tokens = args.flag("tokens", 4096usize)?;
        let (dataset, _) = pquant::data::default_cached_dataset(model.cfg.vocab)?;
        let ppl = pquant::eval::packed_perplexity(&mut model, &dataset.valid, max_tokens);
        println!(
            "packed perplexity ({}, {} tokens max): {ppl:.3}",
            model.cfg.name, max_tokens
        );
        // Speculative report: greedy agreement with plain decode (must be
        // 100% — speculation is an optimization, not an approximation),
        // acceptance rate, and the wall-clock ratio on real prompts.
        if let Some(dpath) = args.flags.get("draft-model") {
            use std::time::Instant;
            let mut draft = pquant::artifact::load_pqm(dpath)?.model;
            if draft.cfg.vocab != model.cfg.vocab {
                bail!(
                    "draft vocab {} incompatible with target vocab {}",
                    draft.cfg.vocab,
                    model.cfg.vocab
                );
            }
            let k = args.flag("spec-k", 4usize)?;
            let (prompt_len, n_new, n_prompts) = (16usize, 32usize, 8usize);
            let mut dec = pquant::serve::SpecDecoder::new(k);
            let mut agree = 0usize;
            let (mut spec_wall, mut plain_wall) = (0f64, 0f64);
            for w in 0..n_prompts {
                let start = w * prompt_len;
                if start + prompt_len > dataset.valid.len() {
                    break;
                }
                let prompt = &dataset.valid[start..start + prompt_len];
                let t0 = Instant::now();
                let spec_out = dec.generate(&mut model, &mut draft, prompt, n_new, None);
                spec_wall += t0.elapsed().as_secs_f64();
                let t0 = Instant::now();
                let plain = model.generate(prompt, n_new);
                plain_wall += t0.elapsed().as_secs_f64();
                if spec_out == plain {
                    agree += 1;
                }
            }
            println!(
                "speculative (draft {}, k={k}): agreement {agree}/{n_prompts} | acceptance \
                 {:.0}% | {:.2} tokens/verify | speedup (plain wall / spec wall) {:.2}x",
                draft.cfg.name,
                dec.stats.acceptance_rate() * 100.0,
                dec.stats.tokens_per_verify(),
                plain_wall / spec_wall.max(1e-9),
            );
        }
        println!("(zero-shot task suite needs the PJRT fwd entry: use --config/--checkpoint)");
        return Ok(());
    }
    let config = args.require("config")?;
    let ckpt = args.require("checkpoint")?;
    let items = args.flag("items", 40usize)?;
    let art = pquant::runtime::load_artifact(config)?;
    let runtime = pquant::runtime::Runtime::cpu()?;
    let state = pquant::runtime::TrainState::load_checkpoint(&art, ckpt)?;
    let (dataset, bpe) = pquant::data::default_cached_dataset(art.manifest.config.vocab)?;
    let fwd_key = if art.manifest.entries.contains_key("fwd_b8") { "fwd_b8" } else { "fwd" };
    let fwd = runtime.compile(&art, fwd_key)?;
    let ppl = pquant::eval::perplexity(
        &state,
        &fwd,
        &dataset.valid,
        art.manifest.seq_len,
        art.manifest.config.vocab,
        4096,
    )?;
    println!("perplexity: {ppl:.3}");
    let fwd1 = runtime.compile(&art, "fwd")?;
    let mut total = 0.0;
    for task in pquant::eval::task_suite(0x7A5C, items) {
        let acc = pquant::eval::task_accuracy(
            &state,
            &fwd1,
            &bpe,
            &task,
            art.manifest.seq_len,
            art.manifest.config.vocab,
        )?;
        println!("{:12} {:.1}%  (chance {:.0}%)", task.paper_name, acc * 100.0, task.chance * 100.0);
        total += acc;
    }
    println!("{:12} {:.1}%", "Avg", total / 7.0 * 100.0);
    Ok(())
}

/// Registry + engine + workload facts shared by `serve` and `loadtest`:
/// load the target (and optional draft) model, register, start the engine.
struct ServeStack {
    registry: std::sync::Arc<pquant::serve::ModelRegistry>,
    engine: pquant::serve::Engine,
    speculative: bool,
    vocab: u32,
}

fn build_serve_stack(args: &Args) -> Result<ServeStack> {
    use pquant::serve::{Engine, EngineOptions};
    let kv_defaults = pquant::kvcache::KvPoolOptions::default();
    let kv_blocks = args.flag("kv-blocks", kv_defaults.n_blocks)?;
    let kv_mode = match args.flags.get("kv-mode") {
        Some(v) => pquant::kvcache::KvStorageMode::parse(v)
            .ok_or_else(|| anyhow!("bad --kv-mode {v:?} (expected f32 or int8)"))?,
        None => kv_defaults.mode,
    };
    let kv = (kv_blocks > 0).then_some(pquant::kvcache::KvPoolOptions {
        n_blocks: kv_blocks,
        block_size: args.flag("kv-block-size", kv_defaults.block_size)?.max(1),
        mode: kv_mode,
    });
    let opts = EngineOptions {
        model: "serve".into(),
        max_batch: args.flag("batch", 4usize)?,
        workers: args.flag("workers", 1usize)?,
        queue_depth: args.flag("queue", 64usize)?,
        prefill_chunk: args.flag("prefill-chunk", 16usize)?,
        kv,
        draft_kv: None, // draft pools mirror the target pool geometry
        kv_spill_dir: args.flags.get("kv-spill-dir").map(std::path::PathBuf::from),
        trace: args.flags.contains_key("trace") || args.flags.contains_key("trace-out"),
        timing: if args.flags.contains_key("timing") {
            pquant::infer::TimingMode::Accumulate
        } else {
            pquant::infer::TimingMode::Off
        },
        stall_budget: std::time::Duration::from_millis(args.flag("stall-budget-ms", 5000u64)?),
        ..EngineOptions::default()
    };
    // All serving flows through the registry: load (from .pqm or a live
    // TrainState), register under a name, start the engine against it.
    let registry = std::sync::Arc::new(pquant::serve::ModelRegistry::new());
    if let Some(path) = args.flags.get("model") {
        registry.load_pqm("serve", path)?;
    } else {
        let config = args.require("config")?;
        let art = pquant::runtime::load_artifact(config)?;
        let state = match args.flags.get("checkpoint") {
            Some(ckpt) => pquant::runtime::TrainState::load_checkpoint(&art, ckpt)?,
            None => {
                println!("(no --checkpoint: serving randomly initialized packed weights)");
                pquant::runtime::TrainState::initial(&art)?
            }
        };
        registry.register("serve", pquant::infer::PackedModel::from_state(&art, &state)?, None);
    }
    // Speculative decoding: register the draft beside the target; every
    // request then carries the spec config (vocab compatibility is
    // enforced at submit time with a typed error).
    let speculative = if let Some(path) = args.flags.get("draft-model") {
        registry.load_pqm("draft", path)?;
        true
    } else {
        false
    };
    for m in registry.info() {
        println!(
            "serving {:12} gen {} {:10} {:.2}M params, {:.1} MiB packed",
            m.name,
            m.generation,
            m.variant.name(),
            m.params as f64 / 1e6,
            m.storage_bytes as f64 / (1024.0 * 1024.0)
        );
    }
    let vocab = registry.acquire("serve").expect("registered above").model.cfg.vocab as u32;
    let engine = Engine::start(&registry, opts)?;
    Ok(ServeStack { registry, engine, speculative, vocab })
}

/// `repro serve --http ADDR`: front the engine with the HTTP/SSE server
/// instead of running the batch load test.
fn serve_http(args: &Args, stack: ServeStack, addr: &str) -> Result<()> {
    use pquant::serve::{HttpServer, Router};
    let engine = std::sync::Arc::new(stack.engine);
    let router = Router::new(stack.registry.clone()).route("serve", engine.clone());
    let server = HttpServer::bind(addr, router)?;
    let local = server.local_addr();
    println!("listening on http://{local}");
    println!("  POST /v1/generate   (SSE stream; body: {{\"prompt\": [..], \"n_new\": N, ...}})");
    println!("  GET  /v1/metrics    (JSON; Prometheus text via ?format=prometheus)");
    println!("  GET  /v1/models     GET  /v1/trace/<id|latest|all>");
    println!("  GET  /v1/health     (200 while ready; 503 degraded/draining, with reason)");
    let duration = args.flag("duration", 0u64)?;
    if duration > 0 {
        std::thread::sleep(std::time::Duration::from_secs(duration));
    } else {
        loop {
            // No signal handling offline: serve until the process is killed.
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    server.shutdown();
    let health = engine.health();
    let metrics = engine.metrics().clone();
    let tp = metrics.tpot_percentiles();
    println!(
        "served: {} completed, {} cancelled, {} tokens out, {} worker faults | health {} | \
         tpot ms: p50 {:.1}  p95 {:.1}  p99 {:.1}",
        metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
        metrics.cancelled.load(std::sync::atomic::Ordering::Relaxed),
        metrics.tokens_out.load(std::sync::atomic::Ordering::Relaxed),
        metrics.worker_faults.load(std::sync::atomic::Ordering::Relaxed),
        health.name(),
        tp.p50,
        tp.p95,
        tp.p99
    );
    if let Some(path) = args.flags.get("trace-out") {
        write_trace_out(&metrics, path)?;
    }
    drop(engine); // Engine::drop joins the workers
    Ok(())
}

/// Dump the engine's completed-trace ring (plus the KV event track) as a
/// Chrome trace-event JSON file, Perfetto/`chrome://tracing`-loadable.
fn write_trace_out(metrics: &pquant::serve::ServeMetrics, path: &str) -> Result<()> {
    let tr = metrics
        .trace()
        .ok_or_else(|| anyhow!("--trace-out needs tracing enabled (it implies --trace)"))?;
    let path = std::path::Path::new(path);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, tr.to_chrome_json().to_string() + "\n")
        .with_context(|| format!("writing {}", path.display()))?;
    println!(
        "wrote trace {} ({} completed requests, {} evicted from the ring)",
        path.display(),
        tr.completed_count(),
        tr.dropped_traces()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use pquant::serve::{GenRequest, SamplingParams, SubmitError};
    use std::time::Instant;

    let stack = build_serve_stack(args)?;
    if let Some(addr) = args.flags.get("http").cloned() {
        return serve_http(args, stack, &addr);
    }
    let requests = args.flag("requests", 16usize)?;
    let new_tokens = args.flag("new-tokens", 32usize)?;
    let spec_k = args.flag("spec-k", 4usize)?;
    let temperature = args.flag("temperature", 0.0f32)?;
    let top_k = args.flag("top-k", 0usize)?;
    let seed = args.flag("seed", 0u64)?;
    let ServeStack { engine, speculative, vocab, .. } = stack;
    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(requests);
    for id in 0..requests {
        let prompt: Vec<u32> = (0..8).map(|i| (id as u32 + i as u32) % vocab).collect();
        let sampling = SamplingParams {
            temperature,
            top_k,
            seed: seed.wrapping_add(id as u64),
            stop_tokens: vec![],
        };
        let mut req = GenRequest::sampled(prompt, new_tokens, sampling);
        if speculative {
            req = req.with_spec("draft", spec_k);
        }
        // submit_blocking absorbs QueueFull/KvExhausted backpressure (the
        // load generator outpacing the queue or the KV budget is expected;
        // both drain as in-flight requests finish); terminal errors stop
        // the run.
        match engine.submit_blocking(req) {
            Ok(t) => tickets.push(t),
            Err(e @ SubmitError::KvTooLarge(_)) => {
                bail!("{e}: raise --kv-blocks or lower --new-tokens")
            }
            Err(e @ SubmitError::DraftRejected(..)) => {
                bail!("{e}: --draft-model must share the target's vocabulary")
            }
            Err(e) => bail!("submit failed: {e}"),
        }
    }
    let stats: Vec<_> = tickets.into_iter().map(|t| t.wait()).collect();
    let wall = t0.elapsed();
    let metrics = engine.shutdown();
    let toks = metrics.tokens_out.load(std::sync::atomic::Ordering::Relaxed) as f64;
    println!(
        "{} requests × {} tokens in {:.2}s → {:.1} tokens/s",
        stats.len(),
        new_tokens,
        wall.as_secs_f64(),
        toks / wall.as_secs_f64()
    );
    let mut lats: Vec<f64> = stats
        .iter()
        .map(|s| (s.queue_wait + s.service_time).as_secs_f64() * 1e3)
        .collect();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "latency ms: p50 {:.1}  p95 {:.1}  max {:.1}",
        lats[lats.len() / 2],
        lats[(lats.len() * 95 / 100).min(lats.len() - 1)],
        lats.last().unwrap()
    );
    let qw = metrics.queue_wait_percentiles();
    let tt = metrics.ttft_percentiles();
    let tp = metrics.tpot_percentiles();
    println!(
        "queue wait ms: p50 {:.1}  p95 {:.1}  p99 {:.1}   ttft ms: p50 {:.1}  p95 {:.1}  p99 {:.1}   \
         tpot ms: p50 {:.1}  p95 {:.1}  p99 {:.1}",
        qw.p50, qw.p95, qw.p99, tt.p50, tt.p95, tt.p99, tp.p50, tp.p95, tp.p99
    );
    let occ = metrics.batch_occupancy_percentiles();
    println!(
        "decode batch occupancy: {:.1} rows/step mean ({:.1} seqs/step)  p50 {:.0}  p95 {:.0} over {} fused steps",
        metrics.mean_batch_rows(),
        metrics.mean_batch_seqs(),
        occ.p50,
        occ.p95,
        metrics.batch_steps.load(std::sync::atomic::Ordering::Relaxed)
    );
    if let Some(kv) = metrics.kv() {
        println!(
            "kv pool: {} x {}-token blocks ({}, {:.1} MiB cap, peak {:.1} MiB resident), peak \
             utilization {:.0}% | shared-block hit rate {:.0}% ({} of {} prompt blocks) | cow {} \
             | preempted {} | unused tail returned {}",
            kv.n_blocks,
            kv.block_size,
            kv.mode,
            kv.capacity_bytes as f64 / (1024.0 * 1024.0),
            (kv.peak_in_use * kv.block_bytes) as f64 / (1024.0 * 1024.0),
            kv.peak_utilization * 100.0,
            kv.shared_hit_rate * 100.0,
            kv.shared_attached,
            kv.prompt_blocks,
            kv.cow_copies,
            metrics.preempted.load(std::sync::atomic::Ordering::Relaxed),
            kv.unused_tail_returned,
        );
        if kv.spill_writes > 0 || kv.spilled_entries > 0 || kv.spill_faults > 0 {
            println!(
                "kv spill: {} entries / {} blocks / {:.1} MiB on disk | {} writes, {} faults, \
                 {} fault failures | {} evicted blocks",
                kv.spilled_entries,
                kv.spilled_blocks,
                kv.spilled_bytes as f64 / (1024.0 * 1024.0),
                kv.spill_writes,
                kv.spill_faults,
                kv.spill_fault_fails,
                kv.evicted_blocks,
            );
        }
    }
    if speculative {
        println!(
            "speculative: acceptance {:.0}% | {:.2} tokens/verify ({:.2} accepted) | {} verify \
             steps, {} draft steps | degraded {}",
            metrics.acceptance_rate() * 100.0,
            metrics.spec_tokens_per_verify(),
            metrics.accepted_per_verify(),
            metrics.verify_steps.load(std::sync::atomic::Ordering::Relaxed),
            metrics.draft_steps.load(std::sync::atomic::Ordering::Relaxed),
            metrics.spec_degraded.load(std::sync::atomic::Ordering::Relaxed),
        );
        for kv in metrics.draft_kv() {
            println!(
                "draft kv pool: {} x {}-token blocks, peak utilization {:.0}%",
                kv.n_blocks,
                kv.block_size,
                kv.peak_utilization * 100.0
            );
        }
    }
    if let Some(path) = args.flags.get("trace-out") {
        write_trace_out(&metrics, path)?;
    }
    Ok(())
}

fn cmd_loadtest(args: &Args) -> Result<()> {
    use pquant::serve::loadgen::{self, Target, TraceConfig};

    // Trace shape: defaults form a sane bursty mix; every knob is a flag.
    let mut cfg = TraceConfig {
        seed: args.flag("seed", 0u64)?,
        n_requests: args.flag("requests", 64usize)?,
        rate: args.flag("rate", 200.0f64)?,
        burst_factor: args.flag("burst-factor", 4.0f64)?,
        burst_on_s: args.flag("burst-on", 0.15f64)?,
        burst_off_s: args.flag("burst-off", 0.35f64)?,
        shared_frac: args.flag("shared-frac", 0.4f64)?,
        shared_prefix_len: args.flag("shared-prefix", 16usize)?,
        draft_frac: args.flag("draft-frac", 0.0f64)?,
        spec_k: args.flag("spec-k", 4usize)?,
        max_retries: args.flag("max-retries", 8usize)?,
        ..TraceConfig::default()
    };
    if let Some(spec) = args.flags.get("prompt-lens") {
        cfg.prompt_lens = loadgen::parse_mixture(spec)?;
    }
    if let Some(spec) = args.flags.get("output-lens") {
        cfg.output_lens = loadgen::parse_mixture(spec)?;
    }
    let out_path = std::path::PathBuf::from(
        args.flag("out", "results/bench/loadgen.json".to_string())?,
    );

    // Target: a live HTTP endpoint, or an in-process engine stack built
    // with the same flags as `serve`.
    let (report, records) = if let Some(addr) = args.flags.get("http") {
        cfg.vocab = args.flag("vocab", cfg.vocab)?;
        if cfg.draft_frac > 0.0 {
            cfg.draft_model = Some(args.flag("draft-name", "draft".to_string())?);
        }
        loadgen::run_recorded(Target::Http(addr.clone()), &cfg)?
    } else {
        let stack = build_serve_stack(args)?;
        cfg.vocab = stack.vocab;
        if stack.speculative && cfg.draft_frac > 0.0 {
            cfg.draft_model = Some("draft".into());
        }
        let (report, records) = loadgen::run_recorded(Target::Engine(&stack.engine), &cfg)?;
        let metrics = stack.engine.shutdown();
        println!(
            "engine: {} completed, {} preempted | server-side tpot ms p50 {:.1} p95 {:.1}",
            metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
            metrics.preempted.load(std::sync::atomic::Ordering::Relaxed),
            metrics.tpot_percentiles().p50,
            metrics.tpot_percentiles().p95,
        );
        // Reconcile the report's KV snapshot (taken when the replay ended)
        // against the engine's own final counters: the run is drained, so
        // any drift means the two metering paths disagree.
        if let (Some(rkv), Some(skv)) = (&report.kv, metrics.kv()) {
            let ok = rkv.peak_in_use == skv.peak_in_use
                && rkv.evicted_blocks == skv.evicted_blocks
                && rkv.spill_writes == skv.spill_writes
                && rkv.spill_faults == skv.spill_faults;
            if ok {
                println!("kv reconcile: report matches server-side metrics");
            } else {
                println!(
                    "kv reconcile: MISMATCH (report peak {} evicted {} writes {} faults {} vs \
                     server {} {} {} {})",
                    rkv.peak_in_use,
                    rkv.evicted_blocks,
                    rkv.spill_writes,
                    rkv.spill_faults,
                    skv.peak_in_use,
                    skv.evicted_blocks,
                    skv.spill_writes,
                    skv.spill_faults,
                );
            }
        }
        (report, records)
    };

    println!(
        "loadtest: {} submitted, {} completed, {} rejected | {} x429 {} x503 | \
         {:.1} tokens/s | goodput {:.0}%",
        report.submitted,
        report.completed,
        report.rejected,
        report.retries_429,
        report.retries_503,
        report.throughput(),
        report.goodput() * 100.0
    );
    for t in &report.tiers {
        println!(
            "  {:12} prio {:>2}  n {:>4}  slo-met {:>4} ({:>3.0}%)  \
             ttft ms p50 {:.1} p95 {:.1} p99 {:.1} (target {:.0})  \
             tpot ms p50 {:.1} p95 {:.1} p99 {:.1} (target {:.0})",
            t.name,
            t.priority,
            t.n,
            t.slo_met,
            t.goodput * 100.0,
            t.ttft.p50,
            t.ttft.p95,
            t.ttft.p99,
            t.targets.ttft_ms,
            t.tpot.p50,
            t.tpot.p95,
            t.tpot.p99,
            t.targets.tpot_ms
        );
    }
    if let Some(kv) = &report.kv {
        println!(
            "kv: {} pool, {} blocks, high-water {} blocks ({:.0}%, {:.1} MiB of {:.1} MiB) | \
             shared hit rate {:.0}% | evicted {} | spill writes {} faults {} ({} blocks on disk)",
            kv.mode,
            kv.n_blocks,
            kv.peak_in_use,
            kv.peak_utilization * 100.0,
            kv.peak_resident_bytes as f64 / (1024.0 * 1024.0),
            kv.capacity_bytes as f64 / (1024.0 * 1024.0),
            kv.shared_hit_rate * 100.0,
            kv.evicted_blocks,
            kv.spill_writes,
            kv.spill_faults,
            kv.spilled_blocks,
        );
    }
    report.write(&out_path)?;
    println!("wrote {}", out_path.display());
    if let Some(p) = args.flags.get("out-jsonl") {
        let p = std::path::PathBuf::from(p);
        loadgen::write_jsonl(&records, &p)?;
        println!("wrote {} per-request records to {}", records.len(), p.display());
    }
    Ok(())
}

/// `repro obs-check` — prove the observability surfaces are well-formed:
/// the Prometheus exposition parses and agrees with the JSON snapshot,
/// and trace documents (live `/v1/trace/latest` or a `--trace-out` file)
/// validate as Chrome trace-event JSON. Used by the CI smoke lane.
fn cmd_obs_check(args: &Args) -> Result<()> {
    use pquant::obs::trace::validate_chrome_json;
    use pquant::util::json::Json;
    let mut did_anything = false;
    if let Some(path) = args.flags.get("trace") {
        did_anything = true;
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let j = Json::parse(text.trim()).with_context(|| format!("{path}: invalid JSON"))?;
        let sm = validate_chrome_json(&j).map_err(|e| anyhow!("{path}: {e}"))?;
        if sm.terminals == 0 {
            bail!("{path}: valid chrome trace but no terminal events (no request completed?)");
        }
        println!("{path}: valid chrome trace ({} events, {} terminals)", sm.events, sm.terminals);
    }
    if let Some(addr) = args.flags.get("http") {
        did_anything = true;
        // JSON snapshot first, Prometheus second: counters only grow, so
        // every cross-checked Prometheus value must be >= its JSON twin.
        let (code, body) = http_get(addr, "/v1/metrics", None)?;
        if code != 200 {
            bail!("GET /v1/metrics returned {code}");
        }
        let j = Json::parse(body.trim()).context("JSON metrics response")?;
        let (code, text) = http_get(addr, "/v1/metrics?format=prometheus", Some("text/plain"))?;
        if code != 200 {
            bail!("GET /v1/metrics?format=prometheus returned {code}");
        }
        let samples =
            pquant::obs::prom::parse_text(&text).map_err(|e| anyhow!("prometheus parse: {e}"))?;
        let mut checked = 0usize;
        if let Json::Obj(per_model) = &j {
            for (name, m) in per_model.iter() {
                if name == "http" {
                    continue;
                }
                let Some(jv) = m.opt("completed").and_then(|v| v.as_f64().ok()) else { continue };
                let pv = samples
                    .iter()
                    .find(|smp| {
                        smp.name == "pquant_requests_completed_total"
                            && smp.label("model") == Some(name.as_str())
                    })
                    .map(|smp| smp.value)
                    .ok_or_else(|| {
                        anyhow!("prometheus exposition missing requests_completed_total for {name}")
                    })?;
                if pv < jv {
                    bail!("completed count for {name} went backwards: json {jv}, prometheus {pv}");
                }
                checked += 1;
            }
        }
        if checked == 0 {
            bail!("no engines found to cross-check in the /v1/metrics response");
        }
        println!(
            "{addr}: metrics round-trip ok ({} prometheus samples, {checked} engines cross-checked)",
            samples.len()
        );
        // Health: an idle endpoint under obs-check must report ready with
        // a 200 — anything else means a worker died or pressure never
        // cleared, which the smoke lane should fail loudly on.
        let (code, body) = http_get(addr, "/v1/health", None)?;
        if code != 200 {
            bail!("GET /v1/health returned {code} (body: {})", body.trim());
        }
        let h = Json::parse(body.trim()).context("health response")?;
        match h.opt("status").and_then(|v| v.as_str().ok()) {
            Some(s) if s == "ready" => println!("{addr}: health ready"),
            other => bail!("GET /v1/health status {:?}, expected \"ready\"", other),
        }
        let (code, body) = http_get(addr, "/v1/trace/latest", None)?;
        if code == 200 {
            let j = Json::parse(body.trim()).context("trace/latest response")?;
            let sm = validate_chrome_json(&j).map_err(|e| anyhow!("trace/latest: {e}"))?;
            println!("{addr}: trace/latest valid ({} events, {} terminals)", sm.events, sm.terminals);
        } else {
            println!("{addr}: trace/latest -> {code} (tracing disabled or nothing completed yet)");
        }
    }
    if !did_anything {
        bail!("obs-check needs --http ADDR and/or --trace PATH\n{USAGE}");
    }
    Ok(())
}

/// Minimal blocking GET returning (status, body). Headers are discarded.
fn http_get(addr: &str, path: &str, accept: Option<&str>) -> Result<(u16, String)> {
    use std::io::{BufRead, BufReader, Read, Write};
    let mut stream = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connecting to {addr}"))?;
    stream.set_nodelay(true).ok();
    let accept_hdr = accept.map(|a| format!("Accept: {a}\r\n")).unwrap_or_default();
    write!(stream, "GET {path} HTTP/1.1\r\nHost: {addr}\r\n{accept_hdr}Connection: close\r\n\r\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| anyhow!("bad status line {line:?}"))?;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim_end().is_empty() {
            break;
        }
    }
    let mut body = String::new();
    reader.read_to_string(&mut body)?;
    Ok((status, body))
}

fn cmd_export(args: &Args) -> Result<()> {
    let config = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: repro export <config> <out.pqm>"))?;
    let out = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: repro export <config> <out.pqm>"))?;
    let (model, bpe) = if let Some(seed) = args.opt_flag::<u64>("random")? {
        // Toolchain-free path: pack a random model of a known config
        // (bench/demo/CI workloads where no trained checkpoint exists).
        let cfg = std::iter::once(pquant::config::smoke_config())
            .chain(pquant::config::paper_configs())
            .find(|c| &c.name == config)
            .ok_or_else(|| {
                anyhow!("--random needs a known config name (\"smoke\" or e.g. paper-300M-pquant)")
            })?;
        (pquant::infer::PackedModel::random(&cfg, seed), None)
    } else {
        let art = pquant::runtime::load_artifact(config)
            .with_context(|| format!("loading artifact {config}"))?;
        let state = match args.flags.get("checkpoint") {
            Some(ckpt) => pquant::runtime::TrainState::load_checkpoint(&art, ckpt)?,
            None => {
                println!("(no --checkpoint: exporting initial weights)");
                pquant::runtime::TrainState::initial(&art)?
            }
        };
        let model = pquant::infer::PackedModel::from_state(&art, &state)?;
        let bpe = if args.flags.contains_key("tokenizer") {
            let (_, bpe) = pquant::data::default_cached_dataset(art.manifest.config.vocab)?;
            Some(bpe)
        } else {
            None
        };
        (model, bpe)
    };
    let bytes = pquant::artifact::save_pqm(&model, bpe.as_ref(), out)?;
    println!(
        "wrote {out}: {:.2} MiB, {} variant, {} blocks{}",
        bytes as f64 / (1024.0 * 1024.0),
        model.cfg.variant.name(),
        model.blocks.len(),
        if bpe.is_some() { ", tokenizer embedded" } else { "" }
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: repro inspect <path.pqm>"))?;
    let info = pquant::artifact::inspect_pqm(path)?;
    let cfg = &info.config;
    println!(
        "{path}: .pqm v{}, {:.2} MiB, config {} ({}, {:.2}M params{})",
        info.version,
        info.file_bytes as f64 / (1024.0 * 1024.0),
        cfg.name,
        cfg.variant.name(),
        cfg.param_count() as f64 / 1e6,
        if info.has_tokenizer { ", tokenizer" } else { "" }
    );
    println!("{:12} {:>5} {:>12} {:>10}", "section", "index", "bytes", "crc32");
    for s in &info.sections {
        println!(
            "{:12} {:>5} {:>12} {:>10}",
            pquant::artifact::kind_name(s.kind),
            s.index,
            s.len,
            format!("{:08x}", s.crc)
        );
    }
    Ok(())
}

fn cmd_sensitivity(args: &Args) -> Result<()> {
    let config = args.require("config")?;
    let art = pquant::runtime::load_artifact(config)?;
    let runtime = pquant::runtime::Runtime::cpu()?;
    let state = match args.flags.get("checkpoint") {
        Some(ckpt) => pquant::runtime::TrainState::load_checkpoint(&art, ckpt)?,
        None => pquant::runtime::TrainState::initial(&art)?,
    };
    let (dataset, _) = pquant::data::default_cached_dataset(art.manifest.config.vocab)?;
    let fwd = runtime.compile(&art, "fwd")?;
    let seq = art.manifest.seq_len;
    let d = art.manifest.config.d_model;
    let mut rows = Vec::new();
    for w in 0..8 {
        let start = w * seq;
        if start + seq > dataset.valid.len() {
            break;
        }
        let toks: Vec<i32> = dataset.valid[start..start + seq].iter().map(|&t| t as i32).collect();
        let (_, ffn_in) = state.forward(&fwd, &toks)?;
        rows.extend(ffn_in);
    }
    let n_rows = rows.len() / d;
    let acts = pquant::tensor::Matrix::from_vec(n_rows, d, rows);
    let l = art.manifest.config.n_layers - 1;
    let wname = if art.manifest.config.variant == pquant::config::Variant::PQuant {
        format!("layers.{l}.ffn_up_1bit")
    } else {
        format!("layers.{l}.ffn_up")
    };
    let (shape, data) = state.param_by_name(&art, &wname)?;
    let w = pquant::tensor::Matrix::from_vec(shape[0], shape[1], data);
    let w_eff = pquant::sensitivity::dequantized_weights(&w, art.manifest.config.variant);
    let rep = pquant::sensitivity::sensitivity_map(&w_eff, &acts, 1e-2)?;
    println!(
        "{config} {wname}: gini {:.3}, log-kurtosis {:.2}, top1% mass {:.3}",
        rep.gini, rep.log_kurtosis, rep.top1pct_mass
    );
    println!("{}", pquant::sensitivity::ascii_heatmap(&rep.map, 20, 64));
    Ok(())
}

fn cmd_list() -> Result<()> {
    let root = pquant::runtime::artifacts_root();
    let mut names: Vec<String> = std::fs::read_dir(&root)
        .with_context(|| format!("reading {root:?} (run `make artifacts`)"))?
        .filter_map(|e| e.ok())
        .filter(|e| e.path().join("manifest.json").exists())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    println!("{:24} {:>10} {:>12} {:>6}", "config", "params", "activated", "bits");
    for name in names {
        if let Ok(art) = pquant::runtime::load_artifact(&name) {
            let m = &art.manifest;
            println!(
                "{:24} {:>9.2}M {:>11.2}M {:>6.2}",
                name,
                m.param_count as f64 / 1e6,
                m.activated_param_count as f64 / 1e6,
                m.avg_bits_per_weight
            );
        }
    }
    Ok(())
}
