//! The block pool: a fixed budget of KV blocks, reservation-based
//! admission, the prefix-share map, and the storage/eviction policy.
//!
//! Accounting model: every resident block carries exactly one charge
//! against the budget.  A sequence's [`Reservation`] charges its
//! worst-case block count at admission ([`BlockPool::admit`]) so a decode
//! can never run out of KV mid-flight; frozen prefix blocks transfer their
//! charge to the share map at registration
//! ([`BlockPool::register_prefix`]) and return it on eviction.  Buffers
//! themselves are allocated lazily and recycled on release, so the budget
//! is a ceiling, not a preallocation.
//!
//! Storage precision is a per-pool [`KvStorageMode`]: a block is a fixed
//! byte slab holding `block_size` f32 rows or `pack_factor ×` as many
//! quantized rows (see [`KvData`]).  Under budget pressure the pool sheds
//! share-map entries by a deterministic usage-weighted LRU (logical clock)
//! instead of dropping everything unused, optionally spilling shed entries
//! to disk ([`BlockPool::enable_spill`]) so a recurring prompt faults its
//! prefix back instead of recomputing it.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use super::seq::PagedSeq;
use super::spill::SpillTier;
use super::{KvError, KvPoolOptions, KvSegment, KvStorageMode};
use crate::obs::trace::{KvEventKind, TraceShared};
use crate::quant::quantize_i8_row_into;

/// Identity of the model weights a shared prefix was computed under:
/// (process-unique registry-entry id, generation).  Two prompts may only
/// share KV if their tags are equal — a hot-swap changes the tag, so
/// stale blocks can never serve a new generation, and the never-reused
/// entry id disambiguates a remove+re-register that resets the per-name
/// generation counter (an address would be vulnerable to allocator
/// reuse).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct PrefixTag(pub usize, pub u64);

/// One block's row storage in the pool's precision. Rows are written
/// whole (`write_row`) and read back as one [`KvSegment`]; quantized arms
/// carry one scale per row so copies (CoW, snapshots, spill round-trips)
/// are lossless moves of codes, never re-quantization.
pub(crate) enum KvData {
    F32 { k: Vec<f32>, v: Vec<f32> },
    Int8 { k: Vec<i8>, v: Vec<i8>, ks: Vec<f32>, vs: Vec<f32> },
}

impl KvData {
    pub(crate) fn alloc(mode: KvStorageMode, rows: usize, d: usize) -> KvData {
        match mode {
            KvStorageMode::F32 => {
                KvData::F32 { k: vec![0.0; rows * d], v: vec![0.0; rows * d] }
            }
            KvStorageMode::Int8 => KvData::Int8 {
                k: vec![0; rows * d],
                v: vec![0; rows * d],
                ks: vec![0.0; rows],
                vs: vec![0.0; rows],
            },
        }
    }

    /// An unallocated placeholder (used when moving data out of a page).
    pub(crate) fn empty(mode: KvStorageMode) -> KvData {
        KvData::alloc(mode, 0, 1)
    }

    pub(crate) fn is_allocated(&self) -> bool {
        match self {
            KvData::F32 { k, .. } => !k.is_empty(),
            KvData::Int8 { k, .. } => !k.is_empty(),
        }
    }

    /// Write one token row at row offset `off`, quantizing as needed.
    pub(crate) fn write_row(&mut self, off: usize, d: usize, krow: &[f32], vrow: &[f32]) {
        match self {
            KvData::F32 { k, v } => {
                k[off * d..(off + 1) * d].copy_from_slice(krow);
                v[off * d..(off + 1) * d].copy_from_slice(vrow);
            }
            KvData::Int8 { k, v, ks, vs } => {
                ks[off] = quantize_i8_row_into(krow, &mut k[off * d..(off + 1) * d]);
                vs[off] = quantize_i8_row_into(vrow, &mut v[off * d..(off + 1) * d]);
            }
        }
    }

    /// Copy the first `rows` rows of `src` losslessly (codes and scales
    /// move verbatim; no re-quantization). Modes must match.
    pub(crate) fn copy_rows(&mut self, src: &KvData, rows: usize, d: usize) {
        let n = rows * d;
        match (self, src) {
            (KvData::F32 { k, v }, KvData::F32 { k: sk, v: sv }) => {
                k[..n].copy_from_slice(&sk[..n]);
                v[..n].copy_from_slice(&sv[..n]);
            }
            (
                KvData::Int8 { k, v, ks, vs },
                KvData::Int8 { k: sk, v: sv, ks: sks, vs: svs },
            ) => {
                k[..n].copy_from_slice(&sk[..n]);
                v[..n].copy_from_slice(&sv[..n]);
                ks[..rows].copy_from_slice(&sks[..rows]);
                vs[..rows].copy_from_slice(&svs[..rows]);
            }
            _ => unreachable!("mixed storage modes inside one pool"),
        }
    }

    /// The first `filled` rows as one segment.
    pub(crate) fn seg(&self, filled: usize, d: usize) -> KvSegment<'_> {
        match self {
            KvData::F32 { k, v } => {
                KvSegment::F32 { k: &k[..filled * d], v: &v[..filled * d] }
            }
            KvData::Int8 { k, v, ks, vs } => KvSegment::Int8 {
                k: &k[..filled * d],
                v: &v[..filled * d],
                k_scale: &ks[..filled],
                v_scale: &vs[..filled],
            },
        }
    }
}

/// One frozen KV block: `filled` rows, immutable once built.
/// Shared across sequences behind `Arc`; writers copy first (CoW).
pub struct SharedBlock {
    pub(crate) data: KvData,
    pub(crate) filled: usize,
}

/// One writable block buffer.
pub(crate) struct KvBuf {
    pub(crate) data: KvData,
    pub(crate) filled: usize,
}

impl KvBuf {
    pub(crate) fn empty(mode: KvStorageMode) -> KvBuf {
        KvBuf { data: KvData::empty(mode), filled: 0 }
    }
}

/// A block-budget charge held against the pool; dropping it releases the
/// remaining charge. Sequences own one; the share map holds its charges
/// internally.
pub struct Reservation {
    pub(crate) pool: Arc<BlockPool>,
    pub(crate) charged: usize,
}

impl Drop for Reservation {
    fn drop(&mut self) {
        if self.charged > 0 {
            self.pool.release(self.charged);
            self.charged = 0;
        }
    }
}

/// Per layer, the `(block, filled)` pages attached from the share map.
pub(crate) type SharedPages = Vec<Vec<(Arc<SharedBlock>, usize)>>;

/// A granted admission: the reservation plus any shared prefix attached
/// from the map. Consumed by [`PagedSeq::new`]; dropping it un-admits
/// (the reservation releases, the shared blocks detach).
pub struct Admitted {
    pub(crate) shared_len: usize,
    /// Per layer: `(block, filled)` covering positions `[0, shared_len)`.
    pub(crate) layers: SharedPages,
    pub(crate) reservation: Reservation,
    /// Owned blocks the sequence may still materialize.
    pub(crate) allow: usize,
    pub(crate) tag: PrefixTag,
    /// Hit-rate contributions, counted only when the admission
    /// materializes into a [`PagedSeq`] — a bounced admission (e.g. the
    /// engine queue was full) must not skew the counters.
    pub(crate) metric_prompt_blocks: usize,
    pub(crate) metric_shared_blocks: usize,
}

impl Admitted {
    /// Prompt tokens covered by the attached shared prefix (prefill for
    /// these positions can be skipped).
    pub fn shared_len(&self) -> usize {
        self.shared_len
    }

    /// Blocks charged against the pool by this admission.
    pub fn blocks_reserved(&self) -> usize {
        self.reservation.charged
    }

    /// Weight identity the shared prefix (and future registrations) are
    /// keyed under.
    pub fn tag(&self) -> PrefixTag {
        self.tag
    }

    /// Re-key the admission (valid once sharing is discarded): new KV must
    /// be registered under the weights that will actually compute it.
    pub fn retag(&mut self, tag: PrefixTag) {
        debug_assert_eq!(self.shared_len, 0, "retag with shared blocks attached");
        self.tag = tag;
    }

    /// Detach the shared prefix (e.g. the serving generation moved between
    /// submit and admission) and reserve the delta so owned blocks can
    /// cover the whole prompt instead.
    pub fn discard_sharing(&mut self) -> Result<(), KvError> {
        if self.shared_len == 0 {
            return Ok(());
        }
        let pool = self.reservation.pool.clone();
        let delta = (self.shared_len / pool.block_size) * pool.n_layers;
        if delta > 0 {
            let mut st = pool.state.lock().unwrap();
            pool.reserve_locked(&mut st, delta)?;
        }
        self.reservation.charged += delta;
        self.allow += delta;
        self.layers.clear();
        self.shared_len = 0;
        self.metric_shared_blocks = 0;
        Ok(())
    }
}

struct ShareEntry {
    tag: PrefixTag,
    /// Prompt tokens covered (== key length).
    len: usize,
    /// Per layer, blocks covering `[0, len)`.
    layers: Vec<Vec<Arc<SharedBlock>>>,
    /// Logical-clock tick of the last admission that attached this entry
    /// (or its registration). Drives the deterministic LRU.
    last_used: u64,
    /// Admissions that attached this entry (usage weight).
    uses: u64,
    /// Monotone insertion id — the deterministic tie-break.
    seq_no: u64,
    /// Optional expiry: entries past their deadline shed first.
    deadline: Option<Instant>,
}

/// Map-side bookkeeping for one physical shared block: the map's own
/// handle plus how many [`ShareEntry`]s reference it (boundary entries of
/// one prompt share their leading blocks).
struct MapBlock {
    arc: Arc<SharedBlock>,
    refs: usize,
}

/// A prefix entry shed to the disk tier: everything needed to fault it
/// back (or to report it) without touching the file.
struct SpilledEntry {
    tag: PrefixTag,
    len: usize,
    path: PathBuf,
    /// Physical blocks the entry restores to (across layers).
    blocks: usize,
    /// On-disk payload bytes (spilled-bytes gauge).
    bytes: u64,
    /// Usage carried across the tier boundary so a faulted-back entry
    /// keeps its LRU weight.
    uses: u64,
}

struct PoolState {
    /// Unreserved budget, in blocks.
    available: usize,
    /// Low-water mark of `available` (peak pressure gauge).
    min_available: usize,
    /// Retired buffers awaiting reuse (bounded by `n_blocks`).
    recycle: Vec<KvBuf>,
    /// Prefix-token hash: prompt prefix -> frozen blocks.
    share: HashMap<Vec<u32>, ShareEntry>,
    /// Unique physical blocks held by the map, keyed by `Arc` pointer.
    map_blocks: HashMap<usize, MapBlock>,
    /// Prefix entries resident on disk only (the warm tier).
    spilled: HashMap<Vec<u32>, SpilledEntry>,
    /// Disk tier, when configured.
    spill: Option<SpillTier>,
    /// Logical admission clock (LRU recency source — deterministic, no
    /// wall time).
    clock: u64,
    /// Monotone entry counter (LRU tie-break).
    entry_seq: u64,
}

/// Entries above this are shed opportunistically even without budget
/// pressure, bounding share-map growth on long-running engines.
const SHARE_ENTRY_SOFT_CAP: usize = 1024;

/// Max on-disk spill stubs retained; beyond it the lowest-weight stubs
/// are dropped (files deleted) so the warm tier cannot grow unboundedly.
const SPILL_ENTRY_CAP: usize = 4096;

/// Max block-boundary entries registered per prompt. Long prompts get
/// evenly-spaced boundaries (always including the last) instead of one
/// per block, keeping registration work and key memory linear.
const MAX_BOUNDARY_ENTRIES: usize = 8;

/// Max prefix lengths probed per admission (the exact prompt plus the
/// largest block-aligned prefixes, descending). Bounds the hashing done
/// under the pool lock; a very long prompt only loses matches against
/// tiny prefixes of itself, which save little anyway.
const MAX_LOOKUP_CANDIDATES: usize = 32;

/// LRU usage weight: each attachment is worth this many clock ticks of
/// recency, capped so one hot entry cannot become unevictable forever.
const USAGE_WEIGHT: u64 = 16;
const USAGE_CAP: u64 = 64;

/// Snapshot of the pool's counters (all monotone except the gauges).
#[derive(Debug, Clone, Copy, Default)]
pub struct KvPoolStats {
    pub n_blocks: usize,
    /// Token rows per block under the pool's storage mode.
    pub block_size: usize,
    /// Storage precision of every block.
    pub mode: KvStorageMode,
    /// Bytes one block occupies (K + V rows, scales included).
    pub block_bytes: usize,
    /// `n_blocks * block_bytes` — the pool's RAM ceiling.
    pub capacity_bytes: usize,
    /// Blocks currently charged (sequence reservations + map-held).
    pub in_use: usize,
    /// `in_use * block_bytes`.
    pub resident_bytes: usize,
    /// `in_use / n_blocks`.
    pub utilization: f64,
    /// Most blocks ever charged at once (pressure high-water mark).
    pub peak_in_use: usize,
    /// `peak_in_use / n_blocks`.
    pub peak_utilization: f64,
    /// Physical prompt blocks attached from the share map (hits).
    pub shared_attached: usize,
    /// Physical prompt blocks across all admissions (hit denominator).
    pub prompt_blocks: usize,
    /// `shared_attached / prompt_blocks`.
    pub shared_hit_rate: f64,
    /// Copy-on-write block copies (shared prefix diverged into new tokens).
    pub cow_copies: usize,
    /// Map-held blocks reclaimed (evicted or spilled) under pressure.
    pub evicted_blocks: usize,
    /// Reserved blocks returned without ever being materialized (early
    /// stop-token finishes, cancellations).
    pub unused_tail_returned: usize,
    /// Live prefix entries in the share map.
    pub registered_prefixes: usize,
    /// Prefix entries resident on disk only (warm tier).
    pub spilled_entries: usize,
    /// Blocks those entries restore to.
    pub spilled_blocks: usize,
    /// On-disk bytes of the warm tier.
    pub spilled_bytes: u64,
    /// Entries written to the disk tier (monotone).
    pub spill_writes: usize,
    /// Entries faulted back from disk (monotone).
    pub spill_faults: usize,
    /// Fault attempts that failed (I/O error, CRC mismatch, or no budget
    /// to restore) and fell back to recompute.
    pub spill_fault_fails: usize,
}

/// Fixed budget of fixed-size KV blocks shared by every sequence of one
/// serving engine. See the module docs for the accounting model.
pub struct BlockPool {
    pub(crate) n_blocks: usize,
    /// Effective token rows per block (geometry `block_size` × the
    /// mode's pack factor).
    pub(crate) block_size: usize,
    pub(crate) n_layers: usize,
    pub(crate) d: usize,
    pub(crate) mode: KvStorageMode,
    /// Bytes one block occupies.
    block_bytes: usize,
    state: Mutex<PoolState>,
    shared_attached: AtomicUsize,
    prompt_blocks: AtomicUsize,
    cow_copies: AtomicUsize,
    evicted_blocks: AtomicUsize,
    unused_tail: AtomicUsize,
    spill_writes: AtomicUsize,
    spill_faults: AtomicUsize,
    spill_fault_fails: AtomicUsize,
    /// Trace recorder for pool-level KV events (CoW, spill, eviction).
    /// Attached once by the engine when tracing is enabled; every hook
    /// below is a skipped `if let` otherwise.
    obs: OnceLock<Arc<TraceShared>>,
}

impl std::fmt::Debug for BlockPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("BlockPool")
            .field("n_blocks", &s.n_blocks)
            .field("block_size", &s.block_size)
            .field("mode", &s.mode)
            .field("in_use", &s.in_use)
            .field("registered_prefixes", &s.registered_prefixes)
            .finish()
    }
}

impl BlockPool {
    /// A pool for models of `n_layers` layers and width `d`.
    pub fn new(opts: KvPoolOptions, n_layers: usize, d: usize) -> BlockPool {
        assert!(opts.n_blocks > 0 && opts.block_size > 0 && n_layers > 0 && d > 0);
        BlockPool {
            n_blocks: opts.n_blocks,
            block_size: opts.tokens_per_block(),
            n_layers,
            d,
            mode: opts.mode,
            block_bytes: opts.block_bytes(d),
            state: Mutex::new(PoolState {
                available: opts.n_blocks,
                min_available: opts.n_blocks,
                recycle: Vec::new(),
                share: HashMap::new(),
                map_blocks: HashMap::new(),
                spilled: HashMap::new(),
                spill: None,
                clock: 0,
                entry_seq: 0,
            }),
            shared_attached: AtomicUsize::new(0),
            prompt_blocks: AtomicUsize::new(0),
            cow_copies: AtomicUsize::new(0),
            evicted_blocks: AtomicUsize::new(0),
            unused_tail: AtomicUsize::new(0),
            spill_writes: AtomicUsize::new(0),
            spill_faults: AtomicUsize::new(0),
            spill_fault_fails: AtomicUsize::new(0),
            obs: OnceLock::new(),
        }
    }

    /// Attach a trace recorder: CoW copies, spill writes/faults, and
    /// evictions land on the recorder's pool-level KV track. First call
    /// wins; later calls are ignored.
    pub fn set_obs(&self, tr: Arc<TraceShared>) {
        let _ = self.obs.set(tr);
    }

    #[inline]
    fn kv_event(&self, kind: KvEventKind, n: u64) {
        if let Some(tr) = self.obs.get() {
            tr.kv_event(kind, n);
        }
    }

    /// Configure the disk spill tier: entries shed under pressure are
    /// written to `.pqm` section-container files under `dir` and faulted
    /// back when their prompt recurs. Idempotent; creates `dir`.
    pub fn enable_spill(&self, dir: impl AsRef<Path>) -> std::io::Result<()> {
        let tier = SpillTier::new(dir.as_ref())?;
        self.state.lock().unwrap().spill = Some(tier);
        Ok(())
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    /// Token rows per block (mode-effective).
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Model width (`d_model`) each block row holds.
    pub fn width(&self) -> usize {
        self.d
    }

    /// Storage precision of every block in this pool.
    pub fn mode(&self) -> KvStorageMode {
        self.mode
    }

    /// Unreserved blocks right now.
    pub fn available(&self) -> usize {
        self.state.lock().unwrap().available
    }

    /// Worst-case physical blocks for a sequence of `total_tokens`, with
    /// no prefix sharing.
    pub fn blocks_for(&self, total_tokens: usize) -> usize {
        total_tokens.div_ceil(self.block_size).max(1) * self.n_layers
    }

    /// Admit a sequence that will hold at most `total_tokens` positions
    /// (prompt + generation budget): look up the longest registered prefix
    /// of `prompt` under `tag` (faulting it back from the disk tier if it
    /// was spilled), attach its blocks, and reserve the rest of the worst
    /// case. Fails with [`KvError::OutOfBlocks`] — after shedding cold
    /// shared prefixes — when the budget cannot cover it.
    pub fn admit(
        self: &Arc<Self>,
        prompt: &[u32],
        total_tokens: usize,
        tag: PrefixTag,
    ) -> Result<Admitted, KvError> {
        self.admit_inner(prompt, total_tokens, tag, true)
    }

    /// Re-admission of a preempted sequence (prompt + already-emitted
    /// tokens): identical to [`BlockPool::admit`] but skips the
    /// prompt/hit counters, so recompute does not double-count sharing
    /// metrics.
    pub fn readmit(
        self: &Arc<Self>,
        prompt: &[u32],
        total_tokens: usize,
        tag: PrefixTag,
    ) -> Result<Admitted, KvError> {
        self.admit_inner(prompt, total_tokens, tag, false)
    }

    fn admit_inner(
        self: &Arc<Self>,
        prompt: &[u32],
        total_tokens: usize,
        tag: PrefixTag,
        count_metrics: bool,
    ) -> Result<Admitted, KvError> {
        let bs = self.block_size;
        let l = prompt.len();
        debug_assert!(total_tokens >= l);
        let logical = total_tokens.div_ceil(bs).max(1);
        let mut st = self.state.lock().unwrap();
        st.clock += 1;
        let now = st.clock;

        // Longest matching prefix: the exact prompt (partial-tail entry),
        // then block-aligned lengths descending. The match is capped at
        // `l - 1` so the final prompt position is always re-decoded — its
        // logits seed generation, and KV sharing caches K/V, not logits.
        let mut shared_len = 0usize;
        let mut shared_layers: SharedPages = Vec::new();
        if l > 1 {
            let mut cands: Vec<usize> = Vec::new();
            if l % bs != 0 {
                cands.push(l);
            }
            let mut j = l / bs;
            while j > 0 && cands.len() < MAX_LOOKUP_CANDIDATES {
                cands.push(j * bs);
                j -= 1;
            }
            for cand in cands {
                if !st.share.contains_key(&prompt[..cand]) {
                    // Warm tier: fault a spilled entry back before giving
                    // up on this candidate.
                    self.try_fault_locked(&mut st, &prompt[..cand], tag);
                }
                let Some(entry) = st.share.get_mut(&prompt[..cand]) else { continue };
                if entry.tag != tag || entry.len != cand {
                    continue;
                }
                let e = cand.min(l - 1);
                if e == 0 {
                    break;
                }
                entry.last_used = now;
                entry.uses += 1;
                let nb = e.div_ceil(bs);
                shared_layers = entry
                    .layers
                    .iter()
                    .map(|blocks| {
                        blocks
                            .iter()
                            .take(nb)
                            .enumerate()
                            .map(|(j, b)| (b.clone(), (e - j * bs).min(bs)))
                            .collect()
                    })
                    .collect();
                shared_len = e;
                break;
            }
        }

        let full_shared = shared_len / bs;
        let need = (logical - full_shared) * self.n_layers;
        self.reserve_locked(&mut st, need)?;
        drop(st);

        Ok(Admitted {
            shared_len,
            layers: shared_layers,
            reservation: Reservation { pool: self.clone(), charged: need },
            allow: need,
            tag,
            metric_prompt_blocks: if count_metrics { l.div_ceil(bs) * self.n_layers } else { 0 },
            metric_shared_blocks: if count_metrics && shared_len > 0 {
                shared_len.div_ceil(bs) * self.n_layers
            } else {
                0
            },
        })
    }

    /// Record one materialized admission's hit-rate contribution (called
    /// from [`PagedSeq::new`]).
    pub(crate) fn note_admitted(&self, prompt_blocks: usize, shared_blocks: usize) {
        if prompt_blocks > 0 {
            self.prompt_blocks.fetch_add(prompt_blocks, Ordering::Relaxed);
        }
        if shared_blocks > 0 {
            self.shared_attached.fetch_add(shared_blocks, Ordering::Relaxed);
        }
    }

    /// Reserve a raw block count (no prefix lookup). Used by tests and
    /// benches; the engine admits through [`BlockPool::admit`].
    pub fn try_reserve(self: &Arc<Self>, blocks: usize) -> Result<Reservation, KvError> {
        let mut st = self.state.lock().unwrap();
        self.reserve_locked(&mut st, blocks)?;
        Ok(Reservation { pool: self.clone(), charged: blocks })
    }

    /// Register `prompt`'s prefixes from a sequence whose prefill just
    /// completed; see [`BlockPool::register_prefix_deadline`].
    pub fn register_prefix(&self, prompt: &[u32], seq: &mut PagedSeq) {
        self.register_prefix_deadline(prompt, seq, None);
    }

    /// Register `prompt`'s prefixes from a sequence whose prefill just
    /// completed: freeze the fully-covered prompt blocks in place
    /// (transferring their budget charge to the map), insert one entry per
    /// block boundary, and — budget permitting — snapshot the partial tail
    /// under the full-prompt key. Idempotent per key; entries under a
    /// stale tag are replaced. An optional `deadline` marks the entry
    /// first-in-line for shedding once it passes (per-request control over
    /// how long a prefix is worth caching).
    pub fn register_prefix_deadline(
        &self,
        prompt: &[u32],
        seq: &mut PagedSeq,
        deadline: Option<Instant>,
    ) {
        let bs = self.block_size;
        let l = prompt.len();
        if l == 0 || seq.len() < l {
            return;
        }
        let full = l / bs;
        let tag = seq.tag;
        let mut st = self.state.lock().unwrap();
        if st.share.len() > SHARE_ENTRY_SOFT_CAP {
            let excess = st.share.len() - SHARE_ENTRY_SOFT_CAP;
            self.shed_entries_locked(&mut st, usize::MAX, Some(excess));
        }
        seq.freeze_blocks(full);
        let seq_ptrs = seq.shared_ptrs();

        // Evenly-spaced block boundaries (all of them for short prompts),
        // always ending at the last full block.
        let boundaries: Vec<usize> = if full <= MAX_BOUNDARY_ENTRIES {
            (1..=full).collect()
        } else {
            (1..=MAX_BOUNDARY_ENTRIES).map(|i| i * full / MAX_BOUNDARY_ENTRIES).collect()
        };
        for j in boundaries {
            let key = &prompt[..j * bs];
            match st.share.get(key) {
                Some(existing) if existing.tag == tag => continue,
                Some(existing) => {
                    // Stale tag (old generation). Only replace once no
                    // sequence is attached: removal returns the blocks'
                    // budget charges, which must not happen while the
                    // memory is still resident with a live user.
                    if !Self::entry_unused(&st.map_blocks, existing) {
                        continue;
                    }
                    self.remove_entry_locked(&mut st, key.to_vec());
                }
                None => {}
            }
            let mut layers: Vec<Vec<Arc<SharedBlock>>> = Vec::with_capacity(self.n_layers);
            for layer in 0..self.n_layers {
                let mut blocks = Vec::with_capacity(j);
                for b in 0..j {
                    match seq.shared_arc(layer, b) {
                        Some(arc) => blocks.push(arc),
                        // A non-frozen block here means the sequence
                        // geometry disagrees with the prompt; bail out.
                        None => return,
                    }
                }
                layers.push(blocks);
            }
            self.insert_entry_locked(
                &mut st,
                key.to_vec(),
                tag,
                j * bs,
                layers,
                deadline,
                Some((seq, &seq_ptrs)),
            );
        }

        // Partial tail: snapshot rows [full*bs, l) under the full-prompt
        // key so identical prompts share everything and diverge by CoW.
        let rem = l % bs;
        if rem > 0 {
            let key = prompt.to_vec();
            match st.share.get(&key) {
                Some(existing) if existing.tag == tag => return,
                Some(existing) => {
                    if !Self::entry_unused(&st.map_blocks, existing) {
                        return;
                    }
                    self.remove_entry_locked(&mut st, key.clone());
                }
                None => {}
            }
            if st.available < self.n_layers {
                return; // don't starve admissions to cache a tail
            }
            let mut layers: Vec<Vec<Arc<SharedBlock>>> = Vec::with_capacity(self.n_layers);
            for layer in 0..self.n_layers {
                let mut blocks = Vec::with_capacity(full + 1);
                for b in 0..full {
                    match seq.shared_arc(layer, b) {
                        Some(arc) => blocks.push(arc),
                        None => return,
                    }
                }
                let Some((src, filled)) = seq.block_data(layer, full) else { return };
                if filled < rem {
                    return;
                }
                let mut buf = self.take_buf_locked(&mut st);
                buf.data.copy_rows(src, rem, self.d);
                blocks.push(Arc::new(SharedBlock { data: buf.data, filled: rem }));
                layers.push(blocks);
            }
            st.available -= self.n_layers; // the map's charge for the snapshots
            st.min_available = st.min_available.min(st.available);
            self.insert_entry_locked(
                &mut st,
                key,
                tag,
                l,
                layers,
                deadline,
                Some((seq, &seq_ptrs)),
            );
        }
    }

    /// No sequence outside the map holds any of this entry's blocks.
    fn entry_unused(map_blocks: &HashMap<usize, MapBlock>, e: &ShareEntry) -> bool {
        e.layers.iter().flatten().all(|a| {
            let refs = map_blocks.get(&(Arc::as_ptr(a) as usize)).map_or(0, |m| m.refs);
            // Holders: the map's handle + `refs` entries. More means a
            // live sequence is attached.
            Arc::strong_count(a) <= 1 + refs
        })
    }

    /// Insert one entry, updating per-block map refs. A block entering the
    /// map for the first time from the sequence's frozen pages transfers
    /// one budget charge from the sequence's reservation to the map;
    /// blocks with no originating sequence (tail snapshots, faulted-back
    /// entries) were charged from `available` by the caller.
    #[allow(clippy::too_many_arguments)]
    fn insert_entry_locked(
        &self,
        st: &mut PoolState,
        key: Vec<u32>,
        tag: PrefixTag,
        len: usize,
        layers: Vec<Vec<Arc<SharedBlock>>>,
        deadline: Option<Instant>,
        seq: Option<(&mut PagedSeq, &std::collections::HashSet<usize>)>,
    ) {
        let mut seq = seq;
        for arc in layers.iter().flatten() {
            let ptr = Arc::as_ptr(arc) as usize;
            match st.map_blocks.get_mut(&ptr) {
                Some(mb) => mb.refs += 1,
                None => {
                    st.map_blocks.insert(ptr, MapBlock { arc: arc.clone(), refs: 1 });
                    if let Some((seq, seq_ptrs)) = seq.as_mut() {
                        if seq_ptrs.contains(&ptr) {
                            seq.transfer_charge();
                        }
                    }
                }
            }
        }
        // A fresh registration supersedes any stale disk copy.
        self.drop_spill_stub_locked(st, &key);
        st.entry_seq += 1;
        let entry = ShareEntry {
            tag,
            len,
            layers,
            last_used: st.clock,
            uses: 0,
            seq_no: st.entry_seq,
            deadline,
        };
        st.share.insert(key, entry);
    }

    /// Remove one entry and return how many physical blocks it freed.
    fn remove_entry_locked(&self, st: &mut PoolState, key: Vec<u32>) -> usize {
        let Some(entry) = st.share.remove(&key) else { return 0 };
        let mut freed = 0;
        for arc in entry.layers.into_iter().flatten() {
            let ptr = Arc::as_ptr(&arc) as usize;
            let gone = match st.map_blocks.get_mut(&ptr) {
                Some(mb) => {
                    mb.refs -= 1;
                    mb.refs == 0
                }
                None => false,
            };
            drop(arc);
            if gone {
                let mb = st.map_blocks.remove(&ptr).unwrap();
                st.available += 1;
                freed += 1;
                self.evicted_blocks.fetch_add(1, Ordering::Relaxed);
                if let Ok(sb) = Arc::try_unwrap(mb.arc) {
                    Self::push_recycle(
                        st,
                        self.n_blocks,
                        KvBuf { data: sb.data, filled: 0 },
                    );
                }
            }
        }
        if freed > 0 {
            self.kv_event(KvEventKind::Evict, freed as u64);
        }
        freed
    }

    /// Evict every share-map entry whose blocks no live sequence holds,
    /// returning their budget charges to `available`. Shedding under
    /// pressure is selective (usage-weighted LRU); this is the explicit
    /// drop-everything housekeeping hook (and the leak probe tests use:
    /// after a full drain plus eviction, `in_use` must be zero — anything
    /// left is a leaked request block). Does not touch the disk tier.
    pub fn evict_unused(&self) {
        let mut st = self.state.lock().unwrap();
        let keys: Vec<Vec<u32>> = {
            let share = &st.share;
            let map_blocks = &st.map_blocks;
            share
                .iter()
                .filter(|(_, e)| Self::entry_unused(map_blocks, e))
                .map(|(k, _)| k.clone())
                .collect()
        };
        for key in keys {
            self.remove_entry_locked(&mut st, key);
        }
    }

    /// Spill every currently-unused share-map entry to the disk tier
    /// (no-op without [`BlockPool::enable_spill`]). Explicit housekeeping
    /// hook — e.g. ahead of an anticipated burst of fresh prompts — and
    /// the test seam for the fault-back path.
    pub fn spill_unused(&self) {
        let mut st = self.state.lock().unwrap();
        if st.spill.is_none() {
            return;
        }
        let keys = self.unused_in_shed_order(&st);
        for key in keys {
            self.shed_one_locked(&mut st, key);
        }
    }

    /// Unused entries in deterministic shed order: expired deadlines
    /// first (oldest deadline first), then ascending usage-weighted
    /// recency score, insertion id as the tie-break.
    fn unused_in_shed_order(&self, st: &PoolState) -> Vec<Vec<u32>> {
        let now = Instant::now();
        let mut scored: Vec<(bool, u64, u64, Vec<u32>)> = st
            .share
            .iter()
            .filter(|(_, e)| Self::entry_unused(&st.map_blocks, e))
            .map(|(k, e)| {
                let expired = e.deadline.is_some_and(|d| d <= now);
                let score = e.last_used.saturating_add(USAGE_WEIGHT * e.uses.min(USAGE_CAP));
                (!expired, score, e.seq_no, k.clone())
            })
            .collect();
        scored.sort();
        scored.into_iter().map(|(_, _, _, k)| k).collect()
    }

    /// Shed one entry: spill it to disk when a tier is configured (and
    /// the write succeeds), plain-evict otherwise. Returns blocks freed.
    fn shed_one_locked(&self, st: &mut PoolState, key: Vec<u32>) -> usize {
        if st.spill.is_some() {
            let written = {
                let Some(entry) = st.share.get(&key) else { return 0 };
                let tier = st.spill.as_ref().unwrap();
                tier.write_entry(
                    &key,
                    entry.tag,
                    entry.len,
                    self.mode,
                    self.block_size,
                    self.d,
                    &entry.layers,
                )
            };
            if let Ok((path, bytes)) = written {
                let entry = st.share.get(&key).unwrap();
                let blocks: usize = entry.layers.iter().map(|l| l.len()).sum();
                let stub = SpilledEntry {
                    tag: entry.tag,
                    len: entry.len,
                    path,
                    blocks,
                    bytes,
                    uses: entry.uses,
                };
                self.spill_writes.fetch_add(1, Ordering::Relaxed);
                self.kv_event(KvEventKind::SpillWrite, blocks as u64);
                self.insert_spill_stub_locked(st, key.clone(), stub);
                return self.remove_entry_locked(st, key);
            }
            // Fall through to plain eviction on a failed write.
        }
        self.remove_entry_locked(st, key)
    }

    fn insert_spill_stub_locked(&self, st: &mut PoolState, key: Vec<u32>, stub: SpilledEntry) {
        if st.spilled.len() >= SPILL_ENTRY_CAP {
            // Drop the least-used stub (tie-break: shorter key first, then
            // lexicographic — fully deterministic).
            if let Some(victim) = st
                .spilled
                .iter()
                .min_by_key(|(k, s)| (s.uses, k.len(), (*k).clone()))
                .map(|(k, _)| k.clone())
            {
                self.drop_spill_stub_locked(st, &victim);
            }
        }
        st.spilled.insert(key, stub);
    }

    fn drop_spill_stub_locked(&self, st: &mut PoolState, key: &[u32]) {
        if let Some(stub) = st.spilled.remove(key) {
            std::fs::remove_file(&stub.path).ok();
        }
    }

    /// Shed unused entries until `need_blocks` are free (or
    /// `max_entries` entries were shed). The under-pressure path.
    fn shed_entries_locked(
        &self,
        st: &mut PoolState,
        need_blocks: usize,
        max_entries: Option<usize>,
    ) {
        let keys = self.unused_in_shed_order(st);
        let mut shed = 0usize;
        for key in keys {
            if st.available >= need_blocks {
                break;
            }
            if max_entries.is_some_and(|m| shed >= m) {
                break;
            }
            self.shed_one_locked(st, key);
            shed += 1;
        }
    }

    /// Fault one spilled entry back into the share map if `key` matches a
    /// stub under `tag`. On any failure (I/O, CRC, geometry, or no budget
    /// for the restored blocks) the attempt degrades to a miss.
    fn try_fault_locked(&self, st: &mut PoolState, key: &[u32], tag: PrefixTag) {
        let matches = st
            .spilled
            .get(key)
            .is_some_and(|s| s.tag == tag && s.len == key.len());
        if !matches {
            return;
        }
        let stub = st.spilled.remove(key).unwrap();
        // Budget first: restoring must not overdraw the pool. Shedding
        // colder entries to make room is allowed (tier rotation).
        if self.reserve_locked(st, stub.blocks).is_err() {
            // Leave it on disk for a calmer moment.
            st.spilled.insert(key.to_vec(), stub);
            self.spill_fault_fails.fetch_add(1, Ordering::Relaxed);
            self.kv_event(KvEventKind::SpillFaultFail, 1);
            return;
        }
        let read = {
            let tier = st.spill.as_ref().expect("stub implies a tier");
            tier.read_entry(&stub.path, stub.tag, self.mode, self.block_size, self.d)
        };
        match read {
            Ok(layers) if layers.len() == self.n_layers => {
                let restored: usize = layers.iter().map(|l| l.len()).sum();
                debug_assert_eq!(restored, stub.blocks, "stub block count out of sync");
                std::fs::remove_file(&stub.path).ok();
                self.spill_faults.fetch_add(1, Ordering::Relaxed);
                self.kv_event(KvEventKind::SpillFault, restored as u64);
                let uses = stub.uses;
                self.insert_entry_locked(st, key.to_vec(), tag, stub.len, layers, None, None);
                if let Some(e) = st.share.get_mut(key) {
                    e.uses = uses;
                }
            }
            _ => {
                // Corrupted or unreadable: release the charge, drop the
                // stub and file — recompute is the backstop tier.
                st.available += stub.blocks;
                std::fs::remove_file(&stub.path).ok();
                self.spill_fault_fails.fetch_add(1, Ordering::Relaxed);
                self.kv_event(KvEventKind::SpillFaultFail, 1);
            }
        }
    }

    fn reserve_locked(&self, st: &mut PoolState, need: usize) -> Result<(), KvError> {
        // Chaos hook: a simulated allocation failure takes the same typed
        // OutOfBlocks exit real exhaustion does (no charge was made yet).
        // Guarded on `need > 0` so zero-cost reservations stay infallible.
        if need > 0 && crate::failpoint!("kv.reserve") {
            return Err(KvError::OutOfBlocks { needed: need, available: st.available });
        }
        if st.available < need {
            self.shed_entries_locked(st, need, None);
        }
        if st.available < need {
            return Err(KvError::OutOfBlocks { needed: need, available: st.available });
        }
        st.available -= need;
        st.min_available = st.min_available.min(st.available);
        Ok(())
    }

    pub(crate) fn release(&self, blocks: usize) {
        let mut st = self.state.lock().unwrap();
        st.available += blocks;
        debug_assert!(st.available <= self.n_blocks, "over-released KV blocks");
    }

    pub(crate) fn take_buf(&self) -> KvBuf {
        let mut st = self.state.lock().unwrap();
        self.take_buf_locked(&mut st)
    }

    fn take_buf_locked(&self, st: &mut PoolState) -> KvBuf {
        match st.recycle.pop() {
            Some(mut b) => {
                b.filled = 0;
                b
            }
            None => KvBuf {
                data: KvData::alloc(self.mode, self.block_size, self.d),
                filled: 0,
            },
        }
    }

    pub(crate) fn recycle(&self, bufs: Vec<KvBuf>) {
        if bufs.is_empty() {
            return;
        }
        let mut st = self.state.lock().unwrap();
        for b in bufs {
            Self::push_recycle(&mut st, self.n_blocks, b);
        }
    }

    /// Recycle a single buffer without building a `Vec` — the speculative
    /// rollback path truncates a few blocks per round and must not
    /// allocate to return them.
    pub(crate) fn recycle_one(&self, buf: KvBuf) {
        let mut st = self.state.lock().unwrap();
        Self::push_recycle(&mut st, self.n_blocks, buf);
    }

    fn push_recycle(st: &mut PoolState, cap: usize, mut b: KvBuf) {
        if st.recycle.len() < cap && b.data.is_allocated() {
            b.filled = 0;
            st.recycle.push(b);
        }
    }

    pub(crate) fn note_cow(&self) {
        self.cow_copies.fetch_add(1, Ordering::Relaxed);
        self.kv_event(KvEventKind::CowCopy, 1);
    }

    pub(crate) fn note_unused_tail(&self, blocks: usize) {
        self.unused_tail.fetch_add(blocks, Ordering::Relaxed);
    }

    pub fn stats(&self) -> KvPoolStats {
        let (available, min_available, registered, spilled_entries, spilled_blocks, spilled_bytes) = {
            let st = self.state.lock().unwrap();
            (
                st.available,
                st.min_available,
                st.share.len(),
                st.spilled.len(),
                st.spilled.values().map(|s| s.blocks).sum::<usize>(),
                st.spilled.values().map(|s| s.bytes).sum::<u64>(),
            )
        };
        let in_use = self.n_blocks - available;
        let peak_in_use = self.n_blocks - min_available;
        let shared = self.shared_attached.load(Ordering::Relaxed);
        let prompt = self.prompt_blocks.load(Ordering::Relaxed);
        KvPoolStats {
            n_blocks: self.n_blocks,
            block_size: self.block_size,
            mode: self.mode,
            block_bytes: self.block_bytes,
            capacity_bytes: self.n_blocks * self.block_bytes,
            in_use,
            resident_bytes: in_use * self.block_bytes,
            utilization: in_use as f64 / self.n_blocks as f64,
            peak_in_use,
            peak_utilization: peak_in_use as f64 / self.n_blocks as f64,
            shared_attached: shared,
            prompt_blocks: prompt,
            shared_hit_rate: if prompt == 0 { 0.0 } else { shared as f64 / prompt as f64 },
            cow_copies: self.cow_copies.load(Ordering::Relaxed),
            evicted_blocks: self.evicted_blocks.load(Ordering::Relaxed),
            unused_tail_returned: self.unused_tail.load(Ordering::Relaxed),
            registered_prefixes: registered,
            spilled_entries,
            spilled_blocks,
            spilled_bytes,
            spill_writes: self.spill_writes.load(Ordering::Relaxed),
            spill_faults: self.spill_faults.load(Ordering::Relaxed),
            spill_fault_fails: self.spill_fault_fails.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvStore;

    fn pool(n_blocks: usize, bs: usize) -> Arc<BlockPool> {
        Arc::new(BlockPool::new(
            KvPoolOptions { n_blocks, block_size: bs, mode: KvStorageMode::F32 },
            2, // layers
            4, // d
        ))
    }

    #[test]
    fn reserve_and_release_round_trip() {
        let p = pool(10, 4);
        assert_eq!(p.available(), 10);
        let r = p.try_reserve(6).unwrap();
        assert_eq!(p.available(), 4);
        assert!(matches!(
            p.try_reserve(5),
            Err(KvError::OutOfBlocks { needed: 5, available: 4 })
        ));
        drop(r);
        assert_eq!(p.available(), 10);
    }

    #[test]
    fn admit_reserves_worst_case_without_sharing() {
        let p = pool(64, 4);
        // 10 tokens over block_size 4 -> 3 logical blocks x 2 layers = 6.
        let a = p.admit(&[1, 2, 3], 10, PrefixTag::default()).unwrap();
        assert_eq!(a.blocks_reserved(), 6);
        assert_eq!(a.shared_len(), 0);
        assert_eq!(p.available(), 58);
        drop(a);
        assert_eq!(p.available(), 64);
    }

    #[test]
    fn blocks_for_matches_admit_math() {
        let p = pool(64, 4);
        assert_eq!(p.blocks_for(10), 6);
        assert_eq!(p.blocks_for(8), 4);
        assert_eq!(p.blocks_for(0), 2);
    }

    #[test]
    fn stats_track_utilization() {
        let p = pool(8, 4);
        let _r = p.try_reserve(2).unwrap();
        let s = p.stats();
        assert_eq!(s.in_use, 2);
        assert!((s.utilization - 0.25).abs() < 1e-9);
        assert_eq!(s.registered_prefixes, 0);
        assert_eq!(s.resident_bytes, 2 * s.block_bytes);
        assert_eq!(s.capacity_bytes, 8 * s.block_bytes);
    }

    #[test]
    fn int8_blocks_pack_4x_the_tokens_of_f32() {
        let f32_pool = pool(16, 4);
        let i8_pool = Arc::new(BlockPool::new(
            KvPoolOptions { n_blocks: 16, block_size: 4, mode: KvStorageMode::Int8 },
            2,
            4,
        ));
        // 16 tokens: f32 needs 4 blocks/layer, int8 packs them into 1.
        assert_eq!(f32_pool.blocks_for(16), 8);
        assert_eq!(i8_pool.blocks_for(16), 2);
        // Under the same block budget, int8 admits 4x the sequences.
        let mut held = Vec::new();
        let count = |p: &Arc<BlockPool>, held: &mut Vec<Reservation>| {
            let mut n = 0;
            while let Ok(r) = p.try_reserve(p.blocks_for(16)) {
                held.push(r);
                n += 1;
            }
            n
        };
        let f = count(&f32_pool, &mut held);
        let i = count(&i8_pool, &mut held);
        assert_eq!(f, 2);
        assert_eq!(i, 8);
        assert!(i >= 4 * f);
    }

    #[test]
    fn int8_rows_round_trip_within_quant_error() {
        let p = Arc::new(BlockPool::new(
            KvPoolOptions { n_blocks: 8, block_size: 4, mode: KvStorageMode::Int8 },
            1,
            4,
        ));
        let adm = p.admit(&[], 4, PrefixTag::default()).unwrap();
        let mut seq = PagedSeq::new(&p, adm);
        let krow = [1.0f32, -0.5, 0.25, 0.9];
        let vrow = [0.1f32, 0.2, -0.3, 0.4];
        seq.layer(0).push(&krow, &vrow).unwrap();
        let mut got = Vec::new();
        seq.layer(0).for_each_seg(&mut |seg| {
            if let KvSegment::Int8 { k, k_scale, .. } = seg {
                for (i, &q) in k.iter().enumerate() {
                    got.push((q as f32 / k_scale[0], krow[i]));
                }
            } else {
                panic!("int8 pool must yield int8 segments");
            }
        });
        assert_eq!(got.len(), 4);
        for (deq, orig) in got {
            assert!((deq - orig).abs() <= 1.0 / 127.0 + 1e-6, "{deq} vs {orig}");
        }
    }

    #[test]
    fn shed_order_is_usage_weighted_lru() {
        // Three registered prefixes; B is touched more often than A and C,
        // so under pressure A and C go first, in recency order.
        let p = pool(64, 4);
        let row = [0.5f32; 4];
        let mut register = |toks: &[u32]| {
            let adm = p.admit(toks, toks.len(), PrefixTag::default()).unwrap();
            let mut seq = PagedSeq::new(&p, adm);
            for _ in 0..toks.len() {
                for l in 0..2 {
                    seq.layer(l).push(&row, &row).unwrap();
                }
            }
            p.register_prefix(toks, &mut seq);
        };
        let a: Vec<u32> = (0..4).collect();
        let b: Vec<u32> = (10..14).collect();
        let c: Vec<u32> = (20..24).collect();
        register(&a);
        register(&b);
        register(&c);
        // Touch B twice (usage weight) and A once (recency).
        for _ in 0..2 {
            drop(p.admit(&[10, 11, 12, 13, 99], 6, PrefixTag::default()).unwrap());
        }
        drop(p.admit(&[0, 1, 2, 3, 99], 6, PrefixTag::default()).unwrap());
        let st = p.state.lock().unwrap();
        let order = p.unused_in_shed_order(&st);
        drop(st);
        assert_eq!(order.len(), 3);
        assert_eq!(order[0], c, "least-recent, least-used entry sheds first");
        assert_eq!(order[2], b, "most-used entry sheds last");
    }

    #[test]
    fn expired_deadline_sheds_first_despite_recent_use() {
        let p = pool(64, 4);
        let row = [0.5f32; 4];
        let mut register = |toks: &[u32], deadline: Option<Instant>| {
            let adm = p.admit(toks, toks.len(), PrefixTag::default()).unwrap();
            let mut seq = PagedSeq::new(&p, adm);
            for _ in 0..toks.len() {
                for l in 0..2 {
                    seq.layer(l).push(&row, &row).unwrap();
                }
            }
            p.register_prefix_deadline(toks, &mut seq, deadline);
        };
        let a: Vec<u32> = (0..4).collect();
        let b: Vec<u32> = (10..14).collect();
        register(&a, None);
        // B expired in the past but is used constantly. (checked_sub:
        // Instant can't represent times before boot on a fresh machine.)
        let past = Instant::now()
            .checked_sub(std::time::Duration::from_secs(3600))
            .unwrap_or_else(Instant::now);
        register(&b, Some(past));
        for _ in 0..3 {
            drop(p.admit(&[10, 11, 12, 13, 99], 6, PrefixTag::default()).unwrap());
        }
        let st = p.state.lock().unwrap();
        let order = p.unused_in_shed_order(&st);
        drop(st);
        assert_eq!(order[0], b, "expired entries shed before live ones");
    }
}
