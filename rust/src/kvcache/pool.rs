//! The block pool: a fixed budget of KV blocks, reservation-based
//! admission, and the prefix-share map.
//!
//! Accounting model: every resident block carries exactly one charge
//! against the budget.  A sequence's [`Reservation`] charges its
//! worst-case block count at admission ([`BlockPool::admit`]) so a decode
//! can never run out of KV mid-flight; frozen prefix blocks transfer their
//! charge to the share map at registration
//! ([`BlockPool::register_prefix`]) and return it on eviction.  Buffers
//! themselves are allocated lazily and recycled on release, so the budget
//! is a ceiling, not a preallocation.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::seq::PagedSeq;
use super::{KvError, KvPoolOptions};

/// Identity of the model weights a shared prefix was computed under:
/// (process-unique registry-entry id, generation).  Two prompts may only
/// share KV if their tags are equal — a hot-swap changes the tag, so
/// stale blocks can never serve a new generation, and the never-reused
/// entry id disambiguates a remove+re-register that resets the per-name
/// generation counter (an address would be vulnerable to allocator
/// reuse).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct PrefixTag(pub usize, pub u64);

/// One frozen KV block: `filled` rows of K and V, immutable once built.
/// Shared across sequences behind `Arc`; writers copy first (CoW).
pub struct SharedBlock {
    pub(crate) k: Vec<f32>,
    pub(crate) v: Vec<f32>,
    pub(crate) filled: usize,
}

/// One writable block buffer (`block_size * d` floats for each of K, V).
pub(crate) struct KvBuf {
    pub(crate) k: Vec<f32>,
    pub(crate) v: Vec<f32>,
    pub(crate) filled: usize,
}

impl KvBuf {
    pub(crate) fn empty() -> KvBuf {
        KvBuf { k: Vec::new(), v: Vec::new(), filled: 0 }
    }
}

/// A block-budget charge held against the pool; dropping it releases the
/// remaining charge. Sequences own one; the share map holds its charges
/// internally.
pub struct Reservation {
    pub(crate) pool: Arc<BlockPool>,
    pub(crate) charged: usize,
}

impl Drop for Reservation {
    fn drop(&mut self) {
        if self.charged > 0 {
            self.pool.release(self.charged);
            self.charged = 0;
        }
    }
}

/// Per layer, the `(block, filled)` pages attached from the share map.
pub(crate) type SharedPages = Vec<Vec<(Arc<SharedBlock>, usize)>>;

/// A granted admission: the reservation plus any shared prefix attached
/// from the map. Consumed by [`PagedSeq::new`]; dropping it un-admits
/// (the reservation releases, the shared blocks detach).
pub struct Admitted {
    pub(crate) shared_len: usize,
    /// Per layer: `(block, filled)` covering positions `[0, shared_len)`.
    pub(crate) layers: SharedPages,
    pub(crate) reservation: Reservation,
    /// Owned blocks the sequence may still materialize.
    pub(crate) allow: usize,
    pub(crate) tag: PrefixTag,
    /// Hit-rate contributions, counted only when the admission
    /// materializes into a [`PagedSeq`] — a bounced admission (e.g. the
    /// engine queue was full) must not skew the counters.
    pub(crate) metric_prompt_blocks: usize,
    pub(crate) metric_shared_blocks: usize,
}

impl Admitted {
    /// Prompt tokens covered by the attached shared prefix (prefill for
    /// these positions can be skipped).
    pub fn shared_len(&self) -> usize {
        self.shared_len
    }

    /// Blocks charged against the pool by this admission.
    pub fn blocks_reserved(&self) -> usize {
        self.reservation.charged
    }

    /// Weight identity the shared prefix (and future registrations) are
    /// keyed under.
    pub fn tag(&self) -> PrefixTag {
        self.tag
    }

    /// Re-key the admission (valid once sharing is discarded): new KV must
    /// be registered under the weights that will actually compute it.
    pub fn retag(&mut self, tag: PrefixTag) {
        debug_assert_eq!(self.shared_len, 0, "retag with shared blocks attached");
        self.tag = tag;
    }

    /// Detach the shared prefix (e.g. the serving generation moved between
    /// submit and admission) and reserve the delta so owned blocks can
    /// cover the whole prompt instead.
    pub fn discard_sharing(&mut self) -> Result<(), KvError> {
        if self.shared_len == 0 {
            return Ok(());
        }
        let pool = self.reservation.pool.clone();
        let delta = (self.shared_len / pool.block_size) * pool.n_layers;
        if delta > 0 {
            let mut st = pool.state.lock().unwrap();
            pool.reserve_locked(&mut st, delta)?;
        }
        self.reservation.charged += delta;
        self.allow += delta;
        self.layers.clear();
        self.shared_len = 0;
        self.metric_shared_blocks = 0;
        Ok(())
    }
}

struct ShareEntry {
    tag: PrefixTag,
    /// Prompt tokens covered (== key length).
    len: usize,
    /// Per layer, blocks covering `[0, len)`.
    layers: Vec<Vec<Arc<SharedBlock>>>,
}

/// Map-side bookkeeping for one physical shared block: the map's own
/// handle plus how many [`ShareEntry`]s reference it (boundary entries of
/// one prompt share their leading blocks).
struct MapBlock {
    arc: Arc<SharedBlock>,
    refs: usize,
}

struct PoolState {
    /// Unreserved budget, in blocks.
    available: usize,
    /// Low-water mark of `available` (peak pressure gauge).
    min_available: usize,
    /// Retired buffers awaiting reuse (bounded by `n_blocks`).
    recycle: Vec<KvBuf>,
    /// Prefix-token hash: prompt prefix -> frozen blocks.
    share: HashMap<Vec<u32>, ShareEntry>,
    /// Unique physical blocks held by the map, keyed by `Arc` pointer.
    map_blocks: HashMap<usize, MapBlock>,
}

/// Entries above this are reclaimed opportunistically even without budget
/// pressure, bounding share-map growth on long-running engines.
const SHARE_ENTRY_SOFT_CAP: usize = 1024;

/// Max block-boundary entries registered per prompt. Long prompts get
/// evenly-spaced boundaries (always including the last) instead of one
/// per block, keeping registration work and key memory linear.
const MAX_BOUNDARY_ENTRIES: usize = 8;

/// Max prefix lengths probed per admission (the exact prompt plus the
/// largest block-aligned prefixes, descending). Bounds the hashing done
/// under the pool lock; a very long prompt only loses matches against
/// tiny prefixes of itself, which save little anyway.
const MAX_LOOKUP_CANDIDATES: usize = 32;

/// Snapshot of the pool's counters (all monotone except the gauges).
#[derive(Debug, Clone, Copy, Default)]
pub struct KvPoolStats {
    pub n_blocks: usize,
    pub block_size: usize,
    /// Blocks currently charged (sequence reservations + map-held).
    pub in_use: usize,
    /// `in_use / n_blocks`.
    pub utilization: f64,
    /// Most blocks ever charged at once (pressure high-water mark).
    pub peak_in_use: usize,
    /// `peak_in_use / n_blocks`.
    pub peak_utilization: f64,
    /// Physical prompt blocks attached from the share map (hits).
    pub shared_attached: usize,
    /// Physical prompt blocks across all admissions (hit denominator).
    pub prompt_blocks: usize,
    /// `shared_attached / prompt_blocks`.
    pub shared_hit_rate: f64,
    /// Copy-on-write block copies (shared prefix diverged into new tokens).
    pub cow_copies: usize,
    /// Map-held blocks reclaimed under pressure.
    pub evicted_blocks: usize,
    /// Reserved blocks returned without ever being materialized (early
    /// stop-token finishes, cancellations).
    pub unused_tail_returned: usize,
    /// Live prefix entries in the share map.
    pub registered_prefixes: usize,
}

/// Fixed budget of fixed-size KV blocks shared by every sequence of one
/// serving engine. See the module docs for the accounting model.
pub struct BlockPool {
    pub(crate) n_blocks: usize,
    pub(crate) block_size: usize,
    pub(crate) n_layers: usize,
    pub(crate) d: usize,
    state: Mutex<PoolState>,
    shared_attached: AtomicUsize,
    prompt_blocks: AtomicUsize,
    cow_copies: AtomicUsize,
    evicted_blocks: AtomicUsize,
    unused_tail: AtomicUsize,
}

impl std::fmt::Debug for BlockPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("BlockPool")
            .field("n_blocks", &s.n_blocks)
            .field("block_size", &s.block_size)
            .field("in_use", &s.in_use)
            .field("registered_prefixes", &s.registered_prefixes)
            .finish()
    }
}

impl BlockPool {
    /// A pool for models of `n_layers` layers and width `d`.
    pub fn new(opts: KvPoolOptions, n_layers: usize, d: usize) -> BlockPool {
        assert!(opts.n_blocks > 0 && opts.block_size > 0 && n_layers > 0 && d > 0);
        BlockPool {
            n_blocks: opts.n_blocks,
            block_size: opts.block_size,
            n_layers,
            d,
            state: Mutex::new(PoolState {
                available: opts.n_blocks,
                min_available: opts.n_blocks,
                recycle: Vec::new(),
                share: HashMap::new(),
                map_blocks: HashMap::new(),
            }),
            shared_attached: AtomicUsize::new(0),
            prompt_blocks: AtomicUsize::new(0),
            cow_copies: AtomicUsize::new(0),
            evicted_blocks: AtomicUsize::new(0),
            unused_tail: AtomicUsize::new(0),
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Model width (`d_model`) each block row holds.
    pub fn width(&self) -> usize {
        self.d
    }

    /// Unreserved blocks right now.
    pub fn available(&self) -> usize {
        self.state.lock().unwrap().available
    }

    /// Worst-case physical blocks for a sequence of `total_tokens`, with
    /// no prefix sharing.
    pub fn blocks_for(&self, total_tokens: usize) -> usize {
        total_tokens.div_ceil(self.block_size).max(1) * self.n_layers
    }

    /// Admit a sequence that will hold at most `total_tokens` positions
    /// (prompt + generation budget): look up the longest registered prefix
    /// of `prompt` under `tag`, attach its blocks, and reserve the rest of
    /// the worst case. Fails with [`KvError::OutOfBlocks`] — after
    /// evicting unused shared prefixes — when the budget cannot cover it.
    pub fn admit(
        self: &Arc<Self>,
        prompt: &[u32],
        total_tokens: usize,
        tag: PrefixTag,
    ) -> Result<Admitted, KvError> {
        self.admit_inner(prompt, total_tokens, tag, true)
    }

    /// Re-admission of a preempted sequence (prompt + already-emitted
    /// tokens): identical to [`BlockPool::admit`] but skips the
    /// prompt/hit counters, so recompute does not double-count sharing
    /// metrics.
    pub fn readmit(
        self: &Arc<Self>,
        prompt: &[u32],
        total_tokens: usize,
        tag: PrefixTag,
    ) -> Result<Admitted, KvError> {
        self.admit_inner(prompt, total_tokens, tag, false)
    }

    fn admit_inner(
        self: &Arc<Self>,
        prompt: &[u32],
        total_tokens: usize,
        tag: PrefixTag,
        count_metrics: bool,
    ) -> Result<Admitted, KvError> {
        let bs = self.block_size;
        let l = prompt.len();
        debug_assert!(total_tokens >= l);
        let logical = total_tokens.div_ceil(bs).max(1);
        let mut st = self.state.lock().unwrap();

        // Longest matching prefix: the exact prompt (partial-tail entry),
        // then block-aligned lengths descending. The match is capped at
        // `l - 1` so the final prompt position is always re-decoded — its
        // logits seed generation, and KV sharing caches K/V, not logits.
        let mut shared_len = 0usize;
        let mut shared_layers: SharedPages = Vec::new();
        if l > 1 {
            let mut cands: Vec<usize> = Vec::new();
            if l % bs != 0 {
                cands.push(l);
            }
            let mut j = l / bs;
            while j > 0 && cands.len() < MAX_LOOKUP_CANDIDATES {
                cands.push(j * bs);
                j -= 1;
            }
            for cand in cands {
                let Some(entry) = st.share.get(&prompt[..cand]) else { continue };
                if entry.tag != tag || entry.len != cand {
                    continue;
                }
                let e = cand.min(l - 1);
                if e == 0 {
                    break;
                }
                let nb = e.div_ceil(bs);
                shared_layers = entry
                    .layers
                    .iter()
                    .map(|blocks| {
                        blocks
                            .iter()
                            .take(nb)
                            .enumerate()
                            .map(|(j, b)| (b.clone(), (e - j * bs).min(bs)))
                            .collect()
                    })
                    .collect();
                shared_len = e;
                break;
            }
        }

        let full_shared = shared_len / bs;
        let need = (logical - full_shared) * self.n_layers;
        self.reserve_locked(&mut st, need)?;
        drop(st);

        Ok(Admitted {
            shared_len,
            layers: shared_layers,
            reservation: Reservation { pool: self.clone(), charged: need },
            allow: need,
            tag,
            metric_prompt_blocks: if count_metrics { l.div_ceil(bs) * self.n_layers } else { 0 },
            metric_shared_blocks: if count_metrics && shared_len > 0 {
                shared_len.div_ceil(bs) * self.n_layers
            } else {
                0
            },
        })
    }

    /// Record one materialized admission's hit-rate contribution (called
    /// from [`PagedSeq::new`]).
    pub(crate) fn note_admitted(&self, prompt_blocks: usize, shared_blocks: usize) {
        if prompt_blocks > 0 {
            self.prompt_blocks.fetch_add(prompt_blocks, Ordering::Relaxed);
        }
        if shared_blocks > 0 {
            self.shared_attached.fetch_add(shared_blocks, Ordering::Relaxed);
        }
    }

    /// Reserve a raw block count (no prefix lookup). Used by tests and
    /// benches; the engine admits through [`BlockPool::admit`].
    pub fn try_reserve(self: &Arc<Self>, blocks: usize) -> Result<Reservation, KvError> {
        let mut st = self.state.lock().unwrap();
        self.reserve_locked(&mut st, blocks)?;
        Ok(Reservation { pool: self.clone(), charged: blocks })
    }

    /// Register `prompt`'s prefixes from a sequence whose prefill just
    /// completed: freeze the fully-covered prompt blocks in place
    /// (transferring their budget charge to the map), insert one entry per
    /// block boundary, and — budget permitting — snapshot the partial tail
    /// under the full-prompt key. Idempotent per key; entries under a
    /// stale tag are replaced.
    pub fn register_prefix(&self, prompt: &[u32], seq: &mut PagedSeq) {
        let bs = self.block_size;
        let l = prompt.len();
        if l == 0 || seq.len() < l {
            return;
        }
        let full = l / bs;
        let tag = seq.tag;
        let mut st = self.state.lock().unwrap();
        if st.share.len() > SHARE_ENTRY_SOFT_CAP {
            self.evict_unused_locked(&mut st);
        }
        seq.freeze_blocks(full);
        let seq_ptrs = seq.shared_ptrs();

        // Evenly-spaced block boundaries (all of them for short prompts),
        // always ending at the last full block.
        let boundaries: Vec<usize> = if full <= MAX_BOUNDARY_ENTRIES {
            (1..=full).collect()
        } else {
            (1..=MAX_BOUNDARY_ENTRIES).map(|i| i * full / MAX_BOUNDARY_ENTRIES).collect()
        };
        for j in boundaries {
            let key = &prompt[..j * bs];
            match st.share.get(key) {
                Some(existing) if existing.tag == tag => continue,
                Some(existing) => {
                    // Stale tag (old generation). Only replace once no
                    // sequence is attached: removal returns the blocks'
                    // budget charges, which must not happen while the
                    // memory is still resident with a live user.
                    if !Self::entry_unused(&st.map_blocks, existing) {
                        continue;
                    }
                    self.remove_entry_locked(&mut st, key.to_vec());
                }
                None => {}
            }
            let mut layers: Vec<Vec<Arc<SharedBlock>>> = Vec::with_capacity(self.n_layers);
            for layer in 0..self.n_layers {
                let mut blocks = Vec::with_capacity(j);
                for b in 0..j {
                    match seq.shared_arc(layer, b) {
                        Some(arc) => blocks.push(arc),
                        // A non-frozen block here means the sequence
                        // geometry disagrees with the prompt; bail out.
                        None => return,
                    }
                }
                layers.push(blocks);
            }
            self.insert_entry_locked(&mut st, key.to_vec(), tag, j * bs, layers, seq, &seq_ptrs);
        }

        // Partial tail: snapshot rows [full*bs, l) under the full-prompt
        // key so identical prompts share everything and diverge by CoW.
        let rem = l % bs;
        if rem > 0 {
            let key = prompt.to_vec();
            match st.share.get(&key) {
                Some(existing) if existing.tag == tag => return,
                Some(existing) => {
                    if !Self::entry_unused(&st.map_blocks, existing) {
                        return;
                    }
                    self.remove_entry_locked(&mut st, key.clone());
                }
                None => {}
            }
            if st.available < self.n_layers {
                return; // don't starve admissions to cache a tail
            }
            let floats = bs * self.d;
            let mut layers: Vec<Vec<Arc<SharedBlock>>> = Vec::with_capacity(self.n_layers);
            for layer in 0..self.n_layers {
                let mut blocks = Vec::with_capacity(full + 1);
                for b in 0..full {
                    match seq.shared_arc(layer, b) {
                        Some(arc) => blocks.push(arc),
                        None => return,
                    }
                }
                let Some((src_k, src_v, filled)) = seq.block_rows(layer, full) else { return };
                if filled < rem {
                    return;
                }
                let mut buf = Self::take_buf_locked(&mut st, floats);
                buf.k[..rem * self.d].copy_from_slice(&src_k[..rem * self.d]);
                buf.v[..rem * self.d].copy_from_slice(&src_v[..rem * self.d]);
                blocks.push(Arc::new(SharedBlock { k: buf.k, v: buf.v, filled: rem }));
                layers.push(blocks);
            }
            st.available -= self.n_layers; // the map's charge for the snapshots
            st.min_available = st.min_available.min(st.available);
            self.insert_entry_locked(&mut st, key, tag, l, layers, seq, &seq_ptrs);
        }
    }

    /// No sequence outside the map holds any of this entry's blocks.
    fn entry_unused(map_blocks: &HashMap<usize, MapBlock>, e: &ShareEntry) -> bool {
        e.layers.iter().flatten().all(|a| {
            let refs = map_blocks.get(&(Arc::as_ptr(a) as usize)).map_or(0, |m| m.refs);
            // Holders: the map's handle + `refs` entries. More means a
            // live sequence is attached.
            Arc::strong_count(a) <= 1 + refs
        })
    }

    /// Insert one entry, updating per-block map refs. A block entering the
    /// map for the first time from the sequence's frozen pages transfers
    /// one budget charge from the sequence's reservation to the map.
    #[allow(clippy::too_many_arguments)]
    fn insert_entry_locked(
        &self,
        st: &mut PoolState,
        key: Vec<u32>,
        tag: PrefixTag,
        len: usize,
        layers: Vec<Vec<Arc<SharedBlock>>>,
        seq: &mut PagedSeq,
        seq_ptrs: &std::collections::HashSet<usize>,
    ) {
        for arc in layers.iter().flatten() {
            let ptr = Arc::as_ptr(arc) as usize;
            match st.map_blocks.get_mut(&ptr) {
                Some(mb) => mb.refs += 1,
                None => {
                    st.map_blocks.insert(ptr, MapBlock { arc: arc.clone(), refs: 1 });
                    // Transfer the charge for a block the sequence froze;
                    // snapshot blocks were charged from `available` above
                    // and are recognized by not belonging to the sequence.
                    if seq_ptrs.contains(&ptr) {
                        seq.transfer_charge();
                    }
                }
            }
        }
        st.share.insert(key, ShareEntry { tag, len, layers });
    }

    fn remove_entry_locked(&self, st: &mut PoolState, key: Vec<u32>) {
        let Some(entry) = st.share.remove(&key) else { return };
        for arc in entry.layers.into_iter().flatten() {
            let ptr = Arc::as_ptr(&arc) as usize;
            let gone = match st.map_blocks.get_mut(&ptr) {
                Some(mb) => {
                    mb.refs -= 1;
                    mb.refs == 0
                }
                None => false,
            };
            drop(arc);
            if gone {
                let mb = st.map_blocks.remove(&ptr).unwrap();
                st.available += 1;
                self.evicted_blocks.fetch_add(1, Ordering::Relaxed);
                if let Ok(sb) = Arc::try_unwrap(mb.arc) {
                    Self::push_recycle(st, self.n_blocks, KvBuf { k: sb.k, v: sb.v, filled: 0 });
                }
            }
        }
    }

    /// Evict every share-map entry whose blocks no live sequence holds,
    /// returning their budget charges to `available`. Admission already
    /// does this under pressure; this is the explicit housekeeping hook
    /// (and the leak probe tests use: after a full drain plus eviction,
    /// `in_use` must be zero — anything left is a leaked request block).
    pub fn evict_unused(&self) {
        let mut st = self.state.lock().unwrap();
        self.evict_unused_locked(&mut st);
    }

    /// Evict every entry whose blocks have no users outside the map.
    fn evict_unused_locked(&self, st: &mut PoolState) {
        let keys: Vec<Vec<u32>> = {
            let share = &st.share;
            let map_blocks = &st.map_blocks;
            share
                .iter()
                .filter(|(_, e)| Self::entry_unused(map_blocks, e))
                .map(|(k, _)| k.clone())
                .collect()
        };
        for key in keys {
            self.remove_entry_locked(st, key);
        }
    }

    fn reserve_locked(&self, st: &mut PoolState, need: usize) -> Result<(), KvError> {
        if st.available < need {
            self.evict_unused_locked(st);
        }
        if st.available < need {
            return Err(KvError::OutOfBlocks { needed: need, available: st.available });
        }
        st.available -= need;
        st.min_available = st.min_available.min(st.available);
        Ok(())
    }

    pub(crate) fn release(&self, blocks: usize) {
        let mut st = self.state.lock().unwrap();
        st.available += blocks;
        debug_assert!(st.available <= self.n_blocks, "over-released KV blocks");
    }

    pub(crate) fn take_buf(&self) -> KvBuf {
        let mut st = self.state.lock().unwrap();
        Self::take_buf_locked(&mut st, self.block_size * self.d)
    }

    fn take_buf_locked(st: &mut PoolState, floats: usize) -> KvBuf {
        match st.recycle.pop() {
            Some(mut b) => {
                b.filled = 0;
                b
            }
            None => KvBuf { k: vec![0.0; floats], v: vec![0.0; floats], filled: 0 },
        }
    }

    pub(crate) fn recycle(&self, bufs: Vec<KvBuf>) {
        if bufs.is_empty() {
            return;
        }
        let mut st = self.state.lock().unwrap();
        for b in bufs {
            Self::push_recycle(&mut st, self.n_blocks, b);
        }
    }

    /// Recycle a single buffer without building a `Vec` — the speculative
    /// rollback path truncates a few blocks per round and must not
    /// allocate to return them.
    pub(crate) fn recycle_one(&self, buf: KvBuf) {
        let mut st = self.state.lock().unwrap();
        Self::push_recycle(&mut st, self.n_blocks, buf);
    }

    fn push_recycle(st: &mut PoolState, cap: usize, mut b: KvBuf) {
        if st.recycle.len() < cap && !b.k.is_empty() {
            b.filled = 0;
            st.recycle.push(b);
        }
    }

    pub(crate) fn note_cow(&self) {
        self.cow_copies.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_unused_tail(&self, blocks: usize) {
        self.unused_tail.fetch_add(blocks, Ordering::Relaxed);
    }

    pub fn stats(&self) -> KvPoolStats {
        let (available, min_available, registered) = {
            let st = self.state.lock().unwrap();
            (st.available, st.min_available, st.share.len())
        };
        let in_use = self.n_blocks - available;
        let peak_in_use = self.n_blocks - min_available;
        let shared = self.shared_attached.load(Ordering::Relaxed);
        let prompt = self.prompt_blocks.load(Ordering::Relaxed);
        KvPoolStats {
            n_blocks: self.n_blocks,
            block_size: self.block_size,
            in_use,
            utilization: in_use as f64 / self.n_blocks as f64,
            peak_in_use,
            peak_utilization: peak_in_use as f64 / self.n_blocks as f64,
            shared_attached: shared,
            prompt_blocks: prompt,
            shared_hit_rate: if prompt == 0 { 0.0 } else { shared as f64 / prompt as f64 },
            cow_copies: self.cow_copies.load(Ordering::Relaxed),
            evicted_blocks: self.evicted_blocks.load(Ordering::Relaxed),
            unused_tail_returned: self.unused_tail.load(Ordering::Relaxed),
            registered_prefixes: registered,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(n_blocks: usize, bs: usize) -> Arc<BlockPool> {
        Arc::new(BlockPool::new(
            KvPoolOptions { n_blocks, block_size: bs },
            2, // layers
            4, // d
        ))
    }

    #[test]
    fn reserve_and_release_round_trip() {
        let p = pool(10, 4);
        assert_eq!(p.available(), 10);
        let r = p.try_reserve(6).unwrap();
        assert_eq!(p.available(), 4);
        assert!(matches!(
            p.try_reserve(5),
            Err(KvError::OutOfBlocks { needed: 5, available: 4 })
        ));
        drop(r);
        assert_eq!(p.available(), 10);
    }

    #[test]
    fn admit_reserves_worst_case_without_sharing() {
        let p = pool(64, 4);
        // 10 tokens over block_size 4 -> 3 logical blocks x 2 layers = 6.
        let a = p.admit(&[1, 2, 3], 10, PrefixTag::default()).unwrap();
        assert_eq!(a.blocks_reserved(), 6);
        assert_eq!(a.shared_len(), 0);
        assert_eq!(p.available(), 58);
        drop(a);
        assert_eq!(p.available(), 64);
    }

    #[test]
    fn blocks_for_matches_admit_math() {
        let p = pool(64, 4);
        assert_eq!(p.blocks_for(10), 6);
        assert_eq!(p.blocks_for(8), 4);
        assert_eq!(p.blocks_for(0), 2);
    }

    #[test]
    fn stats_track_utilization() {
        let p = pool(8, 4);
        let _r = p.try_reserve(2).unwrap();
        let s = p.stats();
        assert_eq!(s.in_use, 2);
        assert!((s.utilization - 0.25).abs() < 1e-9);
        assert_eq!(s.registered_prefixes, 0);
    }
}
