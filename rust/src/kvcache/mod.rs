//! Paged KV-cache subsystem: a fixed block budget under the whole serving
//! stack, with selectable storage precision and a disk spill tier.
//!
//! With 1-bit weights the KV cache — not the model — dominates serving
//! memory (the BitNet-style regime in PAPERS.md), so KV memory must be a
//! managed, metered resource rather than a per-request `Vec` sized to the
//! worst case.  This module provides:
//!
//! * [`BlockPool`] — owns a fixed budget of `n_blocks` fixed-size KV
//!   blocks (`block_size` tokens × `d_model` floats for K and V, per
//!   layer).  Admission reserves blocks up front
//!   ([`BlockPool::admit`]), so a sequence that was admitted can always
//!   finish — exhaustion surfaces as a recoverable
//!   [`KvError::OutOfBlocks`] at admission, never a worker panic.
//! * [`KvStorageMode`] — per-pool storage precision.  A block is a fixed
//!   byte slab: in [`KvStorageMode::F32`] it holds `block_size` f32 rows;
//!   in [`KvStorageMode::Int8`] the same slab holds `4 × block_size`
//!   per-row-absmax INT8 rows (γ from
//!   [`quantize_i8_row_into`](crate::quant::quantize_i8_row_into), one
//!   scale per row for K and V), so the same block budget admits ~4× the
//!   sequences.  Attention reads quantized rows through [`KvSegment`]
//!   without any staging copies.
//! * [`PagedSeq`] — one sequence's per-layer page tables mapping token
//!   positions to blocks.  Blocks are either owned (writable) or shared
//!   (frozen [`SharedBlock`]s behind `Arc`); writing into a shared block
//!   copies it first (copy-on-write on divergence).  Sequence length is
//!   **non-monotonic** under speculative decoding: [`PagedSeq::truncate`]
//!   rolls a rejected suffix back, returning whole blocks to the
//!   sequence's allowance with their buffers recycled through the pool
//!   (allocation-free in steady state).
//! * **Prefix sharing + tiering** — completed prefills register their
//!   block-aligned prompt prefixes in a hash over prompt tokens
//!   ([`BlockPool::register_prefix`]); later admissions with a matching
//!   prompt attach the frozen blocks and skip the covered prefill compute
//!   ([`Admitted::shared_len`]).  Entries are tagged with a
//!   [`PrefixTag`] (model generation identity) so a hot-swap can never
//!   leak stale KV into a new generation.  Under budget pressure the pool
//!   sheds entries by a deterministic usage-weighted LRU (logical clock,
//!   not wall time) rather than dropping everything unused; with a spill
//!   directory configured ([`BlockPool::enable_spill`]) shed entries are
//!   written to disk in the `.pqm` section-container format and faulted
//!   back (CRC-verified) when the prompt recurs — a warm tier between
//!   "resident" and "recompute".
//! * [`KvStore`] — the per-layer cache abstraction attention decodes
//!   against.  The contiguous [`KvCache`](crate::infer::KvCache) fast
//!   path and the paged [`PagedLayer`] both implement it, and both expose
//!   the cache as ordered contiguous [`KvSegment`]s, so in F32 mode the
//!   attention arithmetic (and therefore greedy output) is bit-identical
//!   across the two; Int8 mode dequantizes per element inside the same
//!   walk, with the divergence bounded by test.
//!
//! The serving [`Engine`](crate::serve::Engine) layers budgeted admission,
//! preemption and pool metrics on top; see `serve/engine.rs`.

pub mod pool;
pub mod seq;
pub mod spill;

pub use pool::{Admitted, BlockPool, KvPoolStats, PrefixTag, Reservation};
pub use seq::{PagedLayer, PagedSeq};

/// Recoverable KV-cache errors. These replace the seed's `assert!` overflow
/// panic: a cache that cannot grow fails the one request, not the worker.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KvError {
    /// The pool cannot cover a reservation (admission-time backpressure).
    OutOfBlocks { needed: usize, available: usize },
    /// A fixed-capacity contiguous cache is full (`cap` tokens).
    CacheOverflow { cap: usize },
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfBlocks { needed, available } => {
                write!(f, "KV pool exhausted: need {needed} blocks, {available} available")
            }
            KvError::CacheOverflow { cap } => {
                write!(f, "KV cache overflow: capacity {cap} tokens")
            }
        }
    }
}

impl std::error::Error for KvError {}

/// Storage precision of one pool's KV blocks.
///
/// A block is a fixed byte slab sized for `block_size` f32 rows; quantized
/// modes pack [`KvStorageMode::pack_factor`] × as many rows into the same
/// slab, so the *byte* budget of the pool is mode-independent while its
/// *token* capacity scales with the mode.  The packing is deliberately
/// row-granular (one scale per row, rows addressed by offset) so a
/// ternary/1-bit experiment mode can slot in as another arm later.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KvStorageMode {
    /// Full-precision rows: `d` f32s per row for each of K and V.
    #[default]
    F32,
    /// Per-row absmax INT8: `d` i8s + one f32 scale γ per row for each of
    /// K and V (dequantize with `x = q / γ`).  4× the rows per block.
    Int8,
}

impl KvStorageMode {
    /// Token rows a quantized block holds per f32 row of the same bytes.
    pub fn pack_factor(self) -> usize {
        match self {
            KvStorageMode::F32 => 1,
            KvStorageMode::Int8 => 4,
        }
    }

    /// Bytes one K row (or one V row) of width `d` occupies, including
    /// its per-row scale.
    pub fn row_bytes(self, d: usize) -> usize {
        match self {
            KvStorageMode::F32 => 4 * d,
            KvStorageMode::Int8 => d + 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KvStorageMode::F32 => "f32",
            KvStorageMode::Int8 => "int8",
        }
    }

    /// Parse a `--kv-mode` CLI value.
    pub fn parse(s: &str) -> Option<KvStorageMode> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "full" => Some(KvStorageMode::F32),
            "int8" | "i8" | "q8" => Some(KvStorageMode::Int8),
            _ => None,
        }
    }
}

impl std::fmt::Display for KvStorageMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Pool geometry knobs (engine-facing; layer count and width come from the
/// model config at [`BlockPool::new`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolOptions {
    /// Total physical blocks in the budget (per-layer granularity: one
    /// sequence of `t` tokens uses `ceil(t / tokens_per_block)` blocks per
    /// layer).
    pub n_blocks: usize,
    /// Tokens per block *at f32 width*; quantized modes pack
    /// `mode.pack_factor() × block_size` tokens into the same block bytes.
    pub block_size: usize,
    /// Storage precision of every block in the pool.
    pub mode: KvStorageMode,
}

impl Default for KvPoolOptions {
    fn default() -> Self {
        KvPoolOptions { n_blocks: 4096, block_size: 16, mode: KvStorageMode::F32 }
    }
}

impl KvPoolOptions {
    /// Token rows one block holds under this geometry's mode.
    pub fn tokens_per_block(&self) -> usize {
        self.block_size * self.mode.pack_factor()
    }

    /// Bytes one block occupies (K + V rows, scales included).
    pub fn block_bytes(&self, d: usize) -> usize {
        2 * self.tokens_per_block() * self.mode.row_bytes(d)
    }
}

/// One ordered slab of cached rows, in the pool's storage precision.
/// Quantized arms expose the raw codes plus per-row scales so consumers
/// dequantize in place (no staging buffers on the decode hot path).
#[derive(Clone, Copy)]
pub enum KvSegment<'a> {
    /// `rows × d` f32s for each of K and V.
    F32 { k: &'a [f32], v: &'a [f32] },
    /// `rows × d` i8 codes and `rows` scales γ for each of K and V;
    /// element `i` of row `r` dequantizes as `k[r*d + i] as f32 / k_scale[r]`.
    Int8 { k: &'a [i8], v: &'a [i8], k_scale: &'a [f32], v_scale: &'a [f32] },
}

impl KvSegment<'_> {
    /// Token rows this segment covers.
    pub fn rows(&self, d: usize) -> usize {
        match self {
            KvSegment::F32 { k, .. } => k.len() / d,
            KvSegment::Int8 { k, .. } => k.len() / d,
        }
    }
}

/// One layer's KV cache as attention sees it: append one row per decoded
/// token, read back the whole history as ordered contiguous segments.
///
/// Both implementations expose whole rows (multiples of `d` elements) in
/// position order, so a consumer that walks segments row-by-row performs
/// exactly the same arithmetic in the same order regardless of layout —
/// in F32 mode the paged path is bit-identical to the contiguous one by
/// construction; quantized modes perform the same walk over codes.
pub trait KvStore {
    /// Tokens currently cached.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append one token's K and V rows (`d` floats each). Recoverable:
    /// a full cache returns [`KvError`], it does not panic. Quantized
    /// stores quantize the row on the way in.
    fn push(&mut self, k: &[f32], v: &[f32]) -> Result<(), KvError>;

    /// Visit the ordered contiguous [`KvSegment`]s covering positions
    /// `[0, len)` without allocating — the decode hot path. Each segment
    /// holds a whole number of rows.
    fn for_each_seg<'a>(&'a self, f: &mut dyn FnMut(KvSegment<'a>));

    /// F32-only convenience walk kept for the bit-exactness tests and
    /// existing consumers; quantized segments are skipped (debug-asserted
    /// against, since mixing would silently drop rows).
    fn for_each_segment<'a>(&'a self, f: &mut dyn FnMut(&'a [f32], &'a [f32])) {
        self.for_each_seg(&mut |seg| match seg {
            KvSegment::F32 { k, v } => f(k, v),
            KvSegment::Int8 { .. } => {
                debug_assert!(false, "for_each_segment on a quantized store");
            }
        });
    }

    /// Allocating convenience view of the same walk (tests, inspection).
    fn segments(&self) -> Vec<(&[f32], &[f32])> {
        let mut segs = Vec::new();
        self.for_each_segment(&mut |k, v| segs.push((k, v)));
        segs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn errors_render_both_counts() {
        let e = KvError::OutOfBlocks { needed: 8, available: 3 };
        let s = format!("{e}");
        assert!(s.contains('8') && s.contains('3'), "{s}");
        assert!(format!("{}", KvError::CacheOverflow { cap: 4 }).contains('4'));
    }

    #[test]
    fn default_options_are_sane() {
        let o = KvPoolOptions::default();
        assert!(o.n_blocks > 0 && o.block_size > 0);
        assert_eq!(o.mode, KvStorageMode::F32);
    }

    #[test]
    fn mode_geometry_packs_4x_in_the_same_bytes() {
        let f32_opts = KvPoolOptions { n_blocks: 8, block_size: 16, mode: KvStorageMode::F32 };
        let i8_opts = KvPoolOptions { mode: KvStorageMode::Int8, ..f32_opts };
        assert_eq!(f32_opts.tokens_per_block(), 16);
        assert_eq!(i8_opts.tokens_per_block(), 64);
        let d = 128;
        // Same order of block bytes: int8 packs 4x the rows at ~1/4 the
        // row width (the per-row scale is the only overhead).
        assert_eq!(f32_opts.block_bytes(d), 2 * 16 * 4 * d);
        assert_eq!(i8_opts.block_bytes(d), 2 * 64 * (d + 4));
        let overhead = i8_opts.block_bytes(d) as f64 / f32_opts.block_bytes(d) as f64;
        assert!(overhead < 1.04, "scale overhead must stay small, got {overhead}");
    }

    #[test]
    fn mode_parse_round_trips() {
        for m in [KvStorageMode::F32, KvStorageMode::Int8] {
            assert_eq!(KvStorageMode::parse(m.name()), Some(m));
        }
        assert_eq!(KvStorageMode::parse("ternary"), None);
    }
}
