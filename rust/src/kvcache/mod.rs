//! Paged KV-cache subsystem: a fixed block budget under the whole serving
//! stack.
//!
//! With 1-bit weights the KV cache — not the model — dominates serving
//! memory (the BitNet-style regime in PAPERS.md), so KV memory must be a
//! managed, metered resource rather than a per-request `Vec` sized to the
//! worst case.  This module provides:
//!
//! * [`BlockPool`] — owns a fixed budget of `n_blocks` fixed-size KV
//!   blocks (`block_size` tokens × `d_model` floats for K and V, per
//!   layer).  Admission reserves blocks up front
//!   ([`BlockPool::admit`]), so a sequence that was admitted can always
//!   finish — exhaustion surfaces as a recoverable
//!   [`KvError::OutOfBlocks`] at admission, never a worker panic.
//! * [`PagedSeq`] — one sequence's per-layer page tables mapping token
//!   positions to blocks.  Blocks are either owned (writable) or shared
//!   (frozen [`SharedBlock`]s behind `Arc`); writing into a shared block
//!   copies it first (copy-on-write on divergence).  Sequence length is
//!   **non-monotonic** under speculative decoding: [`PagedSeq::truncate`]
//!   rolls a rejected suffix back, returning whole blocks to the
//!   sequence's allowance with their buffers recycled through the pool
//!   (allocation-free in steady state).
//! * **Prefix sharing** — completed prefills register their block-aligned
//!   prompt prefixes in a hash over prompt tokens
//!   ([`BlockPool::register_prefix`]); later admissions with a matching
//!   prompt attach the frozen blocks and skip the covered prefill compute
//!   ([`Admitted::shared_len`]).  Entries are tagged with a
//!   [`PrefixTag`] (model generation identity) so a hot-swap can never
//!   leak stale KV into a new generation.
//! * [`KvStore`] — the per-layer cache abstraction attention decodes
//!   against.  The contiguous [`KvCache`](crate::infer::KvCache) fast
//!   path and the paged [`PagedLayer`] both implement it, and both expose
//!   the cache as ordered contiguous segments, so the attention arithmetic
//!   (and therefore greedy output) is bit-identical across the two.
//!
//! The serving [`Engine`](crate::serve::Engine) layers budgeted admission,
//! preemption and pool metrics on top; see `serve/engine.rs`.

pub mod pool;
pub mod seq;

pub use pool::{Admitted, BlockPool, KvPoolStats, PrefixTag, Reservation};
pub use seq::{PagedLayer, PagedSeq};

/// Recoverable KV-cache errors. These replace the seed's `assert!` overflow
/// panic: a cache that cannot grow fails the one request, not the worker.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KvError {
    /// The pool cannot cover a reservation (admission-time backpressure).
    OutOfBlocks { needed: usize, available: usize },
    /// A fixed-capacity contiguous cache is full (`cap` tokens).
    CacheOverflow { cap: usize },
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfBlocks { needed, available } => {
                write!(f, "KV pool exhausted: need {needed} blocks, {available} available")
            }
            KvError::CacheOverflow { cap } => {
                write!(f, "KV cache overflow: capacity {cap} tokens")
            }
        }
    }
}

impl std::error::Error for KvError {}

/// Pool geometry knobs (engine-facing; layer count and width come from the
/// model config at [`BlockPool::new`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolOptions {
    /// Total physical blocks in the budget (per-layer granularity: one
    /// sequence of `t` tokens uses `ceil(t / block_size)` blocks per layer).
    pub n_blocks: usize,
    /// Tokens per block.
    pub block_size: usize,
}

impl Default for KvPoolOptions {
    fn default() -> Self {
        KvPoolOptions { n_blocks: 4096, block_size: 16 }
    }
}

/// One layer's KV cache as attention sees it: append one row per decoded
/// token, read back the whole history as ordered contiguous segments.
///
/// Both implementations expose whole rows (multiples of `d` floats) in
/// position order, so a consumer that walks segments row-by-row performs
/// exactly the same float ops in the same order regardless of layout —
/// the paged path is bit-identical to the contiguous one by construction.
pub trait KvStore {
    /// Tokens currently cached.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append one token's K and V rows (`d` floats each). Recoverable:
    /// a full cache returns [`KvError`], it does not panic.
    fn push(&mut self, k: &[f32], v: &[f32]) -> Result<(), KvError>;

    /// Visit the ordered contiguous `(k, v)` slabs covering positions
    /// `[0, len)` without allocating — the decode hot path. Each slab
    /// holds a whole number of rows.
    fn for_each_segment<'a>(&'a self, f: &mut dyn FnMut(&'a [f32], &'a [f32]));

    /// Allocating convenience view of the same walk (tests, inspection).
    fn segments(&self) -> Vec<(&[f32], &[f32])> {
        let mut segs = Vec::new();
        self.for_each_segment(&mut |k, v| segs.push((k, v)));
        segs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn errors_render_both_counts() {
        let e = KvError::OutOfBlocks { needed: 8, available: 3 };
        let s = format!("{e}");
        assert!(s.contains('8') && s.contains('3'), "{s}");
        assert!(format!("{}", KvError::CacheOverflow { cap: 4 }).contains('4'));
    }

    #[test]
    fn default_options_are_sane() {
        let o = KvPoolOptions::default();
        assert!(o.n_blocks > 0 && o.block_size > 0);
    }
}
