//! Per-sequence page tables over the block pool.
//!
//! A [`PagedSeq`] owns one page table per layer. Pages are either owned
//! buffers (writable, drawn from the sequence's reserved allowance) or
//! frozen [`SharedBlock`]s attached from the prefix map; writing into a
//! shared page copies it first (copy-on-write), which is how two requests
//! with the same prompt diverge into their own generations.
//!
//! Pages store rows in the pool's [`KvStorageMode`](super::KvStorageMode):
//! `push` quantizes on the way in, reads go out as [`KvSegment`]s in the
//! stored precision, and block-to-block copies (CoW, snapshots) move codes
//! and scales verbatim — a copied row is bit-identical to its source, so
//! sharing never compounds quantization error.

use std::sync::Arc;

use super::pool::{Admitted, BlockPool, KvBuf, Reservation, SharedBlock};
use super::{KvError, KvSegment, KvStore};

pub(crate) enum Page {
    Owned(KvBuf),
    Shared { blk: Arc<SharedBlock>, filled: usize },
}

impl Page {
    fn filled(&self) -> usize {
        match self {
            Page::Owned(b) => b.filled,
            Page::Shared { filled, .. } => *filled,
        }
    }
}

pub(crate) struct LayerPages {
    pub(crate) blocks: Vec<Page>,
    pub(crate) len: usize,
}

/// One sequence's paged KV across all layers of a model. Created from a
/// pool [`Admitted`]; dropping it recycles owned buffers and releases the
/// remaining reservation.
pub struct PagedSeq {
    pool: Arc<BlockPool>,
    layers: Vec<LayerPages>,
    pub(crate) reservation: Reservation,
    /// Owned blocks this sequence may still materialize (worst case was
    /// reserved up front, so `push` never races the pool).
    allow: usize,
    pub(crate) tag: super::PrefixTag,
    block_size: usize,
    d: usize,
}

impl PagedSeq {
    pub fn new(pool: &Arc<BlockPool>, admitted: Admitted) -> PagedSeq {
        let shared_len = admitted.shared_len;
        let layers: Vec<LayerPages> = if shared_len == 0 {
            (0..pool.n_layers()).map(|_| LayerPages { blocks: Vec::new(), len: 0 }).collect()
        } else {
            admitted
                .layers
                .into_iter()
                .map(|blocks| LayerPages {
                    blocks: blocks
                        .into_iter()
                        .map(|(blk, filled)| Page::Shared { blk, filled })
                        .collect(),
                    len: shared_len,
                })
                .collect()
        };
        debug_assert_eq!(layers.len(), pool.n_layers());
        // Hit-rate metrics count here — at materialization — so a bounced
        // admission (queue full, generation moved) never skews them.
        pool.note_admitted(admitted.metric_prompt_blocks, admitted.metric_shared_blocks);
        PagedSeq {
            pool: pool.clone(),
            layers,
            reservation: admitted.reservation,
            allow: admitted.allow,
            tag: admitted.tag,
            block_size: pool.block_size(),
            d: pool.width(),
        }
    }

    /// Tokens cached (identical across layers between decode steps).
    pub fn len(&self) -> usize {
        self.layers.first().map_or(0, |l| l.len)
    }

    /// Weight identity this sequence's KV was computed under.
    pub fn tag(&self) -> super::PrefixTag {
        self.tag
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Physical blocks currently mapped by this sequence.
    pub fn blocks_in_use(&self) -> usize {
        self.layers.iter().map(|l| l.blocks.len()).sum()
    }

    /// Mutable single-layer view for one decode step.
    pub fn layer(&mut self, l: usize) -> PagedLayer<'_> {
        PagedLayer {
            pages: &mut self.layers[l],
            pool: &self.pool,
            allow: &mut self.allow,
            block_size: self.block_size,
            d: self.d,
        }
    }

    /// Freeze the first `n` (full) blocks of every layer in place so the
    /// prefix map can hold them. Idempotent; partial blocks are skipped.
    pub(crate) fn freeze_blocks(&mut self, n: usize) {
        let bs = self.block_size;
        let mode = self.pool.mode();
        for layer in &mut self.layers {
            for page in layer.blocks.iter_mut().take(n) {
                if page.filled() < bs {
                    continue;
                }
                let old = std::mem::replace(page, Page::Owned(KvBuf::empty(mode)));
                *page = match old {
                    Page::Owned(buf) => Page::Shared {
                        filled: buf.filled,
                        blk: Arc::new(SharedBlock { data: buf.data, filled: buf.filled }),
                    },
                    shared => shared,
                };
            }
        }
    }

    pub(crate) fn shared_arc(&self, layer: usize, block: usize) -> Option<Arc<SharedBlock>> {
        match self.layers.get(layer)?.blocks.get(block)? {
            Page::Shared { blk, .. } => Some(blk.clone()),
            Page::Owned(_) => None,
        }
    }

    /// One block's raw storage and filled-row count (snapshot source for
    /// the partial-tail registration copy — lossless, mode-preserving).
    pub(crate) fn block_data(
        &self,
        layer: usize,
        block: usize,
    ) -> Option<(&super::pool::KvData, usize)> {
        match self.layers.get(layer)?.blocks.get(block)? {
            Page::Owned(b) => Some((&b.data, b.filled)),
            Page::Shared { blk, filled } => Some((&blk.data, *filled)),
        }
    }

    /// Pointer identities of every shared block this sequence references
    /// (O(1) membership for the registration charge-transfer check).
    pub(crate) fn shared_ptrs(&self) -> std::collections::HashSet<usize> {
        self.layers
            .iter()
            .flat_map(|l| {
                l.blocks.iter().filter_map(|p| match p {
                    Page::Shared { blk, .. } => Some(Arc::as_ptr(blk) as usize),
                    Page::Owned(_) => None,
                })
            })
            .collect()
    }

    /// Move one block's budget charge from this sequence to the map.
    pub(crate) fn transfer_charge(&mut self) {
        debug_assert!(self.reservation.charged > 0, "charge transfer without charge");
        self.reservation.charged = self.reservation.charged.saturating_sub(1);
    }

    /// Roll the sequence back to `new_len` tokens (speculative decode
    /// rejected a drafted suffix). Whole owned blocks beyond the boundary
    /// return to the sequence's allowance (their buffers recycle into the
    /// pool, so a later re-push reuses them without allocating); a partial
    /// boundary block keeps its buffer with `filled` reduced. Shared pages
    /// only shrink their per-sequence `filled` view — the frozen block
    /// itself is immutable. Growing is not supported (no-op).
    pub fn truncate(&mut self, new_len: usize) {
        if new_len >= self.len() {
            return;
        }
        let bs = self.block_size;
        let keep = new_len.div_ceil(bs);
        let rem = new_len % bs;
        let (pool, layers, allow) = (&self.pool, &mut self.layers, &mut self.allow);
        for layer in layers.iter_mut() {
            while layer.blocks.len() > keep {
                match layer.blocks.pop() {
                    Some(Page::Owned(buf)) => {
                        *allow += 1;
                        pool.recycle_one(buf);
                    }
                    // Shared pages were never drawn from the allowance;
                    // dropping the handle is enough (the map or other
                    // sequences keep the block alive).
                    Some(Page::Shared { .. }) | None => {}
                }
            }
            if rem > 0 {
                if let Some(p) = layer.blocks.last_mut() {
                    match p {
                        Page::Owned(b) => b.filled = b.filled.min(rem),
                        Page::Shared { filled, .. } => *filled = (*filled).min(rem),
                    }
                }
            }
            layer.len = new_len;
        }
    }
}

impl Drop for PagedSeq {
    fn drop(&mut self) {
        if self.allow > 0 {
            self.pool.note_unused_tail(self.allow);
        }
        let layers = std::mem::take(&mut self.layers);
        let mut bufs = Vec::new();
        for layer in layers {
            for page in layer.blocks {
                match page {
                    Page::Owned(b) => bufs.push(b),
                    Page::Shared { blk, .. } => {
                        // Frozen blocks the map never took (or already
                        // evicted) are ours alone — recycle the buffer.
                        if let Ok(sb) = Arc::try_unwrap(blk) {
                            bufs.push(KvBuf { data: sb.data, filled: 0 });
                        }
                    }
                }
            }
        }
        self.pool.recycle(bufs);
        // `reservation` drops after this, releasing the remaining charge.
    }
}

/// One layer of a [`PagedSeq`] as attention sees it. Implements
/// [`KvStore`], so [`PackedBlock::try_forward`](crate::infer::PackedBlock)
/// decodes against paged and contiguous caches through the same code.
pub struct PagedLayer<'a> {
    pages: &'a mut LayerPages,
    pool: &'a BlockPool,
    allow: &'a mut usize,
    block_size: usize,
    d: usize,
}

impl PagedLayer<'_> {
    fn alloc_owned(&mut self) -> Result<KvBuf, KvError> {
        if *self.allow == 0 {
            return Err(KvError::OutOfBlocks { needed: 1, available: 0 });
        }
        *self.allow -= 1;
        Ok(self.pool.take_buf())
    }

    /// Replace a shared page with an owned copy of its filled rows. The
    /// copy moves stored codes/scales verbatim (no re-quantization).
    fn cow(&mut self, bi: usize) -> Result<(), KvError> {
        let mut buf = self.alloc_owned()?;
        if let Page::Shared { blk, filled } = &self.pages.blocks[bi] {
            buf.data.copy_rows(&blk.data, *filled, self.d);
            buf.filled = *filled;
        }
        self.pages.blocks[bi] = Page::Owned(buf);
        self.pool.note_cow();
        Ok(())
    }
}

impl KvStore for PagedLayer<'_> {
    fn len(&self) -> usize {
        self.pages.len
    }

    fn push(&mut self, k: &[f32], v: &[f32]) -> Result<(), KvError> {
        let (bs, d) = (self.block_size, self.d);
        debug_assert_eq!(k.len(), d);
        debug_assert_eq!(v.len(), d);
        let pos = self.pages.len;
        let bi = pos / bs;
        let off = pos % bs;
        if bi == self.pages.blocks.len() {
            debug_assert_eq!(off, 0);
            let buf = self.alloc_owned()?;
            self.pages.blocks.push(Page::Owned(buf));
        } else if matches!(self.pages.blocks[bi], Page::Shared { .. }) {
            self.cow(bi)?;
        }
        let Some(Page::Owned(buf)) = self.pages.blocks.get_mut(bi) else {
            return Err(KvError::CacheOverflow { cap: pos });
        };
        if buf.filled != off {
            return Err(KvError::CacheOverflow { cap: pos });
        }
        buf.data.write_row(off, d, k, v);
        buf.filled = off + 1;
        self.pages.len = pos + 1;
        Ok(())
    }

    fn for_each_seg<'a>(&'a self, f: &mut dyn FnMut(KvSegment<'a>)) {
        let d = self.d;
        for p in self.pages.blocks.iter().filter(|p| p.filled() > 0) {
            match p {
                Page::Owned(b) => f(b.data.seg(b.filled, d)),
                Page::Shared { blk, filled } => f(blk.data.seg(*filled, d)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{KvPoolOptions, KvStorageMode, PrefixTag};
    use super::*;

    fn tiny_pool() -> Arc<BlockPool> {
        Arc::new(BlockPool::new(
            KvPoolOptions { n_blocks: 16, block_size: 4, mode: KvStorageMode::F32 },
            1,
            2,
        ))
    }

    #[test]
    fn push_fills_blocks_and_segments_cover_all_rows() {
        let pool = tiny_pool();
        let adm = pool.admit(&[], 10, PrefixTag::default()).unwrap();
        let mut seq = PagedSeq::new(&pool, adm);
        for i in 0..10 {
            let row = [i as f32, -(i as f32)];
            seq.layer(0).push(&row, &row).unwrap();
        }
        assert_eq!(seq.len(), 10);
        assert_eq!(seq.blocks_in_use(), 3);
        let layer = seq.layer(0);
        let segs = layer.segments();
        let rows: usize = segs.iter().map(|(k, _)| k.len() / 2).sum();
        assert_eq!(rows, 10);
        // Position order is preserved across segment boundaries.
        let flat: Vec<f32> = segs.iter().flat_map(|(k, _)| k.iter().copied()).collect();
        assert_eq!(flat[8], 4.0, "block boundary row must follow in order");
    }

    #[test]
    fn exhausting_the_allowance_is_an_error_not_a_panic() {
        let pool = tiny_pool();
        let adm = pool.admit(&[], 4, PrefixTag::default()).unwrap();
        assert_eq!(adm.blocks_reserved(), 1);
        let mut seq = PagedSeq::new(&pool, adm);
        let row = [0.0f32; 2];
        for _ in 0..4 {
            seq.layer(0).push(&row, &row).unwrap();
        }
        assert!(matches!(
            seq.layer(0).push(&row, &row),
            Err(KvError::OutOfBlocks { .. })
        ));
    }

    #[test]
    fn truncate_rolls_back_and_repush_reuses_blocks() {
        let pool = tiny_pool();
        let adm = pool.admit(&[], 12, PrefixTag::default()).unwrap();
        let mut seq = PagedSeq::new(&pool, adm);
        let row = |i: usize| [i as f32, -(i as f32)];
        for i in 0..11 {
            let r = row(i);
            seq.layer(0).push(&r, &r).unwrap();
        }
        assert_eq!(seq.blocks_in_use(), 3);
        // Roll back into the middle of block 1: block 2 returns whole, the
        // boundary block keeps its buffer with filled reduced.
        seq.truncate(6);
        assert_eq!(seq.len(), 6);
        assert_eq!(seq.blocks_in_use(), 2);
        let layer = seq.layer(0);
        let rows: usize = layer.segments().iter().map(|(k, _)| k.len() / 2).sum();
        assert_eq!(rows, 6);
        drop(layer);
        // Re-pushing resumes at the truncation boundary and can refill the
        // whole original reservation (the allowance got the blocks back).
        for i in 6..12 {
            let r = row(i);
            seq.layer(0).push(&r, &r).unwrap();
        }
        assert_eq!(seq.len(), 12);
        let layer = seq.layer(0);
        let flat: Vec<f32> = layer
            .segments()
            .iter()
            .flat_map(|(k, _)| k.iter().copied())
            .collect();
        let want: Vec<f32> = (0..12).flat_map(|i| [i as f32, -(i as f32)]).collect();
        assert_eq!(flat, want, "rows after rollback must be position-ordered");
        // Growing via truncate is a no-op.
        drop(layer);
        seq.truncate(40);
        assert_eq!(seq.len(), 12);
    }

    #[test]
    fn dropping_a_seq_returns_its_blocks() {
        let pool = tiny_pool();
        let adm = pool.admit(&[], 12, PrefixTag::default()).unwrap();
        let mut seq = PagedSeq::new(&pool, adm);
        let row = [1.0f32; 2];
        for _ in 0..5 {
            seq.layer(0).push(&row, &row).unwrap();
        }
        assert_eq!(pool.available(), 13);
        drop(seq);
        assert_eq!(pool.available(), 16);
        // One block reserved for tokens 5..12 was never materialized.
        assert!(pool.stats().unused_tail_returned >= 1);
    }

    #[test]
    fn int8_cow_copy_is_bit_identical_to_its_source() {
        let pool = Arc::new(BlockPool::new(
            KvPoolOptions { n_blocks: 16, block_size: 1, mode: KvStorageMode::Int8 },
            1,
            2,
        ));
        // block_size 1 packs to 4 tokens/block in int8.
        assert_eq!(pool.block_size(), 4);
        let adm = pool.admit(&[], 8, PrefixTag::default()).unwrap();
        let mut seq = PagedSeq::new(&pool, adm);
        for i in 0..4 {
            let row = [0.9 - i as f32 * 0.3, -0.2 + i as f32 * 0.1];
            seq.layer(0).push(&row, &row).unwrap();
        }
        // Freeze the full block, snapshot its raw codes, then trigger CoW
        // by pushing past it via a second sequence sharing the block.
        seq.freeze_blocks(1);
        let snap: Vec<i8> = match seq.block_data(0, 0).unwrap().0 {
            super::super::pool::KvData::Int8 { k, .. } => k.clone(),
            _ => panic!("int8 pool must store int8"),
        };
        pool.register_prefix(&[10, 11, 12, 13], &mut seq);
        drop(seq);
        let adm2 = pool.admit(&[10, 11, 12, 13, 14], 8, PrefixTag::default()).unwrap();
        assert_eq!(adm2.shared_len(), 4);
        let mut seq2 = PagedSeq::new(&pool, adm2);
        // Overwrite position 3 (inside the shared block) — forces a CoW
        // whose first 3 rows must be byte-for-byte the frozen codes.
        seq2.truncate(3);
        seq2.layer(0).push(&[0.5, 0.5], &[0.5, 0.5]).unwrap();
        let copied: Vec<i8> = match seq2.block_data(0, 0).unwrap().0 {
            super::super::pool::KvData::Int8 { k, .. } => k.clone(),
            _ => panic!(),
        };
        assert_eq!(&copied[..3 * 2], &snap[..3 * 2], "CoW must move codes verbatim");
    }
}
