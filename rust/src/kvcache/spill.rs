//! The KV disk tier: frozen shared-prefix entries serialized to `.pqm`
//! section-container files and faulted back on demand.
//!
//! One file per spilled entry: a `KV_META` section carrying the prefix
//! identity ([`PrefixTag`]) and pool geometry (storage mode, rows per
//! block, width), then one `KV_BLOCK` section per physical block in
//! (layer, block) order.  Blocks serialize losslessly — f32 rows as raw
//! bits, quantized rows as their i8 codes plus f32 scale bits — so a
//! faulted-back block is bit-identical to what was evicted: re-attaching
//! it produces exactly the KV a resident hit would have.  Every section is
//! CRC-checked by the shared `.pqm` reader on the way back in; any
//! mismatch fails the fault, and the pool degrades to recompute.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::pool::{KvData, PrefixTag, SharedBlock};
use super::KvStorageMode;
use crate::artifact::{kind, read_container, save_container, section_payload};

/// Spill-file metadata payload size: tag (8+8) + len (8) + n_layers (4) +
/// blocks_per_layer (4) + mode (1) + block_size (4) + d (4).
const META_BYTES: usize = 41;

fn mode_code(mode: KvStorageMode) -> u8 {
    match mode {
        KvStorageMode::F32 => 0,
        KvStorageMode::Int8 => 1,
    }
}

/// A directory of spilled prefix entries plus a filename counter. Owned by
/// the pool's state (one tier per pool); all bookkeeping about *which*
/// entries are on disk lives in the pool — the tier only moves bytes.
pub(crate) struct SpillTier {
    dir: PathBuf,
    counter: AtomicU64,
}

impl SpillTier {
    pub(crate) fn new(dir: &Path) -> std::io::Result<SpillTier> {
        std::fs::create_dir_all(dir)?;
        // Crash recovery: a `.tmp` is a write that never reached its
        // rename (see `write_entry`), so no pool bookkeeping references
        // it — sweep the orphans rather than let them accumulate.
        if let Ok(entries) = std::fs::read_dir(dir) {
            for e in entries.flatten() {
                let p = e.path();
                if p.extension().is_some_and(|x| x == "tmp") {
                    std::fs::remove_file(&p).ok();
                }
            }
        }
        Ok(SpillTier { dir: dir.to_path_buf(), counter: AtomicU64::new(0) })
    }

    /// Serialize one entry's blocks to a fresh file under the tier
    /// directory. Returns the path and the file size in bytes.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn write_entry(
        &self,
        _key: &[u32],
        tag: PrefixTag,
        len: usize,
        mode: KvStorageMode,
        block_size: usize,
        d: usize,
        layers: &[Vec<Arc<SharedBlock>>],
    ) -> Result<(PathBuf, u64)> {
        let n_layers = layers.len();
        let blocks_per_layer = layers.first().map_or(0, |l| l.len());
        let total = n_layers * blocks_per_layer;
        if total == 0 || total > u16::MAX as usize {
            bail!("entry has {total} blocks, spill files index blocks as u16");
        }
        let mut meta = Vec::with_capacity(META_BYTES);
        meta.extend_from_slice(&(tag.0 as u64).to_le_bytes());
        meta.extend_from_slice(&tag.1.to_le_bytes());
        meta.extend_from_slice(&(len as u64).to_le_bytes());
        meta.extend_from_slice(&(n_layers as u32).to_le_bytes());
        meta.extend_from_slice(&(blocks_per_layer as u32).to_le_bytes());
        meta.push(mode_code(mode));
        meta.extend_from_slice(&(block_size as u32).to_le_bytes());
        meta.extend_from_slice(&(d as u32).to_le_bytes());

        let mut payloads: Vec<(u16, u16, Vec<u8>)> = Vec::with_capacity(1 + total);
        payloads.push((kind::KV_META, 0, meta));
        for (l, blocks) in layers.iter().enumerate() {
            if blocks.len() != blocks_per_layer {
                bail!("ragged entry: layer {l} has {} blocks, layer 0 has {blocks_per_layer}", blocks.len());
            }
            for (b, blk) in blocks.iter().enumerate() {
                let flat = (l * blocks_per_layer + b) as u16;
                payloads.push((kind::KV_BLOCK, flat, encode_block(blk, d)));
            }
        }
        let bytes = save_container(&payloads);
        if crate::failpoint!("spill.write") {
            bail!("failpoint spill.write: injected spill I/O error");
        }
        let n = self.counter.fetch_add(1, Ordering::Relaxed);
        let path = self.dir.join(format!("kv-{:x}-{:x}-{n}.pqm", tag.0, tag.1));
        // Write-then-rename so a crash mid-write never leaves a torn
        // `.pqm` behind: the file is visible under its final name only
        // once complete. Orphaned `.tmp`s are swept at the next startup.
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, &bytes).with_context(|| format!("writing spill file {tmp:?}"))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming spill file {tmp:?} into place"))?;
        Ok((path, bytes.len() as u64))
    }

    /// Read one spill file back into shared blocks, verifying every
    /// section CRC and that the file's identity/geometry match the pool's.
    pub(crate) fn read_entry(
        &self,
        path: &Path,
        tag: PrefixTag,
        mode: KvStorageMode,
        block_size: usize,
        d: usize,
    ) -> Result<Vec<Vec<Arc<SharedBlock>>>> {
        if crate::failpoint!("spill.read") {
            bail!("failpoint spill.read: injected spill I/O error");
        }
        let bytes = std::fs::read(path).with_context(|| format!("reading spill file {path:?}"))?;
        let sections = read_container(&bytes)?;
        let meta_sec = sections
            .iter()
            .find(|s| s.kind == kind::KV_META)
            .context("spill file has no kv_meta section")?;
        let m = section_payload(&bytes, meta_sec);
        if m.len() != META_BYTES {
            bail!("kv_meta is {} bytes, expected {META_BYTES}", m.len());
        }
        let file_tag = PrefixTag(
            u64::from_le_bytes(m[0..8].try_into().unwrap()) as usize,
            u64::from_le_bytes(m[8..16].try_into().unwrap()),
        );
        let n_layers = u32::from_le_bytes(m[24..28].try_into().unwrap()) as usize;
        let blocks_per_layer = u32::from_le_bytes(m[28..32].try_into().unwrap()) as usize;
        let file_mode = m[32];
        let file_bs = u32::from_le_bytes(m[33..37].try_into().unwrap()) as usize;
        let file_d = u32::from_le_bytes(m[37..41].try_into().unwrap()) as usize;
        if file_tag != tag {
            bail!("spill file tag {file_tag:?} does not match expected {tag:?}");
        }
        if file_mode != mode_code(mode) || file_bs != block_size || file_d != d {
            bail!(
                "spill file geometry (mode {file_mode}, bs {file_bs}, d {file_d}) does not match pool (mode {}, bs {block_size}, d {d})",
                mode_code(mode)
            );
        }
        let total = n_layers * blocks_per_layer;
        let mut slots: Vec<Option<Arc<SharedBlock>>> = (0..total).map(|_| None).collect();
        for s in &sections {
            if s.kind != kind::KV_BLOCK {
                continue;
            }
            let flat = s.index as usize;
            if flat >= total {
                bail!("kv_block index {flat} out of range ({total} blocks)");
            }
            if slots[flat].is_some() {
                bail!("duplicate kv_block index {flat}");
            }
            slots[flat] = Some(Arc::new(decode_block(
                section_payload(&bytes, s),
                mode,
                block_size,
                d,
            )?));
        }
        let mut layers = Vec::with_capacity(n_layers);
        let mut it = slots.into_iter();
        for l in 0..n_layers {
            let mut blocks = Vec::with_capacity(blocks_per_layer);
            for b in 0..blocks_per_layer {
                blocks.push(
                    it.next()
                        .flatten()
                        .with_context(|| format!("missing kv_block for layer {l} block {b}"))?,
                );
            }
            layers.push(blocks);
        }
        Ok(layers)
    }
}

/// Serialize one block losslessly: `filled` as u32, then the filled rows'
/// raw storage (f32 bit patterns, or i8 codes followed by scale bits).
fn encode_block(blk: &SharedBlock, d: usize) -> Vec<u8> {
    let filled = blk.filled;
    let mut out = Vec::with_capacity(4 + 2 * filled * (d * 4 + 4));
    out.extend_from_slice(&(filled as u32).to_le_bytes());
    match &blk.data {
        KvData::F32 { k, v } => {
            for x in &k[..filled * d] {
                out.extend_from_slice(&x.to_le_bytes());
            }
            for x in &v[..filled * d] {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        KvData::Int8 { k, v, ks, vs } => {
            out.extend(k[..filled * d].iter().map(|&q| q as u8));
            out.extend(v[..filled * d].iter().map(|&q| q as u8));
            for x in &ks[..filled] {
                out.extend_from_slice(&x.to_le_bytes());
            }
            for x in &vs[..filled] {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    out
}

fn decode_block(
    payload: &[u8],
    mode: KvStorageMode,
    block_size: usize,
    d: usize,
) -> Result<SharedBlock> {
    if payload.len() < 4 {
        bail!("kv_block payload truncated");
    }
    let filled = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
    if filled > block_size {
        bail!("kv_block filled {filled} exceeds block size {block_size}");
    }
    let body = &payload[4..];
    let mut data = KvData::alloc(mode, block_size, d);
    match &mut data {
        KvData::F32 { k, v } => {
            let want = 2 * filled * d * 4;
            if body.len() != want {
                bail!("f32 kv_block body is {} bytes, expected {want}", body.len());
            }
            for (i, chunk) in body.chunks_exact(4).enumerate() {
                let x = f32::from_le_bytes(chunk.try_into().unwrap());
                if i < filled * d {
                    k[i] = x;
                } else {
                    v[i - filled * d] = x;
                }
            }
        }
        KvData::Int8 { k, v, ks, vs } => {
            let want = 2 * filled * d + 2 * filled * 4;
            if body.len() != want {
                bail!("int8 kv_block body is {} bytes, expected {want}", body.len());
            }
            let (codes, scales) = body.split_at(2 * filled * d);
            for (dst, &b) in k[..filled * d].iter_mut().zip(&codes[..filled * d]) {
                *dst = b as i8;
            }
            for (dst, &b) in v[..filled * d].iter_mut().zip(&codes[filled * d..]) {
                *dst = b as i8;
            }
            for (i, chunk) in scales.chunks_exact(4).enumerate() {
                let x = f32::from_le_bytes(chunk.try_into().unwrap());
                if i < filled {
                    ks[i] = x;
                } else {
                    vs[i - filled] = x;
                }
            }
        }
    }
    Ok(SharedBlock { data, filled })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The failpoint registry is process-global, so the test that arms
    /// `spill.*` must not overlap the tests doing real writes/reads.
    static SPILL_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn spill_lock() -> std::sync::MutexGuard<'static, ()> {
        SPILL_TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn block(mode: KvStorageMode, bs: usize, d: usize, filled: usize, seed: f32) -> SharedBlock {
        let mut data = KvData::alloc(mode, bs, d);
        for r in 0..filled {
            let krow: Vec<f32> = (0..d).map(|i| seed + (r * d + i) as f32 * 0.37 - 3.0).collect();
            let vrow: Vec<f32> = (0..d).map(|i| -seed + (r * d + i) as f32 * 0.11).collect();
            data.write_row(r, d, &krow, &vrow);
        }
        SharedBlock { data, filled }
    }

    fn raw(data: &KvData) -> (Vec<u32>, Vec<u32>, Vec<u32>, Vec<u32>) {
        match data {
            KvData::F32 { k, v } => (
                k.iter().map(|x| x.to_bits()).collect(),
                v.iter().map(|x| x.to_bits()).collect(),
                vec![],
                vec![],
            ),
            KvData::Int8 { k, v, ks, vs } => (
                k.iter().map(|&q| q as u8 as u32).collect(),
                v.iter().map(|&q| q as u8 as u32).collect(),
                ks.iter().map(|x| x.to_bits()).collect(),
                vs.iter().map(|x| x.to_bits()).collect(),
            ),
        }
    }

    #[test]
    fn spill_round_trip_is_bit_identical_per_mode() {
        let _g = spill_lock();
        let dir = std::env::temp_dir().join(format!("pquant-spill-test-{}", std::process::id()));
        for mode in [KvStorageMode::F32, KvStorageMode::Int8] {
            let tier = SpillTier::new(&dir).unwrap();
            let (bs, d) = (8, 4);
            let tag = PrefixTag(3, 7);
            let layers: Vec<Vec<Arc<SharedBlock>>> = (0..2)
                .map(|l| {
                    (0..2)
                        .map(|b| Arc::new(block(mode, bs, d, if b == 1 { 5 } else { bs }, (l * 2 + b) as f32)))
                        .collect()
                })
                .collect();
            let (path, bytes) = tier
                .write_entry(&[1, 2, 3], tag, 13, mode, bs, d, &layers)
                .unwrap();
            assert!(bytes > 0 && path.exists());
            let back = tier.read_entry(&path, tag, mode, bs, d).unwrap();
            assert_eq!(back.len(), 2);
            for (orig_l, back_l) in layers.iter().zip(&back) {
                for (orig, restored) in orig_l.iter().zip(back_l) {
                    assert_eq!(orig.filled, restored.filled);
                    let (ok, ov, oks, ovs) = raw(&orig.data);
                    let (bk, bv, bks, bvs) = raw(&restored.data);
                    // Only filled rows must round-trip; the tail is
                    // zero-initialized on both sides, so whole-buffer
                    // equality holds.
                    assert_eq!(ok, bk, "{mode} K codes");
                    assert_eq!(ov, bv, "{mode} V codes");
                    assert_eq!(oks, bks, "{mode} K scales");
                    assert_eq!(ovs, bvs, "{mode} V scales");
                }
            }
            // Wrong tag is refused.
            assert!(tier.read_entry(&path, PrefixTag(9, 9), mode, bs, d).is_err());
            // Corruption is caught by the section CRC.
            let mut corrupt = std::fs::read(&path).unwrap();
            let last = corrupt.len() - 1;
            corrupt[last] ^= 0x10;
            std::fs::write(&path, &corrupt).unwrap();
            assert!(tier.read_entry(&path, tag, mode, bs, d).is_err());
            std::fs::remove_file(&path).ok();
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn successful_writes_leave_no_tmp_behind() {
        let _g = spill_lock();
        let dir = std::env::temp_dir()
            .join(format!("pquant-spill-tmp-clean-{}", std::process::id()));
        let tier = SpillTier::new(&dir).unwrap();
        let (bs, d) = (8, 4);
        let layers: Vec<Vec<Arc<SharedBlock>>> =
            vec![vec![Arc::new(block(KvStorageMode::F32, bs, d, bs, 1.0))]];
        let (path, _) = tier
            .write_entry(&[1, 2], PrefixTag(1, 2), bs, KvStorageMode::F32, bs, d, &layers)
            .unwrap();
        assert!(path.exists());
        let tmps: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.path().extension().is_some_and(|x| x == "tmp"))
            .collect();
        assert!(tmps.is_empty(), "rename must consume the staging file");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn startup_sweeps_orphaned_tmp_files_only() {
        let dir = std::env::temp_dir()
            .join(format!("pquant-spill-tmp-sweep-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // A crash mid-write leaves a staging file; a completed entry does
        // not. Only the former may be swept.
        let orphan = dir.join("kv-3-7-0.tmp");
        let entry = dir.join("kv-3-7-1.pqm");
        std::fs::write(&orphan, b"torn half-entry").unwrap();
        std::fs::write(&entry, b"complete entry").unwrap();
        let _tier = SpillTier::new(&dir).unwrap();
        assert!(!orphan.exists(), "orphaned .tmp swept at startup");
        assert!(entry.exists(), "completed entries are untouched");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn armed_spill_failpoints_inject_io_errors() {
        let _g = spill_lock();
        let dir = std::env::temp_dir()
            .join(format!("pquant-spill-failpoint-{}", std::process::id()));
        let tier = SpillTier::new(&dir).unwrap();
        let (bs, d) = (8, 4);
        let layers: Vec<Vec<Arc<SharedBlock>>> =
            vec![vec![Arc::new(block(KvStorageMode::F32, bs, d, bs, 2.0))]];
        crate::util::failpoint::arm("spill.write", 1.0, 9);
        let failed = tier
            .write_entry(&[5, 6], PrefixTag(5, 6), bs, KvStorageMode::F32, bs, d, &layers)
            .is_err();
        crate::util::failpoint::disarm("spill.write");
        assert!(failed, "armed spill.write fails the write");
        let (path, _) = tier
            .write_entry(&[5, 6], PrefixTag(5, 6), bs, KvStorageMode::F32, bs, d, &layers)
            .unwrap();
        crate::util::failpoint::arm("spill.read", 1.0, 9);
        let read_failed = tier.read_entry(&path, PrefixTag(5, 6), KvStorageMode::F32, bs, d);
        crate::util::failpoint::disarm("spill.read");
        assert!(read_failed.is_err(), "armed spill.read fails the fault-back");
        assert!(
            tier.read_entry(&path, PrefixTag(5, 6), KvStorageMode::F32, bs, d).is_ok(),
            "disarmed read recovers"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
