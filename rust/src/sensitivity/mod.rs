//! OBS/SPQR weight-sensitivity analysis (paper §2.3, eq. 1-2) and the
//! *parameter democratization* metrics built on it (Fig 2 / Fig 5a).
//!
//! For a linear layer y = x·W with calibration activations X [m, k]:
//!
//! ```text
//! H    = XᵀX / m + δ·mean(diag)·I        (damped Hessian)
//! s_ij = w_ij² / (2·[H⁻¹]_ii)            (eq. 2; i = input dim)
//! ```
//!
//! Democratization is quantified by how *concentrated* the sensitivity
//! distribution is: Gini coefficient, excess kurtosis of log-sensitivity,
//! and the share of total sensitivity mass held by the top 1% of weights.
//! A 16-bit model shows high concentration; a collapsed 1-bit model is
//! near-uniform (Gini → small).

use anyhow::Result;

use crate::tensor::{linalg::damped, Matrix};

/// Sensitivity map + summary statistics for one weight matrix.
#[derive(Debug, Clone)]
pub struct SensitivityReport {
    /// s_ij per weight, same shape as W.
    pub map: Matrix,
    pub gini: f64,
    pub log_kurtosis: f64,
    /// Fraction of total sensitivity mass in the top 1% of weights.
    pub top1pct_mass: f64,
    /// Fraction in the top 10%.
    pub top10pct_mass: f64,
}

/// Compute eq. 2 for W [k, n] given calibration activations X [m, k]
/// (rows = tokens). `rel_damp` is the GPTQ-style relative ridge (1e-2).
pub fn sensitivity_map(w: &Matrix, x: &Matrix, rel_damp: f32) -> Result<SensitivityReport> {
    assert_eq!(w.rows, x.cols, "W rows must match activation feature dim");
    let h = damped(&x.gram(), rel_damp);
    let h_inv = crate::tensor::cholesky_inverse(&h)?;
    let mut map = Matrix::zeros(w.rows, w.cols);
    for i in 0..w.rows {
        let denom = (2.0 * h_inv.at(i, i)).max(1e-12);
        for j in 0..w.cols {
            let wij = w.at(i, j);
            *map.at_mut(i, j) = wij * wij / denom;
        }
    }
    Ok(summarize(map))
}

/// Summary statistics from a raw sensitivity map.
pub fn summarize(map: Matrix) -> SensitivityReport {
    let mut vals: Vec<f64> = map.data.iter().map(|&v| v as f64).collect();
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = vals.len();
    let total: f64 = vals.iter().sum::<f64>().max(1e-30);

    // Gini over the sorted values.
    let mut cum = 0.0f64;
    let mut gini_sum = 0.0f64;
    for (i, v) in vals.iter().enumerate() {
        cum += v;
        gini_sum += cum;
        let _ = i;
    }
    let gini = 1.0 - 2.0 * (gini_sum / (n as f64 * total)) + 1.0 / n as f64;

    // Excess kurtosis of log-sensitivity (log spreads the dynamic range,
    // matching the paper's log-sensitivity heatmaps).
    let logs: Vec<f64> = vals.iter().map(|v| (v + 1e-30).ln()).collect();
    let mean = logs.iter().sum::<f64>() / n as f64;
    let var = logs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
    let m4 = logs.iter().map(|x| (x - mean).powi(4)).sum::<f64>() / n as f64;
    let log_kurtosis = if var > 1e-18 { m4 / (var * var) - 3.0 } else { 0.0 };

    let top = |frac: f64| -> f64 {
        let k = ((n as f64 * frac).ceil() as usize).max(1);
        vals[n - k..].iter().sum::<f64>() / total
    };

    SensitivityReport {
        gini,
        log_kurtosis,
        top1pct_mass: top(0.01),
        top10pct_mass: top(0.10),
        map,
    }
}

/// Simulated-quantization sensitivity for a whole matrix family: quantize
/// W per `variant`, compute the *dequantized* weights' map (what the
/// deployed model actually multiplies by).
pub fn dequantized_weights(w: &Matrix, variant: crate::config::Variant) -> Matrix {
    use crate::config::Variant;
    match variant {
        Variant::Fp16 => w.clone(),
        Variant::BitNet | Variant::PQuant => {
            let b = crate::quant::binarize(&w.data);
            Matrix::from_vec(w.rows, w.cols, crate::quant::dequant_binary(&b))
        }
        Variant::BitNet158 => {
            let t = crate::quant::ternarize(&w.data);
            Matrix::from_vec(
                w.rows,
                w.cols,
                t.vals.iter().map(|&v| v as f32 * t.scale).collect(),
            )
        }
    }
}

/// ASCII heatmap of a (downsampled) sensitivity map — the Fig 2 / Fig 5a
/// rendering for a terminal. Darker glyph = higher log-sensitivity.
pub fn ascii_heatmap(map: &Matrix, max_rows: usize, max_cols: usize) -> String {
    const SHADES: &[u8] = b" .:-=+*#%@";
    let pooled = map.max_pool_to(max_rows, max_cols);
    let logs: Vec<f32> = pooled.data.iter().map(|&v| (v + 1e-30).ln()).collect();
    let lo = logs.iter().copied().fold(f32::INFINITY, f32::min);
    let hi = logs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let span = (hi - lo).max(1e-6);
    let mut out = String::new();
    for i in 0..pooled.rows {
        for j in 0..pooled.cols {
            let t = (logs[i * pooled.cols + j] - lo) / span;
            let idx = ((t * (SHADES.len() - 1) as f32).round() as usize).min(SHADES.len() - 1);
            out.push(SHADES[idx] as char);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_acts(m: usize, k: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(m, k, |_, _| rng.normal())
    }

    #[test]
    fn uniform_weights_are_democratized() {
        // all-equal |w| → low concentration
        let w = Matrix::from_fn(32, 16, |i, j| if (i + j) % 2 == 0 { 0.5 } else { -0.5 });
        let x = random_acts(128, 32, 1);
        let rep = sensitivity_map(&w, &x, 1e-2).unwrap();
        assert!(rep.gini < 0.45, "gini {} should be small", rep.gini);
    }

    #[test]
    fn outlier_weights_concentrate_sensitivity() {
        let mut rng = Rng::new(2);
        let mut w = Matrix::from_fn(32, 16, |_, _| rng.normal() * 0.02);
        // a few huge weights
        for k in 0..5 {
            *w.at_mut(k * 5 % 32, k * 3 % 16) = 4.0;
        }
        let x = random_acts(128, 32, 3);
        let rep = sensitivity_map(&w, &x, 1e-2).unwrap();
        assert!(rep.gini > 0.5, "gini {} should be large", rep.gini);
        assert!(rep.top1pct_mass > 0.3, "top1% {} should dominate", rep.top1pct_mass);
    }

    #[test]
    fn binarized_weights_lose_concentration() {
        // The core paper observation (Fig 2): quantizing to ±λ flattens
        // the sensitivity landscape.
        let mut rng = Rng::new(4);
        let mut w = Matrix::from_fn(48, 24, |_, _| rng.normal() * 0.05);
        for k in 0..8 {
            *w.at_mut((k * 7) % 48, (k * 5) % 24) = 3.0;
        }
        let x = random_acts(256, 48, 5);
        let fp = sensitivity_map(&w, &x, 1e-2).unwrap();
        let bin = dequantized_weights(&w, crate::config::Variant::BitNet);
        let b = sensitivity_map(&bin, &x, 1e-2).unwrap();
        assert!(
            b.gini < fp.gini * 0.8,
            "binarization should flatten sensitivity: fp {} vs 1-bit {}",
            fp.gini,
            b.gini
        );
        assert!(b.top1pct_mass < fp.top1pct_mass);
    }

    #[test]
    fn heatmap_renders() {
        let m = Matrix::from_fn(64, 64, |i, j| ((i * j) % 17) as f32 + 0.1);
        let art = ascii_heatmap(&m, 8, 16);
        let lines: Vec<&str> = art.lines().collect();
        assert_eq!(lines.len(), 8);
        assert!(lines.iter().all(|l| l.len() == 16));
    }

    #[test]
    fn gini_bounds() {
        let m = Matrix::from_vec(1, 4, vec![1.0, 1.0, 1.0, 1.0]);
        let rep = summarize(m);
        assert!(rep.gini.abs() < 0.01, "uniform gini ≈ 0, got {}", rep.gini);
        let m = Matrix::from_vec(1, 100, {
            let mut v = vec![0.0; 100];
            v[0] = 1.0;
            v
        });
        let rep = summarize(m);
        assert!(rep.gini > 0.95, "delta gini ≈ 1, got {}", rep.gini);
    }
}
