//! Dense f32 matrix substrate: storage, views, matmul, reductions, and the
//! Cholesky-based inverse the OBS sensitivity analysis needs (eq. 2).

pub mod linalg;

pub use linalg::{cholesky, cholesky_inverse, solve_lower};

/// Row-major dense f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Naive triple-loop matmul (the sensitivity path only touches
    /// D_model-sized matrices; the serving hot path uses `gemm::*`).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue;
                }
                let orow = &other.data[k * other.cols..(k + 1) * other.cols];
                let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// X·Xᵀ/n accumulated from calibration rows — the (scaled) Hessian of
    /// the layer-wise reconstruction problem (sec 2.3).
    pub fn gram(&self) -> Matrix {
        let n = self.cols;
        let mut g = Matrix::zeros(n, n);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..n {
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                let grow = &mut g.data[i * n..(i + 1) * n];
                for (gj, xj) in grow.iter_mut().zip(row) {
                    *gj += xi * xj;
                }
            }
        }
        let scale = 1.0 / self.rows.max(1) as f32;
        for v in &mut g.data {
            *v *= scale;
        }
        g
    }

    pub fn frobenius_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn mean(&self) -> f32 {
        self.data.iter().sum::<f32>() / self.data.len().max(1) as f32
    }

    pub fn abs_mean(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).sum::<f32>() / self.data.len().max(1) as f32
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Max-pool downsample to at most (max_r, max_c) — the visualization
    /// transform used for the paper's Fig 2 heatmaps.
    pub fn max_pool_to(&self, max_r: usize, max_c: usize) -> Matrix {
        let pr = self.rows.div_ceil(max_r).max(1);
        let pc = self.cols.div_ceil(max_c).max(1);
        let out_r = self.rows.div_ceil(pr);
        let out_c = self.cols.div_ceil(pc);
        let mut out = Matrix::zeros(out_r, out_c);
        for i in 0..self.rows {
            for j in 0..self.cols {
                let v = self.at(i, j);
                let o = out.at_mut(i / pr, j / pc);
                if v > *o {
                    *o = v;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        let i = Matrix::identity(3);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_fn(3, 5, |i, j| (i * 7 + j) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let x = Matrix::from_fn(10, 4, |i, j| ((i + 1) * (j + 2)) as f32 * 0.1);
        let g = x.gram();
        for i in 0..4 {
            assert!(g.at(i, i) >= 0.0);
            for j in 0..4 {
                assert!((g.at(i, j) - g.at(j, i)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn max_pool_shrinks_and_keeps_max() {
        let a = Matrix::from_fn(100, 60, |i, j| (i + j) as f32);
        let p = a.max_pool_to(10, 6);
        assert!(p.rows <= 10 && p.cols <= 6);
        assert_eq!(p.at(p.rows - 1, p.cols - 1), a.at(99, 59));
    }

    #[test]
    fn reductions() {
        let a = Matrix::from_vec(1, 4, vec![-2.0, 1.0, 0.0, 1.0]);
        assert_eq!(a.mean(), 0.0);
        assert_eq!(a.abs_mean(), 1.0);
        assert_eq!(a.max_abs(), 2.0);
        assert!((a.frobenius_norm() - (6.0f32).sqrt()).abs() < 1e-6);
    }
}
