//! Cholesky factorization + SPD inverse — the numerical core of the OBS
//! sensitivity metric (eq. 2 needs diag((XXᵀ + δI)⁻¹)).

use anyhow::{bail, Result};

use super::Matrix;

/// Lower-triangular Cholesky factor L with A = L·Lᵀ.
/// Fails if A is not (numerically) positive definite — callers add a
/// damping ridge `δI` first, as GPTQ/SPQR do.
pub fn cholesky(a: &Matrix) -> Result<Matrix> {
    assert_eq!(a.rows, a.cols, "cholesky needs a square matrix");
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("matrix not positive definite at pivot {i} (got {sum:.3e})");
                }
                *l.at_mut(i, j) = sum.sqrt() as f32;
            } else {
                *l.at_mut(i, j) = (sum / l.at(j, j) as f64) as f32;
            }
        }
    }
    Ok(l)
}

/// Solve L·y = b for lower-triangular L (forward substitution).
pub fn solve_lower(l: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut sum = b[i] as f64;
        for k in 0..i {
            sum -= l.at(i, k) as f64 * y[k] as f64;
        }
        y[i] = (sum / l.at(i, i) as f64) as f32;
    }
    y
}

/// Solve Lᵀ·x = y (back substitution).
fn solve_upper_t(l: &Matrix, y: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut sum = y[i] as f64;
        for k in i + 1..n {
            sum -= l.at(k, i) as f64 * x[k] as f64;
        }
        x[i] = (sum / l.at(i, i) as f64) as f32;
    }
    x
}

/// Inverse of an SPD matrix via Cholesky (column-by-column solve).
pub fn cholesky_inverse(a: &Matrix) -> Result<Matrix> {
    let n = a.rows;
    let l = cholesky(a)?;
    let mut inv = Matrix::zeros(n, n);
    let mut e = vec![0.0f32; n];
    for col in 0..n {
        e[col] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_upper_t(&l, &y);
        for row in 0..n {
            *inv.at_mut(row, col) = x[row];
        }
        e[col] = 0.0;
    }
    Ok(inv)
}

/// `a + δ·mean(diag)·I` — the damping ridge GPTQ applies before inverting.
pub fn damped(a: &Matrix, rel_delta: f32) -> Matrix {
    let n = a.rows;
    let mean_diag = (0..n).map(|i| a.at(i, i)).sum::<f32>() / n as f32;
    let ridge = (rel_delta * mean_diag).max(1e-8);
    let mut out = a.clone();
    for i in 0..n {
        *out.at_mut(i, i) += ridge;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            *a.at_mut(i, i) += n as f32; // well-conditioned
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(8, 1);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        for (x, y) in a.data.iter().zip(&rec.data) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        let a = random_spd(12, 2);
        let inv = cholesky_inverse(&a).unwrap();
        let prod = a.matmul(&inv);
        for i in 0..12 {
            for j in 0..12 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at(i, j) - want).abs() < 1e-3,
                    "({i},{j}) = {}", prod.at(i, j));
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = Matrix::identity(3);
        *a.at_mut(2, 2) = -1.0;
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn solve_lower_known() {
        let l = Matrix::from_vec(2, 2, vec![2.0, 0.0, 1.0, 3.0]);
        let y = solve_lower(&l, &[4.0, 8.0]);
        assert!((y[0] - 2.0).abs() < 1e-6 && (y[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn damping_increases_diagonal() {
        let a = random_spd(4, 3);
        let d = damped(&a, 0.01);
        for i in 0..4 {
            assert!(d.at(i, i) > a.at(i, i));
        }
    }
}
