//! Training stability monitor (paper Appendix G / Fig 10).
//!
//! The paper reports that 1-bit BitNet "frequently suffers from gradient
//! explosion during training, often requiring checkpoint reloading and
//! restarts", while pQuant stays stable.  This monitor implements that
//! operational loop: it watches the loss stream, flags divergence
//! (NaN/Inf or a loss spike above `spike_factor` × the recent median), and
//! tells the trainer to roll back to the last good snapshot.

use std::collections::VecDeque;

/// Divergence verdict for one step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Ok,
    /// Loss is NaN/Inf or spiked: roll back and re-try from the snapshot.
    RollBack,
}

#[derive(Debug, Clone)]
pub struct StabilityMonitor {
    window: VecDeque<f32>,
    window_len: usize,
    pub spike_factor: f32,
    pub rollbacks: usize,
}

impl StabilityMonitor {
    pub fn new(window_len: usize, spike_factor: f32) -> StabilityMonitor {
        StabilityMonitor {
            window: VecDeque::with_capacity(window_len),
            window_len,
            spike_factor,
            rollbacks: 0,
        }
    }

    /// Paper-shaped defaults.
    pub fn default_paper() -> StabilityMonitor {
        StabilityMonitor::new(20, 1.5)
    }

    fn median(&self) -> Option<f32> {
        if self.window.len() < self.window_len / 2 {
            return None; // not enough history yet
        }
        let mut v: Vec<f32> = self.window.iter().copied().collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(v[v.len() / 2])
    }

    /// Observe a loss; `RollBack` means the step must be discarded.
    pub fn observe(&mut self, loss: f32) -> Verdict {
        if !loss.is_finite() {
            self.rollbacks += 1;
            return Verdict::RollBack;
        }
        if let Some(med) = self.median() {
            if loss > med * self.spike_factor {
                self.rollbacks += 1;
                return Verdict::RollBack;
            }
        }
        self.window.push_back(loss);
        if self.window.len() > self.window_len {
            self.window.pop_front();
        }
        Verdict::Ok
    }

    /// Clear history after a rollback (losses before the snapshot are stale).
    pub fn reset_window(&mut self) {
        self.window.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_smooth_descent() {
        let mut m = StabilityMonitor::default_paper();
        for i in 0..100 {
            let loss = 6.0 - 0.01 * i as f32;
            assert_eq!(m.observe(loss), Verdict::Ok);
        }
        assert_eq!(m.rollbacks, 0);
    }

    #[test]
    fn rejects_nan_immediately() {
        let mut m = StabilityMonitor::default_paper();
        assert_eq!(m.observe(f32::NAN), Verdict::RollBack);
        assert_eq!(m.observe(f32::INFINITY), Verdict::RollBack);
        assert_eq!(m.rollbacks, 2);
    }

    #[test]
    fn rejects_spike_after_history() {
        let mut m = StabilityMonitor::default_paper();
        for _ in 0..20 {
            m.observe(2.0);
        }
        assert_eq!(m.observe(10.0), Verdict::RollBack);
        assert_eq!(m.observe(2.1), Verdict::Ok);
    }

    #[test]
    fn no_spike_detection_without_history() {
        let mut m = StabilityMonitor::default_paper();
        // first observation can be anything finite
        assert_eq!(m.observe(1000.0), Verdict::Ok);
    }
}
