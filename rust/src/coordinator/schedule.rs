//! Two-phase training schedule (paper Appendix B.2, Fig 9).
//!
//! Phase 1 (first half): LR warms up linearly for `warmup` steps, then
//! decays linearly from `peak_lr` to `mid_lr`; weight decay is `wd1`
//! (0.1 in the paper).
//! Phase 2 (second half): LR restarts at `phase2_lr` (< mid-phase value)
//! and decays linearly to `final_lr`; weight decay is disabled — in 1-bit
//! training decay acts on latent weights and causes sign oscillation near
//! quantization thresholds late in training.

/// The paper's two-phase LR/WD schedule.
#[derive(Debug, Clone)]
pub struct TwoPhaseSchedule {
    pub total_steps: u64,
    pub warmup: u64,
    pub peak_lr: f32,
    /// LR at the end of phase 1, as a fraction of peak (paper fig 9 shows
    /// roughly a 3× drop over phase 1).
    pub mid_lr: f32,
    /// LR at the start of phase 2 (the discontinuous drop).
    pub phase2_lr: f32,
    pub final_lr: f32,
    /// Weight decay during phase 1 (0.1 in the paper), 0 in phase 2.
    pub wd1: f32,
}

impl TwoPhaseSchedule {
    /// Paper-shaped defaults for a given length/peak.
    pub fn paper(total_steps: u64, peak_lr: f32) -> TwoPhaseSchedule {
        TwoPhaseSchedule {
            total_steps,
            // paper: 500 warmup steps at 100B-token scale; keep the ratio
            warmup: (total_steps / 20).max(10).min(500),
            peak_lr,
            mid_lr: peak_lr * 0.35,
            phase2_lr: peak_lr * 0.25,
            final_lr: peak_lr * 0.02,
            wd1: 0.1,
        }
    }

    /// Single-phase cosine-free baseline (used by the fp16 ablation —
    /// Appendix E notes half-precision models don't benefit from the
    /// two-phase drop).
    pub fn single_phase(total_steps: u64, peak_lr: f32) -> TwoPhaseSchedule {
        TwoPhaseSchedule {
            total_steps,
            warmup: (total_steps / 20).max(10).min(500),
            peak_lr,
            mid_lr: peak_lr * 0.1,
            phase2_lr: peak_lr * 0.1, // continuous at midpoint
            final_lr: peak_lr * 0.02,
            wd1: 0.1,
        }
    }

    pub fn midpoint(&self) -> u64 {
        self.total_steps / 2
    }

    /// Learning rate at 1-based `step`.
    pub fn lr(&self, step: u64) -> f32 {
        let step = step.min(self.total_steps).max(1);
        if step <= self.warmup {
            return self.peak_lr * step as f32 / self.warmup as f32;
        }
        let mid = self.midpoint();
        if step <= mid {
            let t = (step - self.warmup) as f32 / (mid - self.warmup).max(1) as f32;
            self.peak_lr + (self.mid_lr - self.peak_lr) * t
        } else {
            let t = (step - mid) as f32 / (self.total_steps - mid).max(1) as f32;
            self.phase2_lr + (self.final_lr - self.phase2_lr) * t
        }
    }

    /// Weight decay at `step`: wd1 in phase 1, 0 in phase 2.
    pub fn wd(&self, step: u64) -> f32 {
        if step <= self.midpoint() {
            self.wd1
        } else {
            0.0
        }
    }

    /// (step, lr, wd) triples for plotting (Fig 9 harness).
    pub fn trace(&self, points: usize) -> Vec<(u64, f32, f32)> {
        (0..points)
            .map(|i| {
                let step = 1 + i as u64 * self.total_steps.saturating_sub(1) / (points - 1).max(1) as u64;
                (step, self.lr(step), self.wd(step))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_to_peak() {
        let s = TwoPhaseSchedule::paper(1000, 1e-3);
        assert!(s.lr(1) < s.lr(s.warmup));
        assert!((s.lr(s.warmup) - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn phase1_monotone_decreasing_after_warmup() {
        let s = TwoPhaseSchedule::paper(1000, 1e-3);
        let mid = s.midpoint();
        let mut prev = s.lr(s.warmup);
        for step in s.warmup + 1..=mid {
            let lr = s.lr(step);
            assert!(lr <= prev + 1e-12, "lr not decreasing at {step}");
            prev = lr;
        }
    }

    #[test]
    fn discontinuous_drop_at_midpoint() {
        let s = TwoPhaseSchedule::paper(1000, 1e-3);
        let mid = s.midpoint();
        assert!(s.lr(mid + 1) < s.lr(mid), "phase 2 must start below phase 1 end");
    }

    #[test]
    fn weight_decay_disabled_in_phase2() {
        let s = TwoPhaseSchedule::paper(1000, 1e-3);
        assert_eq!(s.wd(1), 0.1);
        assert_eq!(s.wd(s.midpoint()), 0.1);
        assert_eq!(s.wd(s.midpoint() + 1), 0.0);
        assert_eq!(s.wd(1000), 0.0);
    }

    #[test]
    fn single_phase_is_continuous() {
        let s = TwoPhaseSchedule::single_phase(1000, 1e-3);
        let mid = s.midpoint();
        assert!((s.lr(mid) - s.lr(mid + 1)).abs() < 1e-5);
    }

    #[test]
    fn trace_covers_range() {
        let s = TwoPhaseSchedule::paper(500, 1e-3);
        let t = s.trace(50);
        assert_eq!(t.len(), 50);
        assert_eq!(t[0].0, 1);
        assert_eq!(t.last().unwrap().0, 500);
    }
}
