//! L3 training orchestration: the coordinator owns the event loop, the
//! two-phase schedule, data batching, checkpointing and stability recovery,
//! and drives the AOT train-step executable through the PJRT runtime.
//! Python never runs here — see DESIGN.md.

pub mod schedule;
pub mod stability;
pub mod trainer;

pub use schedule::TwoPhaseSchedule;
pub use stability::{StabilityMonitor, Verdict};
pub use trainer::{TrainOptions, Trainer, TrainingReport};
