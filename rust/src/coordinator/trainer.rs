//! The training loop: batches → sched scalars → AOT train step → metrics,
//! with periodic eval, checkpointing, and stability rollback.

use std::time::Instant;

use anyhow::{Context, Result};

use super::schedule::TwoPhaseSchedule;
use super::stability::{StabilityMonitor, Verdict};
use crate::data::Dataset;
use crate::runtime::{Artifact, CompiledEntry, Runtime, TrainState};

/// Knobs for one training run.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub steps: u64,
    pub peak_lr: f32,
    /// Steps between loss log lines (0 = silent).
    pub log_every: u64,
    /// Steps between in-memory stability snapshots.
    pub snapshot_every: u64,
    /// Steps between held-out perplexity evals (0 = never).
    pub eval_every: u64,
    /// Use the single-phase baseline schedule instead of two-phase.
    pub single_phase: bool,
    /// Optional on-disk checkpoint path written at the end.
    pub final_checkpoint: Option<String>,
    /// Optional packed `.pqm` artifact exported alongside the final
    /// checkpoint (the offline quantize-and-pack step of Appendix A).
    pub export_pqm: Option<String>,
    /// Dataset shuffle seed.
    pub data_seed: u64,
    /// Override α/β init (feature-scaling ablation, Fig 5b). Values are
    /// written into the initial params before training.
    pub feature_scaling_override: Option<(f32, f32)>,
    /// Inject a synthetic loss spike at this step (Fig 10 harness: shows
    /// the rollback machinery; BitNet-style instability does not reliably
    /// reproduce at nano scale).
    pub inject_spike_at: Option<u64>,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            steps: 200,
            peak_lr: 1.5e-3,
            log_every: 20,
            snapshot_every: 25,
            eval_every: 0,
            single_phase: false,
            final_checkpoint: None,
            export_pqm: None,
            data_seed: 0xDA7A,
            feature_scaling_override: None,
            inject_spike_at: None,
        }
    }
}

/// Everything a run produces (consumed by the experiment harnesses).
#[derive(Debug, Clone)]
pub struct TrainingReport {
    pub config_name: String,
    pub losses: Vec<f32>,
    pub eval_ppl: Vec<(u64, f64)>,
    pub final_loss: f32,
    /// Mean loss over the last 10% of steps (smoother than final_loss).
    pub tail_loss: f32,
    pub rollbacks: usize,
    pub wall_seconds: f64,
    pub tokens_per_second: f64,
    pub steps: u64,
    /// Converged feature-scaling values per layer: (alpha, beta).
    pub feature_scaling: Vec<(f32, f32)>,
}

/// Orchestrates one QAT-from-scratch run over an artifact.
pub struct Trainer<'a> {
    pub runtime: &'a Runtime,
    pub artifact: &'a Artifact,
    pub dataset: &'a Dataset,
    pub state: TrainState,
    step_entry: CompiledEntry,
    fwd_entry: Option<CompiledEntry>,
}

impl<'a> Trainer<'a> {
    pub fn new(
        runtime: &'a Runtime,
        artifact: &'a Artifact,
        dataset: &'a Dataset,
    ) -> Result<Trainer<'a>> {
        let step_entry = runtime
            .compile(artifact, "train_step")
            .context("compiling train_step")?;
        let state = TrainState::initial(artifact)?;
        Ok(Trainer { runtime, artifact, dataset, state, step_entry, fwd_entry: None })
    }

    /// Train with a specific batch-size entry (batch ablation, Appendix E).
    pub fn with_entry(
        runtime: &'a Runtime,
        artifact: &'a Artifact,
        dataset: &'a Dataset,
        entry: &str,
    ) -> Result<Trainer<'a>> {
        let step_entry = runtime.compile(artifact, entry)?;
        let state = TrainState::initial(artifact)?;
        Ok(Trainer { runtime, artifact, dataset, state, step_entry, fwd_entry: None })
    }

    fn override_feature_scaling(&mut self, alpha: f32, beta: f32) -> Result<()> {
        use crate::runtime::literal_f32;
        for (i, spec) in self.artifact.manifest.param_layout.iter().enumerate() {
            if spec.name.ends_with(".alpha") {
                self.state.params[i] = literal_f32(&[], &[alpha])?;
            } else if spec.name.ends_with(".beta") {
                self.state.params[i] = literal_f32(&[], &[beta])?;
            }
        }
        Ok(())
    }

    /// Run the full loop; returns the report.
    pub fn run(&mut self, opts: &TrainOptions) -> Result<TrainingReport> {
        let manifest = &self.artifact.manifest;
        if let Some((a, b)) = opts.feature_scaling_override {
            self.override_feature_scaling(a, b)?;
        }
        let schedule = if opts.single_phase {
            TwoPhaseSchedule::single_phase(opts.steps, opts.peak_lr)
        } else {
            TwoPhaseSchedule::paper(opts.steps, opts.peak_lr)
        };
        let batch = self.step_entry.spec.batch;
        let mut batches = self.dataset.batches(batch, manifest.seq_len, opts.data_seed);
        let mut monitor = StabilityMonitor::default_paper();
        let mut losses = Vec::with_capacity(opts.steps as usize);
        let mut eval_ppl = Vec::new();

        // In-memory stability snapshot: (step, serialized state on disk).
        let snap_path = format!("/tmp/pquant_snapshot_{}.ckpt", std::process::id());
        let mut snapshot_step: u64 = 0;
        self.state.save_checkpoint(self.artifact, &snap_path)?;

        let t0 = Instant::now();
        let mut step: u64 = 0;
        let mut retry_budget = 8usize;
        while step < opts.steps {
            let lr = schedule.lr(step + 1);
            let wd = schedule.wd(step + 1);
            let tokens = batches.next_batch();
            let mut loss = self
                .state
                .step(&self.step_entry, &tokens, lr, wd)
                .with_context(|| format!("train step {step}"))?;
            if opts.inject_spike_at == Some(step) {
                loss = loss * 20.0; // simulated divergence (Fig 10 harness)
            }
            match monitor.observe(loss) {
                Verdict::Ok => {
                    losses.push(loss);
                    step += 1;
                    if opts.snapshot_every > 0 && step % opts.snapshot_every == 0 {
                        self.state.save_checkpoint(self.artifact, &snap_path)?;
                        snapshot_step = step;
                    }
                    if opts.log_every > 0 && step % opts.log_every == 0 {
                        println!(
                            "[train {}] step {step}/{} loss {loss:.4} lr {lr:.2e} wd {wd}",
                            manifest.config.name, opts.steps
                        );
                    }
                    if opts.eval_every > 0 && step % opts.eval_every == 0 {
                        if let Some(ppl) = self.eval_perplexity(2048)? {
                            eval_ppl.push((step, ppl));
                            println!(
                                "[train {}] step {step} valid ppl {ppl:.2}",
                                manifest.config.name
                            );
                        }
                    }
                }
                Verdict::RollBack => {
                    if retry_budget == 0 {
                        anyhow::bail!("training diverged beyond the retry budget");
                    }
                    retry_budget -= 1;
                    println!(
                        "[train {}] step {step}: loss {loss:.3} diverged — rolling back to step {snapshot_step}",
                        manifest.config.name
                    );
                    self.state = TrainState::load_checkpoint(self.artifact, &snap_path)?;
                    // Re-seed the batch stream past the bad batch.
                    batches = self.dataset.batches(
                        batch,
                        manifest.seq_len,
                        opts.data_seed ^ (0x5EED + step),
                    );
                    losses.truncate(snapshot_step as usize);
                    step = snapshot_step;
                    monitor.reset_window();
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        std::fs::remove_file(&snap_path).ok();

        if let Some(path) = &opts.final_checkpoint {
            self.state.save_checkpoint(self.artifact, path)?;
        }
        if let Some(path) = &opts.export_pqm {
            let packed = crate::infer::PackedModel::from_state(self.artifact, &self.state)?;
            let bytes = crate::artifact::save_pqm(&packed, None, path)?;
            println!(
                "[train {}] exported packed model → {path} ({:.2} MiB)",
                manifest.config.name,
                bytes as f64 / (1024.0 * 1024.0)
            );
        }

        let tail_n = (losses.len() / 10).max(1);
        let tail_loss =
            losses[losses.len() - tail_n..].iter().sum::<f32>() / tail_n as f32;
        let tokens_per_step = (batch * (manifest.seq_len + 1)) as f64;
        Ok(TrainingReport {
            config_name: manifest.config.name.clone(),
            final_loss: *losses.last().unwrap_or(&f32::NAN),
            tail_loss,
            losses,
            eval_ppl,
            rollbacks: monitor.rollbacks,
            wall_seconds: wall,
            tokens_per_second: tokens_per_step * opts.steps as f64 / wall,
            steps: opts.steps,
            feature_scaling: self.feature_scaling()?,
        })
    }

    /// Current per-layer (α, β) values (Table 7 harness).
    pub fn feature_scaling(&self) -> Result<Vec<(f32, f32)>> {
        let mut alphas = Vec::new();
        let mut betas = Vec::new();
        for (spec, lit) in self
            .artifact
            .manifest
            .param_layout
            .iter()
            .zip(&self.state.params)
        {
            if spec.name.ends_with(".alpha") {
                alphas.push(crate::runtime::literal_to_f32(lit)?[0]);
            } else if spec.name.ends_with(".beta") {
                betas.push(crate::runtime::literal_to_f32(lit)?[0]);
            }
        }
        Ok(alphas.into_iter().zip(betas).collect())
    }

    /// Held-out perplexity via the fwd_b8 entry (or fwd as fallback).
    pub fn eval_perplexity(&mut self, max_tokens: usize) -> Result<Option<f64>> {
        if self.fwd_entry.is_none() {
            let key = if self.artifact.manifest.entries.contains_key("fwd_b8") {
                "fwd_b8"
            } else {
                "fwd"
            };
            self.fwd_entry = Some(self.runtime.compile(self.artifact, key)?);
        }
        let entry = self.fwd_entry.as_ref().unwrap();
        crate::eval::perplexity(
            &self.state,
            entry,
            &self.dataset.valid,
            self.artifact.manifest.seq_len,
            self.artifact.manifest.config.vocab,
            max_tokens,
        )
        .map(Some)
    }
}
