//! Threaded serving engine: request queue → continuous token-level batcher
//! → packed-model decode workers (the §4.5 / Appendix A deployment story:
//! edge inference where GEMV dominates and weight traffic is the
//! bottleneck).
//!
//! Architecture (std threads; the offline environment has no tokio):
//!   * clients submit [`Request`]s over an mpsc channel
//!   * each worker owns one [`PackedModel`] replica and runs *continuous
//!     batching*: an active set of ≤ `max_batch` requests advances one
//!     token per iteration; finished requests are replaced from the queue
//!     immediately (no wave barriers)
//!   * per-request queueing/service latency and aggregate tokens/s are
//!     recorded for the throughput experiments

pub mod registry;

pub use registry::{serve_model, Lease, ModelEntry, ModelInfo, ModelRegistry, SwapReport};

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::infer::{KvCache, PackedModel};

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub n_new: usize,
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub queue_wait: Duration,
    pub service_time: Duration,
}

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Max concurrent requests per worker (continuous batch width).
    pub max_batch: usize,
    /// Worker count (each owns a model replica).
    pub workers: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { max_batch: 4, workers: 1 }
    }
}

struct Active {
    id: u64,
    tokens: Vec<u32>,  // emitted so far
    last_logits: Vec<f32>,
    remaining: usize,
    pos: usize,
    caches: Vec<KvCache>,
    enqueued: Instant,
    started: Instant,
}

/// Aggregate serving metrics.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    pub completed: AtomicUsize,
    pub tokens_out: AtomicUsize,
    /// Peak concurrent active requests observed (batcher invariant probe).
    pub peak_active: AtomicUsize,
}

/// Run workers until the request channel closes; responses go to `tx_out`.
/// Returns aggregate wall time once all workers drain.
pub fn serve(
    models: Vec<PackedModel>,
    rx: Receiver<(Request, Instant)>,
    tx_out: Sender<Response>,
    opts: &ServeOptions,
    metrics: Arc<ServeMetrics>,
) -> Duration {
    assert!(!models.is_empty());
    let rx = Arc::new(Mutex::new(rx));
    let closed = Arc::new(AtomicBool::new(false));
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for mut model in models {
            let rx = rx.clone();
            let tx_out = tx_out.clone();
            let metrics = metrics.clone();
            let closed = closed.clone();
            let max_batch = opts.max_batch;
            scope.spawn(move || {
                let mut active: Vec<Active> = Vec::new();
                loop {
                    // Refill the active set.
                    while active.len() < max_batch && !closed.load(Ordering::Relaxed) {
                        let polled = {
                            let rx = rx.lock().unwrap();
                            if active.is_empty() {
                                // Block briefly when idle.
                                match rx.recv_timeout(Duration::from_millis(20)) {
                                    Ok(r) => Some(r),
                                    Err(RecvTimeoutError::Timeout) => None,
                                    Err(RecvTimeoutError::Disconnected) => {
                                        closed.store(true, Ordering::Relaxed);
                                        None
                                    }
                                }
                            } else {
                                match rx.try_recv() {
                                    Ok(r) => Some(r),
                                    Err(std::sync::mpsc::TryRecvError::Empty) => None,
                                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                                        closed.store(true, Ordering::Relaxed);
                                        None
                                    }
                                }
                            }
                        };
                        let Some((req, enqueued)) = polled else { break };
                        let started = Instant::now();
                        // Prefill: feed the prompt.
                        let max_seq = req.prompt.len() + req.n_new + 1;
                        let mut caches = model.new_caches(max_seq);
                        let mut logits = vec![0.0f32; model.cfg.vocab];
                        for (pos, &t) in req.prompt.iter().enumerate() {
                            logits = model.decode_step(t, pos, &mut caches);
                        }
                        active.push(Active {
                            id: req.id,
                            tokens: Vec::with_capacity(req.n_new),
                            last_logits: logits,
                            remaining: req.n_new,
                            pos: req.prompt.len(),
                            caches,
                            enqueued,
                            started,
                        });
                        // fetch_max: a load-compare-store here loses updates
                        // when several workers race on the shared metric.
                        metrics.peak_active.fetch_max(active.len(), Ordering::Relaxed);
                    }
                    if active.is_empty() {
                        if closed.load(Ordering::Relaxed) {
                            return;
                        }
                        continue;
                    }
                    // One decode step for every active request.
                    let mut i = 0;
                    while i < active.len() {
                        let a = &mut active[i];
                        let next = argmax(&a.last_logits) as u32;
                        a.tokens.push(next);
                        a.remaining -= 1;
                        metrics.tokens_out.fetch_add(1, Ordering::Relaxed);
                        if a.remaining == 0 {
                            let a = active.swap_remove(i);
                            metrics.completed.fetch_add(1, Ordering::Relaxed);
                            let _ = tx_out.send(Response {
                                id: a.id,
                                queue_wait: a.started - a.enqueued,
                                service_time: a.started.elapsed(),
                                tokens: a.tokens,
                            });
                        } else {
                            a.last_logits = model.decode_step(next, a.pos, &mut a.caches);
                            a.pos += 1;
                            i += 1;
                        }
                    }
                }
            });
        }
        drop(tx_out);
    });
    t0.elapsed()
}

fn argmax(x: &[f32]) -> usize {
    let mut bi = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in x.iter().enumerate() {
        if v > bv {
            bi = i;
            bv = v;
        }
    }
    bi
}

/// Convenience one-shot load test: submit `n_requests` identical-shape
/// requests, wait for completion, return (responses, wall, tokens/s).
pub fn load_test(
    models: Vec<PackedModel>,
    n_requests: usize,
    prompt_len: usize,
    n_new: usize,
    opts: &ServeOptions,
) -> (Vec<Response>, Duration, f64) {
    let vocab = models[0].cfg.vocab as u32;
    let (tx, rx) = std::sync::mpsc::channel();
    let (tx_out, rx_out) = std::sync::mpsc::channel();
    let metrics = Arc::new(ServeMetrics::default());
    for id in 0..n_requests {
        let prompt: Vec<u32> = (0..prompt_len).map(|i| (id as u32 + i as u32) % vocab).collect();
        tx.send((Request { id: id as u64, prompt, n_new }, Instant::now())).unwrap();
    }
    drop(tx);
    let wall = serve(models, rx, tx_out, opts, metrics.clone());
    let responses: Vec<Response> = rx_out.iter().collect();
    let toks = metrics.tokens_out.load(Ordering::Relaxed) as f64;
    (responses, wall, toks / wall.as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, Variant};

    fn tiny_model() -> PackedModel {
        PackedModel::random(
            &ModelConfig {
                name: "serve-test".into(),
                variant: Variant::PQuant,
                vocab: 64,
                d_model: 32,
                n_layers: 2,
                n_heads: 2,
                d_ff: 96,
                r: 16,
                n_experts: 2,
                seq_len: 32,
                alpha_init: 2.0,
                beta_init: 0.2,
            },
            3,
        )
    }

    #[test]
    fn all_requests_complete_with_correct_lengths() {
        let (responses, _, tps) =
            load_test(vec![tiny_model()], 10, 4, 6, &ServeOptions::default());
        assert_eq!(responses.len(), 10);
        for r in &responses {
            assert_eq!(r.tokens.len(), 6);
            assert!(r.tokens.iter().all(|&t| t < 64));
        }
        assert!(tps > 0.0);
    }

    #[test]
    fn batcher_never_exceeds_capacity() {
        let metrics = Arc::new(ServeMetrics::default());
        let (tx, rx) = std::sync::mpsc::channel();
        let (tx_out, rx_out) = std::sync::mpsc::channel();
        for id in 0..12 {
            tx.send((Request { id, prompt: vec![1, 2], n_new: 4 }, Instant::now())).unwrap();
        }
        drop(tx);
        let opts = ServeOptions { max_batch: 3, workers: 1 };
        serve(vec![tiny_model()], rx, tx_out, &opts, metrics.clone());
        let _ = rx_out;
        assert!(metrics.peak_active.load(Ordering::Relaxed) <= 3);
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn two_workers_split_the_load() {
        let (responses, _, _) = load_test(
            vec![tiny_model(), tiny_model()],
            8,
            2,
            3,
            &ServeOptions { max_batch: 2, workers: 2 },
        );
        assert_eq!(responses.len(), 8);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn deterministic_tokens_for_same_prompt() {
        let (responses, _, _) =
            load_test(vec![tiny_model()], 3, 0, 5, &ServeOptions::default());
        // prompt depends on id, so use fresh identical requests instead:
        let (tx, rx) = std::sync::mpsc::channel();
        let (tx_out, rx_out) = std::sync::mpsc::channel();
        for id in 0..3 {
            tx.send((Request { id, prompt: vec![7, 9], n_new: 5 }, Instant::now())).unwrap();
        }
        drop(tx);
        serve(
            vec![tiny_model()],
            rx,
            tx_out,
            &ServeOptions::default(),
            Arc::new(ServeMetrics::default()),
        );
        let rs: Vec<Response> = rx_out.iter().collect();
        assert!(rs.windows(2).all(|w| w[0].tokens == w[1].tokens));
        let _ = responses;
    }
}
