//! Serving layer: the persistent [`Engine`] session API over the
//! multi-model [`ModelRegistry`] (the §4.5 / Appendix A deployment story:
//! edge inference where 1-bit GEMV dominates and weight traffic is the
//! bottleneck).
//!
//! Architecture (std threads; the offline environment has no tokio):
//!   * [`Engine::start`] spawns continuous-batching decode workers against
//!     a named, registry-leased model — a [`ModelRegistry::hot_swap`] is
//!     picked up at admission time, so new requests decode on the new
//!     generation while in-flight ones drain on the old lease
//!   * [`Engine::submit`] enforces a bounded admission queue
//!     ([`SubmitError::QueueFull`] is backpressure, not buffering) and a
//!     KV block budget ([`SubmitError::KvExhausted`], reserved against the
//!     paged [`crate::kvcache::BlockPool`]; higher-priority submissions
//!     may preempt in-flight work), and returns a [`Ticket`] streaming
//!     [`Event::Prefilled`] / [`Event::Token`] / [`Event::Done`], with
//!     [`Ticket::cancel`]
//!   * requests carry [`SamplingParams`] — greedy by default (bit-exact
//!     with [`PackedModel::generate`]), or seeded temperature / top-k —
//!     plus stop tokens
//!   * requests may decode **speculatively** ([`GenRequest::spec`]): a
//!     registry-leased draft replica proposes K tokens per round and the
//!     target verifies all K+1 positions as rows of the same fused batch
//!     step ([`spec`]) — greedy output stays bit-identical, rejected
//!     suffixes roll back their KV pages, and [`ServeMetrics`] reports
//!     acceptance rate / draft + verify step counts / net tokens per
//!     verify
//!   * workers interleave chunked prefill with decode slices, so a long
//!     prompt never stalls the active set; [`ServeMetrics`] records
//!     per-request queue-wait and time-to-first-token percentiles
//!
//! The network surface and its measurement harness live here too:
//!   * [`http`] fronts one or more engines with a dependency-free
//!     HTTP/1.1 + SSE server (`POST /v1/generate` streams ticket events;
//!     backpressure maps to 429/503 with [`engine::RetryAfter`] guidance)
//!   * [`loadgen`] replays a seeded bursty trace — mixed lengths, shared
//!     system prompts, priority tiers, a draft-enabled fraction — against
//!     the in-process engine or the HTTP endpoint and reports SLO
//!     attainment (TTFT/TPOT percentiles vs. per-tier targets, goodput,
//!     429/503 rates), with optional per-request JSONL records
//!     ([`loadgen::run_recorded`])
//!
//! Observability ([`crate::obs`]) threads through all of it: engine
//! latency/occupancy metrics land in lock-free histograms and the
//! [`crate::obs::Registry`], `GET /v1/metrics` content-negotiates JSON vs
//! Prometheus text, and [`EngineOptions::trace`] turns on per-request span
//! recording served as Chrome trace-event JSON under `GET /v1/trace/<id>`.
//!
//! [`load_test`] survives as a thin convenience shim over an ephemeral
//! `Engine` for the throughput experiments.

pub mod engine;
pub mod http;
pub mod loadgen;
pub mod registry;
pub mod spec;

pub use engine::{
    DraftError, Engine, EngineOptions, Event, FinishReason, GenRequest, GenStats, HealthState,
    Percentiles, RetryAfter, SamplingParams, ServeMetrics, SubmitError, Ticket,
};
pub use http::{HttpServer, Router};
pub use loadgen::{
    build_trace, KvReport, LoadReport, RequestRecord, SloTargets, Target, Tier, TierReport,
    TraceConfig, TraceEvent,
};
pub use registry::{Lease, ModelEntry, ModelInfo, ModelRegistry, SwapReport};
pub use spec::{SpecDecoder, SpecParams, SpecStats};

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::infer::PackedModel;

/// A completed generation (the [`load_test`] result row).
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub queue_wait: Duration,
    pub service_time: Duration,
}

/// Load-test tuning knobs (the engine exposes more via [`EngineOptions`]).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Max concurrent requests per worker (continuous batch width).
    pub max_batch: usize,
    /// Worker count (each owns a model replica).
    pub workers: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { max_batch: 4, workers: 1 }
    }
}

/// Convenience one-shot load test — a thin shim over an ephemeral
/// [`Engine`]: register the model, submit `n_requests` identical-shape
/// greedy requests, wait for completion, return (responses, wall,
/// tokens/s). One worker is spawned per supplied replica.
pub fn load_test(
    models: Vec<PackedModel>,
    n_requests: usize,
    prompt_len: usize,
    n_new: usize,
    opts: &ServeOptions,
) -> (Vec<Response>, Duration, f64) {
    assert!(!models.is_empty());
    // The engine serves one registry name, so only `models[0]`'s weights
    // are served; the extra elements just set the worker count. The assert
    // catches geometry mismatches loudly, but same-config models with
    // different weights cannot be distinguished here — don't pass any.
    assert!(
        models.iter().all(|m| m.cfg == models[0].cfg),
        "load_test takes replicas of one model, got mixed configs"
    );
    let workers = models.len();
    let vocab = models[0].cfg.vocab as u32;
    let registry = Arc::new(ModelRegistry::new());
    registry.register("load-test", models.into_iter().next().unwrap(), None);
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "load-test".into(),
            max_batch: opts.max_batch,
            workers,
            queue_depth: n_requests.max(1),
            ..EngineOptions::default()
        },
    )
    .expect("model registered above");
    let t0 = Instant::now();
    let tickets: Vec<Ticket> = (0..n_requests)
        .map(|id| {
            let prompt: Vec<u32> =
                (0..prompt_len).map(|i| (id as u32 + i as u32) % vocab).collect();
            // The queue is sized for the burst, but the KV pool may not
            // be: submit_blocking absorbs the KvExhausted backpressure
            // until in-flight requests free blocks.
            engine
                .submit_blocking(GenRequest::greedy(prompt, n_new))
                .unwrap_or_else(|e| panic!("load_test submit failed: {e}"))
        })
        .collect();
    let responses: Vec<Response> = tickets
        .into_iter()
        .map(|t| {
            let stats = t.wait();
            Response {
                id: stats.id,
                tokens: stats.tokens,
                queue_wait: stats.queue_wait,
                service_time: stats.service_time,
            }
        })
        .collect();
    let wall = t0.elapsed();
    let metrics = engine.shutdown();
    let toks = metrics.tokens_out.load(Ordering::Relaxed) as f64;
    (responses, wall, toks / wall.as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, Variant};

    fn tiny_model() -> PackedModel {
        PackedModel::random(
            &ModelConfig {
                name: "serve-test".into(),
                variant: Variant::PQuant,
                vocab: 64,
                d_model: 32,
                n_layers: 2,
                n_heads: 2,
                d_ff: 96,
                r: 16,
                n_experts: 2,
                seq_len: 32,
                alpha_init: 2.0,
                beta_init: 0.2,
            },
            3,
        )
    }

    #[test]
    fn all_requests_complete_with_correct_lengths() {
        let (responses, _, tps) =
            load_test(vec![tiny_model()], 10, 4, 6, &ServeOptions::default());
        assert_eq!(responses.len(), 10);
        for r in &responses {
            assert_eq!(r.tokens.len(), 6);
            assert!(r.tokens.iter().all(|&t| t < 64));
        }
        assert!(tps > 0.0);
    }

    #[test]
    fn two_workers_split_the_load() {
        let (responses, _, _) = load_test(
            vec![tiny_model(), tiny_model()],
            8,
            2,
            3,
            &ServeOptions { max_batch: 2, workers: 2 },
        );
        assert_eq!(responses.len(), 8);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn deterministic_tokens_for_same_prompt() {
        // Identical greedy prompts must produce identical streams, and they
        // must match the reference single-request decode loop.
        let registry = Arc::new(ModelRegistry::new());
        registry.register("m", tiny_model(), None);
        let engine = Engine::start(
            &registry,
            EngineOptions { model: "m".into(), ..EngineOptions::default() },
        )
        .unwrap();
        let tickets: Vec<Ticket> = (0..3)
            .map(|_| engine.submit(GenRequest::greedy(vec![7, 9], 5)).unwrap())
            .collect();
        let streams: Vec<Vec<u32>> =
            tickets.into_iter().map(|t| t.wait().tokens).collect();
        assert!(streams.windows(2).all(|w| w[0] == w[1]));
        assert_eq!(streams[0], tiny_model().generate(&[7, 9], 5));
    }

    #[test]
    fn zero_budget_requests_complete_immediately_with_empty_output() {
        let registry = Arc::new(ModelRegistry::new());
        registry.register("m", tiny_model(), None);
        let engine = Engine::start(
            &registry,
            EngineOptions { model: "m".into(), ..EngineOptions::default() },
        )
        .unwrap();
        let stats = engine.submit(GenRequest::greedy(vec![1, 2, 3], 0)).unwrap().wait();
        assert!(stats.tokens.is_empty());
        assert_eq!(stats.finish, FinishReason::Length);
        assert_eq!(
            engine.metrics().completed.load(Ordering::Relaxed),
            1,
            "zero-budget requests still count as completed"
        );
    }
}
