//! Multi-model serving registry: N named packed models in one server
//! process, replica hand-out for workers, and warm hot-swap.
//!
//! Each registered name maps to an [`ModelEntry`] generation.  Workers
//! [`ModelRegistry::acquire`] a [`Lease`] on the current generation and
//! clone per-worker replicas from it; the lease count is the drain barrier.
//! [`ModelRegistry::hot_swap`] installs a new generation immediately (new
//! acquires see it at once) and then waits for the old generation's leases
//! to drop — load new `.pqm`, drain, swap — so a server can roll a model
//! forward (or serve FP16 / BitNet / pQuant variants side by side) without
//! restarting or interrupting in-flight requests.

use std::collections::HashMap;
use std::ops::Deref;
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::Variant;
use crate::infer::PackedModel;
use crate::tokenizer::Bpe;

/// Process-wide entry counter backing [`ModelEntry::uid`].
static ENTRY_UID: AtomicU64 = AtomicU64::new(1);

/// One immutable generation of a registered model.
pub struct ModelEntry {
    pub name: String,
    /// Monotone per-name counter; bumped by every (re-)register/swap.
    pub generation: u64,
    /// Process-unique id, never reused — unlike the entry's address, which
    /// the allocator can recycle after a remove + re-register. Identity
    /// checks that outlive the entry (e.g. KV prefix-share tags) must use
    /// this, not the pointer.
    pub uid: u64,
    pub model: PackedModel,
    pub tokenizer: Option<Bpe>,
    leases: AtomicUsize,
}

impl ModelEntry {
    /// Leases currently outstanding against this generation.
    pub fn active_leases(&self) -> usize {
        self.leases.load(Ordering::Acquire)
    }
}

/// A counted handle on one model generation. Holding a lease keeps the
/// generation visible to the drain barrier; dropping it releases the slot.
pub struct Lease {
    entry: Arc<ModelEntry>,
}

impl Lease {
    /// Clone an independent serving replica (one per worker).
    pub fn replica(&self) -> PackedModel {
        self.entry.model.clone()
    }

    pub fn entry(&self) -> &Arc<ModelEntry> {
        &self.entry
    }
}

impl Deref for Lease {
    type Target = ModelEntry;

    fn deref(&self) -> &ModelEntry {
        &self.entry
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        self.entry.leases.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Summary row for one registered model (list/inspect output).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub generation: u64,
    pub variant: Variant,
    pub params: usize,
    pub storage_bytes: usize,
    pub active_leases: usize,
    pub has_tokenizer: bool,
}

/// Outcome of a [`ModelRegistry::hot_swap`].
#[derive(Debug, Clone)]
pub struct SwapReport {
    /// Generation now serving under the name.
    pub generation: u64,
    /// Whether the previous generation fully drained within the timeout.
    pub drained: bool,
    /// Time spent waiting on the drain barrier.
    pub waited: Duration,
}

/// Thread-safe registry of named packed models.
#[derive(Default)]
pub struct ModelRegistry {
    slots: RwLock<HashMap<String, Arc<ModelEntry>>>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Insert (or replace) a model under `name`; returns its generation.
    /// Replacing does *not* wait for the old generation — use
    /// [`ModelRegistry::hot_swap`] for the draining variant.
    pub fn register(
        &self,
        name: &str,
        model: PackedModel,
        tokenizer: Option<Bpe>,
    ) -> u64 {
        self.install(name, model, tokenizer).generation
    }

    fn install(
        &self,
        name: &str,
        model: PackedModel,
        tokenizer: Option<Bpe>,
    ) -> Arc<ModelEntry> {
        let mut slots = self.slots.write().unwrap();
        let generation = slots.get(name).map_or(0, |e| e.generation) + 1;
        let entry = Arc::new(ModelEntry {
            name: name.to_string(),
            generation,
            uid: ENTRY_UID.fetch_add(1, Ordering::Relaxed),
            model,
            tokenizer,
            leases: AtomicUsize::new(0),
        });
        slots.insert(name.to_string(), entry.clone());
        entry
    }

    /// Load a `.pqm` artifact and register it; returns the generation.
    pub fn load_pqm(&self, name: &str, path: impl AsRef<Path>) -> Result<u64> {
        let loaded = crate::artifact::load_pqm(path)?;
        Ok(self.register(name, loaded.model, loaded.tokenizer))
    }

    /// Acquire a lease on the current generation of `name`.
    pub fn acquire(&self, name: &str) -> Option<Lease> {
        let slots = self.slots.read().unwrap();
        let entry = slots.get(name)?.clone();
        entry.leases.fetch_add(1, Ordering::AcqRel);
        Some(Lease { entry })
    }

    /// Clone `n` independent replicas of `name` (worker hand-out), plus
    /// the lease covering them.  Hold the lease for as long as the
    /// replicas serve: it is what [`ModelRegistry::hot_swap`]'s drain
    /// barrier counts — dropping it early makes a swap report `drained`
    /// while old-generation replicas are still running.
    pub fn replicas(&self, name: &str, n: usize) -> Option<(Lease, Vec<PackedModel>)> {
        let lease = self.acquire(name)?;
        let models = (0..n.max(1)).map(|_| lease.replica()).collect();
        Some((lease, models))
    }

    /// Warm hot-swap: install the new generation (new acquires see it
    /// immediately), then wait up to `drain_timeout` for leases on the old
    /// generation to drop.  Returns whether the old generation drained.
    pub fn hot_swap(
        &self,
        name: &str,
        model: PackedModel,
        tokenizer: Option<Bpe>,
        drain_timeout: Duration,
    ) -> SwapReport {
        let old = {
            let slots = self.slots.read().unwrap();
            slots.get(name).cloned()
        };
        let entry = self.install(name, model, tokenizer);
        let t0 = Instant::now();
        let mut drained = true;
        if let Some(old) = old {
            while old.active_leases() > 0 {
                if t0.elapsed() >= drain_timeout {
                    drained = false;
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        SwapReport { generation: entry.generation, drained, waited: t0.elapsed() }
    }

    /// Load a `.pqm` artifact and hot-swap it in under `name`.
    pub fn hot_swap_pqm(
        &self,
        name: &str,
        path: impl AsRef<Path>,
        drain_timeout: Duration,
    ) -> Result<SwapReport> {
        let loaded = crate::artifact::load_pqm(path)?;
        Ok(self.hot_swap(name, loaded.model, loaded.tokenizer, drain_timeout))
    }

    /// Remove a model; returns true if it existed. In-flight leases keep
    /// the evicted generation alive until they drop.
    pub fn remove(&self, name: &str) -> bool {
        self.slots.write().unwrap().remove(name).is_some()
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.slots.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    /// Summary of every registered model, sorted by name.
    pub fn info(&self) -> Vec<ModelInfo> {
        let slots = self.slots.read().unwrap();
        let mut rows: Vec<ModelInfo> = slots
            .values()
            .map(|e| ModelInfo {
                name: e.name.clone(),
                generation: e.generation,
                variant: e.model.cfg.variant,
                params: e.model.cfg.param_count(),
                storage_bytes: e.model.storage_bytes(),
                active_leases: e.active_leases(),
                has_tokenizer: e.tokenizer.is_some(),
            })
            .collect();
        rows.sort_by(|a, b| a.name.cmp(&b.name));
        rows
    }

    pub fn len(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn tiny(variant: Variant, seed: u64) -> PackedModel {
        PackedModel::random(
            &ModelConfig {
                name: format!("reg-{}", variant.name()),
                variant,
                vocab: 64,
                d_model: 32,
                n_layers: 2,
                n_heads: 2,
                d_ff: 96,
                r: if variant == Variant::PQuant { 16 } else { 0 },
                n_experts: if variant == Variant::PQuant { 2 } else { 1 },
                seq_len: 32,
                alpha_init: 2.0,
                beta_init: 0.2,
            },
            seed,
        )
    }

    #[test]
    fn register_acquire_and_list() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        assert_eq!(reg.register("pquant", tiny(Variant::PQuant, 1), None), 1);
        assert_eq!(reg.register("fp16", tiny(Variant::Fp16, 2), None), 1);
        assert_eq!(reg.names(), vec!["fp16".to_string(), "pquant".to_string()]);
        assert!(reg.acquire("missing").is_none());
        let lease = reg.acquire("pquant").unwrap();
        assert_eq!(lease.generation, 1);
        assert_eq!(lease.active_leases(), 1);
        let info = reg.info();
        assert_eq!(info.len(), 2);
        assert_eq!(info[1].active_leases, 1);
        drop(lease);
        assert_eq!(reg.info()[1].active_leases, 0);
    }

    #[test]
    fn replicas_are_independent_identical_and_leased() {
        let reg = ModelRegistry::new();
        reg.register("m", tiny(Variant::PQuant, 3), None);
        let (lease, mut reps) = reg.replicas("m", 2).unwrap();
        assert_eq!(reps.len(), 2);
        // The hand-out is covered by a live lease until the caller drops it.
        assert_eq!(lease.active_leases(), 1);
        let (a, b) = reps.split_at_mut(1);
        assert_eq!(a[0].generate(&[1, 2], 5), b[0].generate(&[1, 2], 5));
        drop(lease);
        assert_eq!(reg.acquire("m").unwrap().active_leases(), 1);
    }

    #[test]
    fn hot_swap_bumps_generation_and_waits_for_leases() {
        let reg = ModelRegistry::new();
        reg.register("m", tiny(Variant::BitNet, 1), None);
        let lease = reg.acquire("m").unwrap();
        assert_eq!(lease.generation, 1);

        // Swap with an outstanding lease and a zero drain budget: the new
        // generation is installed, but the old one has not drained.
        let report = reg.hot_swap("m", tiny(Variant::BitNet158, 2), None, Duration::ZERO);
        assert_eq!(report.generation, 2);
        assert!(!report.drained);

        // New acquires land on the new generation while the old lease lives.
        let fresh = reg.acquire("m").unwrap();
        assert_eq!(fresh.generation, 2);
        assert_eq!(fresh.model.cfg.variant, Variant::BitNet158);
        drop(fresh);

        // Once the old lease drops, a re-swap drains immediately.
        drop(lease);
        let report = reg.hot_swap("m", tiny(Variant::PQuant, 3), None, Duration::from_secs(5));
        assert_eq!(report.generation, 3);
        assert!(report.drained);
    }

    #[test]
    fn remove_keeps_inflight_leases_alive() {
        let reg = ModelRegistry::new();
        reg.register("m", tiny(Variant::Fp16, 1), None);
        let lease = reg.acquire("m").unwrap();
        assert!(reg.remove("m"));
        assert!(!reg.remove("m"));
        assert!(reg.acquire("m").is_none());
        // The lease still reads the evicted generation's weights.
        assert_eq!(lease.model.cfg.variant, Variant::Fp16);
    }

    #[test]
    fn engine_served_tokens_match_direct_generation() {
        use super::super::{Engine, EngineOptions, GenRequest};
        let reg = Arc::new(ModelRegistry::new());
        reg.register("m", tiny(Variant::PQuant, 5), None);
        let engine = Engine::start(
            &reg,
            EngineOptions { model: "m".into(), max_batch: 2, ..EngineOptions::default() },
        )
        .unwrap();
        let tickets: Vec<_> = (0..4)
            .map(|_| engine.submit(GenRequest::greedy(vec![3, 1], 5)).unwrap())
            .collect();

        let mut direct = tiny(Variant::PQuant, 5);
        let want = direct.generate(&[3, 1], 5);
        for t in tickets {
            assert_eq!(t.wait().tokens, want, "registry-served tokens diverge");
        }
    }

    #[test]
    fn per_name_generations_are_independent() {
        let reg = ModelRegistry::new();
        assert_eq!(reg.register("a", tiny(Variant::Fp16, 1), None), 1);
        assert_eq!(reg.register("a", tiny(Variant::Fp16, 2), None), 2);
        assert_eq!(reg.register("b", tiny(Variant::BitNet, 3), None), 1);
        assert_eq!(reg.len(), 2);
    }
}
