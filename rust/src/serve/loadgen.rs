//! Trace-driven workload harness with SLO reporting.
//!
//! Serving numbers measured "in a loop" say little about behaviour under
//! traffic shaped like real users, so this module replays a **seeded
//! trace** — bursty arrivals (Poisson modulated by an on/off burst
//! process), mixed prompt/output length distributions, a shared
//! system-prompt fraction (exercising the KV prefix-share map), priority
//! tiers with per-tier SLO targets, and a draft-enabled fraction — against
//! either the in-process [`Engine`] or a live HTTP endpoint
//! ([`Target::Http`], speaking the `serve::http` wire format over a raw
//! `TcpStream`).
//!
//! The trace is built entirely up front by [`build_trace`] from a
//! [`TraceConfig`] and a seed: same seed + config ⇒ byte-identical
//! schedule, so runs are comparable across commits. Execution measures
//! **client-observed** latencies (submit → first token, mean inter-token
//! gap) and applies the engine's typed [`RetryAfter`] guidance in its
//! retry loop when a submission bounces with 429/503-class backpressure.
//!
//! The result is a [`LoadReport`]: per-tier TTFT/TPOT percentiles vs.
//! targets, **goodput** (fraction of a tier's requests that completed
//! within SLO), and overall 429/503 retry/reject rates — serialized to
//! `results/bench/loadgen.json` by the `repro loadtest` subcommand and
//! `benches/loadgen.rs`. [`run_recorded`] additionally returns every
//! request's [`RequestRecord`] (arrival, queue wait, TTFT, TPOT, tokens,
//! tier, finish reason, retries), which `repro loadtest --out-jsonl PATH`
//! writes one-JSON-object-per-line via [`write_jsonl`].

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::kvcache::KvPoolStats;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::rng::Rng;

use super::{Engine, Event, FinishReason, GenRequest, Percentiles, SamplingParams};

/// Per-tier latency targets. A completed request "meets SLO" when its
/// client-observed TTFT and TPOT both land under these.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloTargets {
    pub ttft_ms: f64,
    pub tpot_ms: f64,
}

/// One priority tier in the workload mix.
#[derive(Debug, Clone, PartialEq)]
pub struct Tier {
    pub name: String,
    /// Engine priority (higher preempts lower).
    pub priority: i32,
    /// Unnormalized share of requests landing in this tier.
    pub weight: f64,
    pub slo: SloTargets,
}

/// Everything that shapes the trace. Deterministic given `seed`.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceConfig {
    pub seed: u64,
    pub n_requests: usize,
    /// Mean arrival rate (req/s) in the quiet state.
    pub rate: f64,
    /// Rate multiplier while a burst is on (Markov-modulated Poisson).
    pub burst_factor: f64,
    /// Mean burst / quiet-gap durations (seconds, exponential holding).
    pub burst_on_s: f64,
    pub burst_off_s: f64,
    /// (length, weight) mixtures for prompt and output lengths.
    pub prompt_lens: Vec<(usize, f64)>,
    pub output_lens: Vec<(usize, f64)>,
    /// Fraction of requests opening with the shared system prompt.
    pub shared_frac: f64,
    pub shared_prefix_len: usize,
    pub tiers: Vec<Tier>,
    /// Fraction of requests decoding speculatively (needs `draft_model`).
    pub draft_frac: f64,
    pub draft_model: Option<String>,
    pub spec_k: usize,
    /// Client-side retry budget per request on 429/503 backpressure.
    pub max_retries: usize,
    /// Token id space for synthetic prompts.
    pub vocab: u32,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 0,
            n_requests: 64,
            rate: 200.0,
            burst_factor: 4.0,
            burst_on_s: 0.15,
            burst_off_s: 0.35,
            prompt_lens: vec![(4, 0.5), (8, 0.3), (16, 0.2)],
            output_lens: vec![(8, 0.6), (16, 0.3), (24, 0.1)],
            shared_frac: 0.4,
            shared_prefix_len: 16,
            tiers: vec![
                Tier {
                    name: "interactive".into(),
                    priority: 1,
                    weight: 0.3,
                    slo: SloTargets { ttft_ms: 250.0, tpot_ms: 50.0 },
                },
                Tier {
                    name: "standard".into(),
                    priority: 0,
                    weight: 0.5,
                    slo: SloTargets { ttft_ms: 500.0, tpot_ms: 100.0 },
                },
                Tier {
                    name: "batch".into(),
                    priority: -1,
                    weight: 0.2,
                    slo: SloTargets { ttft_ms: 2000.0, tpot_ms: 400.0 },
                },
            ],
            draft_frac: 0.0,
            draft_model: None,
            spec_k: 4,
            max_retries: 8,
            vocab: 64,
        }
    }
}

/// One scheduled request: arrival offset + fully materialized payload.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub at: Duration,
    pub prompt: Vec<u32>,
    pub n_new: usize,
    pub tier: usize,
    pub shared: bool,
    pub draft: bool,
}

/// Materialize the whole schedule up front. A single RNG stream drawn in
/// a fixed order makes the trace a pure function of (config, seed).
pub fn build_trace(cfg: &TraceConfig) -> Vec<TraceEvent> {
    assert!(!cfg.tiers.is_empty(), "trace needs at least one tier");
    assert!(!cfg.prompt_lens.is_empty() && !cfg.output_lens.is_empty());
    let mut rng = Rng::new(cfg.seed ^ 0x6c6f6164); // "load"
    let shared_prefix: Vec<u32> =
        (0..cfg.shared_prefix_len).map(|_| rng.below(cfg.vocab as usize) as u32).collect();
    let tier_weights: Vec<f64> = cfg.tiers.iter().map(|t| t.weight).collect();
    let prompt_w: Vec<f64> = cfg.prompt_lens.iter().map(|&(_, w)| w).collect();
    let output_w: Vec<f64> = cfg.output_lens.iter().map(|&(_, w)| w).collect();
    // Exponential holding times drive the burst state machine; each
    // arrival's interarrival gap is exponential at the state's rate.
    let exp = |rng: &mut Rng, mean: f64| -> f64 { -mean * (1.0 - rng.f64()).max(1e-12).ln() };
    let mut bursting = false;
    let mut state_left = exp(&mut rng, cfg.burst_off_s);
    let mut clock = 0.0f64;
    let mut trace = Vec::with_capacity(cfg.n_requests);
    for _ in 0..cfg.n_requests {
        let rate = if bursting { cfg.rate * cfg.burst_factor } else { cfg.rate };
        let mut gap = exp(&mut rng, 1.0 / rate.max(1e-9));
        // Burst state flips mid-gap: spend the remaining wait at the new
        // state's rate (memorylessness makes the re-draw exact).
        while gap > state_left {
            gap -= state_left;
            bursting = !bursting;
            state_left = exp(&mut rng, if bursting { cfg.burst_on_s } else { cfg.burst_off_s });
            let new_rate = if bursting { cfg.rate * cfg.burst_factor } else { cfg.rate };
            gap = gap * rate / new_rate.max(1e-9);
        }
        state_left -= gap;
        clock += gap;
        let tier = rng.weighted(&tier_weights);
        let prompt_len = cfg.prompt_lens[rng.weighted(&prompt_w)].0.max(1);
        let n_new = cfg.output_lens[rng.weighted(&output_w)].0;
        let shared = rng.f64() < cfg.shared_frac && cfg.shared_prefix_len > 0;
        let draft = cfg.draft_model.is_some() && rng.f64() < cfg.draft_frac;
        let mut prompt = Vec::with_capacity(prompt_len.max(cfg.shared_prefix_len + 1));
        if shared {
            prompt.extend_from_slice(&shared_prefix);
        }
        let tail = if shared { prompt_len.max(1) } else { prompt_len };
        prompt.extend((0..tail).map(|_| rng.below(cfg.vocab as usize) as u32));
        trace.push(TraceEvent { at: Duration::from_secs_f64(clock), prompt, n_new, tier, shared, draft });
    }
    trace
}

/// What the generator drives: the in-process engine, or a live HTTP
/// endpoint speaking the `serve::http` wire format.
pub enum Target<'a> {
    Engine(&'a Engine),
    Http(String),
}

/// Client-observed outcome of one request (after retries) — one line of
/// the `--out-jsonl` per-request log.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    /// Index in the generated trace (stable across runs of one seed).
    pub index: usize,
    /// Tier index into [`TraceConfig::tiers`].
    pub tier: usize,
    pub tier_name: String,
    /// Scheduled arrival offset on the trace clock.
    pub arrival_ms: f64,
    /// Opened with the shared system prompt.
    pub shared: bool,
    /// Requested speculative decoding.
    pub draft: bool,
    pub completed: bool,
    /// Terminal state: `length`/`stop`/`cancelled`/`failed`/
    /// `worker_fault`/`deadline`, `rejected` when the retry budget ran
    /// out, `incomplete` when the stream closed without a done frame.
    pub finish: String,
    /// Server-reported submit→admission wait (from the done frame).
    pub queue_wait_ms: Option<f64>,
    pub ttft_ms: Option<f64>,
    pub tpot_ms: Option<f64>,
    pub tokens: usize,
    pub retries_429: usize,
    pub retries_503: usize,
    pub rejected: bool,
}

impl RequestRecord {
    fn new(index: usize, ev: &TraceEvent, cfg: &TraceConfig) -> RequestRecord {
        RequestRecord {
            index,
            tier: ev.tier,
            tier_name: cfg.tiers[ev.tier].name.clone(),
            arrival_ms: ev.at.as_secs_f64() * 1e3,
            shared: ev.shared,
            draft: ev.draft,
            completed: false,
            finish: String::new(),
            queue_wait_ms: None,
            ttft_ms: None,
            tpot_ms: None,
            tokens: 0,
            retries_429: 0,
            retries_503: 0,
            rejected: false,
        }
    }

    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(num).unwrap_or(Json::Null);
        obj(vec![
            ("index", num(self.index as f64)),
            ("tier", s(&self.tier_name)),
            ("arrival_ms", num(self.arrival_ms)),
            ("shared", Json::Bool(self.shared)),
            ("draft", Json::Bool(self.draft)),
            ("completed", Json::Bool(self.completed)),
            ("finish", s(&self.finish)),
            ("queue_wait_ms", opt(self.queue_wait_ms)),
            ("ttft_ms", opt(self.ttft_ms)),
            ("tpot_ms", opt(self.tpot_ms)),
            ("tokens", num(self.tokens as f64)),
            ("retries_429", num(self.retries_429 as f64)),
            ("retries_503", num(self.retries_503 as f64)),
            ("rejected", Json::Bool(self.rejected)),
        ])
    }
}

/// Write per-request records as JSON Lines (one object per line),
/// creating parent directories.
pub fn write_jsonl(records: &[RequestRecord], path: &std::path::Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut out = String::with_capacity(records.len() * 160);
    for r in records {
        out.push_str(&r.to_json().to_string());
        out.push('\n');
    }
    std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))
}

/// Per-tier slice of the SLO report.
#[derive(Debug, Clone)]
pub struct TierReport {
    pub name: String,
    pub priority: i32,
    pub targets: SloTargets,
    pub n: usize,
    pub completed: usize,
    pub slo_met: usize,
    /// Fraction of the tier's requests that completed within SLO.
    pub goodput: f64,
    pub ttft: Percentiles,
    pub tpot: Percentiles,
}

/// Server-side KV-pool accounting captured when the replay ends, so the
/// client-observed SLO numbers can be reconciled against the memory
/// pressure that produced them. Sourced from the engine's
/// [`KvPoolStats`] directly (in-process target) or from the `kv` object
/// in `GET /v1/metrics` (HTTP target) — the same counters either way.
#[derive(Debug, Clone, PartialEq)]
pub struct KvReport {
    /// Storage precision of the pool ("f32" / "int8").
    pub mode: String,
    pub n_blocks: usize,
    pub capacity_bytes: usize,
    /// Block high-water mark over the run (peak concurrent charge).
    pub peak_in_use: usize,
    pub peak_utilization: f64,
    /// Byte high-water mark (`peak_in_use × block_bytes`).
    pub peak_resident_bytes: usize,
    pub shared_hit_rate: f64,
    pub evicted_blocks: usize,
    pub spilled_blocks: usize,
    pub spill_writes: usize,
    pub spill_faults: usize,
}

impl KvReport {
    pub fn from_stats(st: &KvPoolStats) -> KvReport {
        KvReport {
            mode: st.mode.name().to_string(),
            n_blocks: st.n_blocks,
            capacity_bytes: st.capacity_bytes,
            peak_in_use: st.peak_in_use,
            peak_utilization: st.peak_utilization,
            peak_resident_bytes: st.peak_in_use * st.block_bytes,
            shared_hit_rate: st.shared_hit_rate,
            evicted_blocks: st.evicted_blocks,
            spilled_blocks: st.spilled_blocks,
            spill_writes: st.spill_writes,
            spill_faults: st.spill_faults,
        }
    }

    /// Rebuild from the `kv` object of a `/v1/metrics` response.
    fn from_json(j: &Json) -> Option<KvReport> {
        let f = |k: &str| j.opt(k).and_then(|v| v.as_f64().ok());
        let block_bytes = f("block_bytes")? as usize;
        let peak_in_use = f("peak_in_use")? as usize;
        Some(KvReport {
            mode: j.opt("mode")?.as_str().ok()?.to_string(),
            n_blocks: f("n_blocks")? as usize,
            capacity_bytes: f("capacity_bytes")? as usize,
            peak_in_use,
            peak_utilization: f("peak_utilization")?,
            peak_resident_bytes: peak_in_use * block_bytes,
            shared_hit_rate: f("shared_hit_rate")?,
            evicted_blocks: f("evicted_blocks")? as usize,
            spilled_blocks: f("spilled_blocks")? as usize,
            spill_writes: f("spill_writes")? as usize,
            spill_faults: f("spill_faults")? as usize,
        })
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("mode", s(&self.mode)),
            ("n_blocks", num(self.n_blocks as f64)),
            ("capacity_bytes", num(self.capacity_bytes as f64)),
            ("peak_in_use", num(self.peak_in_use as f64)),
            ("peak_utilization", num(self.peak_utilization)),
            ("peak_resident_bytes", num(self.peak_resident_bytes as f64)),
            ("shared_hit_rate", num(self.shared_hit_rate)),
            ("evicted_blocks", num(self.evicted_blocks as f64)),
            ("spilled_blocks", num(self.spilled_blocks as f64)),
            ("spill_writes", num(self.spill_writes as f64)),
            ("spill_faults", num(self.spill_faults as f64)),
        ])
    }
}

/// The SLO attainment report for one trace replay.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub wall: Duration,
    pub submitted: usize,
    pub completed: usize,
    /// Requests that exhausted their retry budget on backpressure.
    pub rejected: usize,
    pub retries_429: usize,
    pub retries_503: usize,
    pub tokens_out: usize,
    pub tiers: Vec<TierReport>,
    /// Server-side KV pressure snapshot (None when the engine runs
    /// without a pool, or the HTTP target exposes no `kv` metrics).
    pub kv: Option<KvReport>,
}

impl LoadReport {
    pub fn throughput(&self) -> f64 {
        self.tokens_out as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Overall goodput: SLO-met requests over all submitted.
    pub fn goodput(&self) -> f64 {
        let met: usize = self.tiers.iter().map(|t| t.slo_met).sum();
        met as f64 / self.submitted.max(1) as f64
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("wall_s", num(self.wall.as_secs_f64())),
            ("submitted", num(self.submitted as f64)),
            ("completed", num(self.completed as f64)),
            ("rejected", num(self.rejected as f64)),
            ("retries_429", num(self.retries_429 as f64)),
            ("retries_503", num(self.retries_503 as f64)),
            ("tokens_out", num(self.tokens_out as f64)),
            ("tokens_per_s", num(self.throughput())),
            ("goodput", num(self.goodput())),
            (
                "tiers",
                arr(self.tiers.iter().map(|t| {
                    obj(vec![
                        ("name", s(&t.name)),
                        ("priority", num(t.priority as f64)),
                        ("ttft_target_ms", num(t.targets.ttft_ms)),
                        ("tpot_target_ms", num(t.targets.tpot_ms)),
                        ("n", num(t.n as f64)),
                        ("completed", num(t.completed as f64)),
                        ("slo_met", num(t.slo_met as f64)),
                        ("goodput", num(t.goodput)),
                        ("ttft_ms", t.ttft.to_json()),
                        ("tpot_ms", t.tpot.to_json()),
                    ])
                })),
            ),
        ];
        if let Some(kv) = &self.kv {
            pairs.push(("kv", kv.to_json()));
        }
        obj(pairs)
    }

    /// Write the pretty JSON report, creating parent directories.
    pub fn write(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty() + "\n")
            .with_context(|| format!("writing {}", path.display()))
    }
}

/// Replay `cfg`'s trace against `target` and report SLO attainment.
/// One driver thread paces arrivals on the trace clock; each request runs
/// on its own thread (retry loop + stream consumption), mirroring
/// independent clients.
pub fn run(target: Target<'_>, cfg: &TraceConfig) -> Result<LoadReport> {
    Ok(run_recorded(target, cfg)?.0)
}

/// [`run`], plus every request's [`RequestRecord`] in trace order.
pub fn run_recorded(
    target: Target<'_>,
    cfg: &TraceConfig,
) -> Result<(LoadReport, Vec<RequestRecord>)> {
    let trace = build_trace(cfg);
    let outcomes: Mutex<Vec<RequestRecord>> = Mutex::new(Vec::with_capacity(trace.len()));
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for (i, ev) in trace.iter().enumerate() {
            let wait = (t0 + ev.at).saturating_duration_since(Instant::now());
            if !wait.is_zero() {
                std::thread::sleep(wait);
            }
            let target = &target;
            let outcomes = &outcomes;
            scope.spawn(move || {
                let mut outcome = match target {
                    Target::Engine(engine) => run_one_engine(engine, i, ev, cfg),
                    Target::Http(addr) => run_one_http(addr, i, ev, cfg),
                };
                if outcome.finish.is_empty() {
                    outcome.finish =
                        if outcome.rejected { "rejected" } else { "incomplete" }.to_string();
                }
                outcomes.lock().unwrap().push(outcome);
            });
        }
    });
    let wall = t0.elapsed();
    let mut records = outcomes.into_inner().unwrap();
    records.sort_by_key(|r| r.index);
    let mut report = summarize(cfg, &records, wall);
    // Snapshot server-side KV pressure after the last request drains, so
    // peaks cover the whole replay.
    report.kv = match &target {
        Target::Engine(engine) => engine.kv_pool().map(|p| KvReport::from_stats(&p.stats())),
        Target::Http(addr) => fetch_http_kv(addr),
    };
    Ok((report, records))
}

/// GET /v1/metrics from the serving endpoint and lift out the `kv`
/// object. Best-effort: a target without KV metrics yields `None`.
fn fetch_http_kv(addr: &str) -> Option<KvReport> {
    let mut stream = TcpStream::connect(addr).ok()?;
    stream.set_nodelay(true).ok();
    write!(stream, "GET /v1/metrics HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n").ok()?;
    stream.flush().ok()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).ok()?;
    if line.split_whitespace().nth(1) != Some("200") {
        return None;
    }
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).ok()?;
        if h.trim_end().is_empty() {
            break;
        }
    }
    let mut body = String::new();
    reader.read_to_string(&mut body).ok()?;
    let j = Json::parse(body.trim()).ok()?;
    // The response is keyed by model name, one metrics object per routed
    // engine; take the first engine that exposes a pool.
    match &j {
        Json::Obj(per_model) => {
            per_model.values().find_map(|m| KvReport::from_json(m.opt("kv")?))
        }
        _ => None,
    }
}

fn summarize(cfg: &TraceConfig, outcomes: &[RequestRecord], wall: Duration) -> LoadReport {
    let mut tiers = Vec::with_capacity(cfg.tiers.len());
    for (i, tier) in cfg.tiers.iter().enumerate() {
        let of_tier: Vec<&RequestRecord> = outcomes.iter().filter(|o| o.tier == i).collect();
        let ttft: Vec<f64> = of_tier.iter().filter_map(|o| o.ttft_ms).collect();
        let tpot: Vec<f64> = of_tier.iter().filter_map(|o| o.tpot_ms).collect();
        let slo_met = of_tier
            .iter()
            .filter(|o| {
                // Single-token outputs have no inter-token gap; TTFT alone
                // decides their SLO.
                o.completed
                    && o.ttft_ms.is_some_and(|t| t <= tier.slo.ttft_ms)
                    && o.tpot_ms.map_or(true, |t| t <= tier.slo.tpot_ms)
            })
            .count();
        let completed = of_tier.iter().filter(|o| o.completed).count();
        tiers.push(TierReport {
            name: tier.name.clone(),
            priority: tier.priority,
            targets: tier.slo,
            n: of_tier.len(),
            completed,
            slo_met,
            goodput: slo_met as f64 / of_tier.len().max(1) as f64,
            ttft: Percentiles::of(&ttft),
            tpot: Percentiles::of(&tpot),
        });
    }
    LoadReport {
        wall,
        submitted: outcomes.len(),
        completed: outcomes.iter().filter(|o| o.completed).count(),
        rejected: outcomes.iter().filter(|o| o.rejected).count(),
        retries_429: outcomes.iter().map(|o| o.retries_429).sum(),
        retries_503: outcomes.iter().map(|o| o.retries_503).sum(),
        tokens_out: outcomes.iter().map(|o| o.tokens).sum(),
        tiers,
        kv: None,
    }
}

fn request_for(ev: &TraceEvent, cfg: &TraceConfig) -> GenRequest {
    let mut req = GenRequest::sampled(ev.prompt.clone(), ev.n_new, SamplingParams::default())
        .with_priority(cfg.tiers[ev.tier].priority);
    if ev.draft {
        if let Some(draft) = &cfg.draft_model {
            req = req.with_spec(draft.clone(), cfg.spec_k);
        }
    }
    req
}

/// Cap on one retry sleep so a load test against a tiny engine finishes
/// promptly even when the engine suggests a long back-off.
const RETRY_SLEEP_CAP: Duration = Duration::from_millis(100);

fn run_one_engine(engine: &Engine, index: usize, ev: &TraceEvent, cfg: &TraceConfig) -> RequestRecord {
    let mut out = RequestRecord::new(index, ev, cfg);
    let submit_t0 = Instant::now();
    let mut req = request_for(ev, cfg);
    let ticket = loop {
        match engine.submit(req) {
            Ok(t) => break t,
            Err(e) if e.is_backpressure() => {
                let total = out.retries_429 + out.retries_503;
                let ra = e.retry_after().unwrap_or(Duration::from_millis(5));
                match &e {
                    super::SubmitError::QueueFull(..) => out.retries_429 += 1,
                    _ => out.retries_503 += 1,
                }
                if total >= cfg.max_retries {
                    out.rejected = true;
                    return out;
                }
                req = e.into_request();
                std::thread::sleep(ra.min(RETRY_SLEEP_CAP));
            }
            Err(_) => {
                out.rejected = true;
                return out;
            }
        }
    };
    let mut first_tok: Option<Instant> = None;
    let mut last_tok: Option<Instant> = None;
    loop {
        match ticket.recv() {
            Some(Event::Prefilled { .. }) => {}
            Some(Event::Token(_)) => {
                let now = Instant::now();
                if first_tok.is_none() {
                    first_tok = Some(now);
                }
                last_tok = Some(now);
                out.tokens += 1;
            }
            Some(Event::Done(stats)) => {
                out.completed = matches!(stats.finish, FinishReason::Length | FinishReason::Stop);
                out.finish = match stats.finish {
                    FinishReason::Length => "length",
                    FinishReason::Stop => "stop",
                    FinishReason::Cancelled => "cancelled",
                    FinishReason::Failed => "failed",
                    FinishReason::WorkerFault => "worker_fault",
                    FinishReason::DeadlineExceeded => "deadline",
                }
                .to_string();
                out.queue_wait_ms = Some(stats.queue_wait.as_secs_f64() * 1e3);
                break;
            }
            None => break,
        }
    }
    finish_timing(&mut out, submit_t0, first_tok, last_tok);
    out
}

fn finish_timing(
    out: &mut RequestRecord,
    submit_t0: Instant,
    first_tok: Option<Instant>,
    last_tok: Option<Instant>,
) {
    if let Some(first) = first_tok {
        out.ttft_ms = Some(first.duration_since(submit_t0).as_secs_f64() * 1e3);
        if out.tokens >= 2 {
            let span = last_tok.unwrap().duration_since(first).as_secs_f64() * 1e3;
            out.tpot_ms = Some(span / (out.tokens - 1) as f64);
        }
    }
}

// ------------------------------------------------- the HTTP client path

fn body_for(ev: &TraceEvent, cfg: &TraceConfig) -> String {
    let mut pairs = vec![
        ("prompt", arr(ev.prompt.iter().map(|&t| num(t as f64)))),
        ("n_new", num(ev.n_new as f64)),
        ("priority", num(cfg.tiers[ev.tier].priority as f64)),
    ];
    if ev.draft {
        if let Some(draft) = &cfg.draft_model {
            pairs.push(("draft_model", s(draft)));
            pairs.push(("spec_k", num(cfg.spec_k as f64)));
        }
    }
    obj(pairs).to_string()
}

/// One POST /v1/generate round: returns the HTTP status plus, on 200, the
/// streamed outcome fields, or on backpressure the parsed retry hint.
fn http_attempt(
    addr: &str,
    body: &str,
    submit_t0: Instant,
    out: &mut RequestRecord,
) -> Result<(u16, Option<Duration>)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    write!(
        stream,
        "POST /v1/generate HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| anyhow!("bad status line {line:?}"))?;
    // Headers (keep Retry-After for the backpressure path).
    let mut retry_after = None;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("retry-after") {
                retry_after = v.trim().parse::<u64>().ok().map(Duration::from_secs);
            }
        }
    }
    if status != 200 {
        // Prefer the precise millisecond hint from the JSON body.
        let mut rest = String::new();
        reader.read_to_string(&mut rest).ok();
        if let Ok(j) = Json::parse(rest.trim()) {
            if let Some(ms) = j.opt("retry_after_ms").and_then(|v| v.as_f64().ok()) {
                retry_after = Some(Duration::from_secs_f64(ms.max(0.0) / 1e3));
            }
        }
        return Ok((status, retry_after));
    }
    // SSE stream: `event: <kind>` then `data: {...}`, blank-line separated.
    let mut first_tok: Option<Instant> = None;
    let mut last_tok: Option<Instant> = None;
    let mut event_kind = String::new();
    loop {
        let mut l = String::new();
        if reader.read_line(&mut l)? == 0 {
            break;
        }
        let l = l.trim_end();
        if let Some(kind) = l.strip_prefix("event: ") {
            event_kind = kind.to_string();
        } else if let Some(data) = l.strip_prefix("data: ") {
            match event_kind.as_str() {
                "token" => {
                    let now = Instant::now();
                    if first_tok.is_none() {
                        first_tok = Some(now);
                    }
                    last_tok = Some(now);
                    out.tokens += 1;
                }
                "done" => {
                    let j = Json::parse(data)?;
                    let finish = j.get("finish")?.as_str()?.to_string();
                    out.completed = finish == "length" || finish == "stop";
                    out.queue_wait_ms = j.opt("queue_wait_ms").and_then(|v| v.as_f64().ok());
                    out.finish = finish;
                }
                _ => {}
            }
        }
    }
    finish_timing(out, submit_t0, first_tok, last_tok);
    Ok((200, None))
}

fn run_one_http(addr: &str, index: usize, ev: &TraceEvent, cfg: &TraceConfig) -> RequestRecord {
    let mut out = RequestRecord::new(index, ev, cfg);
    let body = body_for(ev, cfg);
    let submit_t0 = Instant::now();
    loop {
        match http_attempt(addr, &body, submit_t0, &mut out) {
            Ok((200, _)) => return out,
            Ok((code @ (429 | 503), hint)) => {
                let total = out.retries_429 + out.retries_503;
                if code == 429 {
                    out.retries_429 += 1;
                } else {
                    out.retries_503 += 1;
                }
                if total >= cfg.max_retries {
                    out.rejected = true;
                    return out;
                }
                std::thread::sleep(hint.unwrap_or(Duration::from_millis(5)).min(RETRY_SLEEP_CAP));
            }
            Ok(_) | Err(_) => {
                out.rejected = true;
                return out;
            }
        }
    }
}

/// Parse a `"len:weight,len:weight"` CLI mixture spec.
pub fn parse_mixture(spec: &str) -> Result<Vec<(usize, f64)>> {
    let mut mix = Vec::new();
    for part in spec.split(',') {
        let (len, w) = match part.split_once(':') {
            Some((l, w)) => (l.trim().parse()?, w.trim().parse()?),
            None => (part.trim().parse()?, 1.0),
        };
        mix.push((len, w));
    }
    if mix.is_empty() {
        bail!("empty length mixture {spec:?}");
    }
    Ok(mix)
}
